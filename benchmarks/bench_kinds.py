"""New-kind microbench: cc / kreach / rw rows on the ingested fixture.

The visit-algebra workload matrix grew past the paper's sssp/bfs/ppr
trio — connected components (zero-weight min-label propagation with the
strict-pending rule), hop-budgeted weighted k-reach (lex-(hops, dist)
packed into one f32 plane), and batched random-walk sampling (a
per-(source, step) tape, no algebra at all).  This module gives each new
kind a measured row per backend so BENCH_engine.json carries their perf
trajectory next to the dispatch and serving sections, and so
``planner.auto_fused`` has somewhere to read yardsticks from when a
fused variant of these kinds lands.

The quick graph is deliberately the committed SNAP-style fixture
(``build_suite("snap-tiny")`` -> ``graphs.io.load_edge_list``): the rows
measure the kinds on *really ingested* data — sparse ids compacted on
load, text weights, a hub-heavy degree tail the degree-aware planner has
to size around — not on a friendly generator.  Each timed run is also
cross-checked (cc against the union-find oracle, kreach/rw engine vs
baselines bitwise), so a row can never be fast-but-wrong.

Rows mirror into the ``bench_kinds`` section of the top-level
``BENCH_engine.json`` (CI asserts every kind x backend cell is present).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import mirror_engine_rows, rnd, sources_for, timed
from repro.core import oracles
from repro.fpp import FPPSession
from repro.graphs.generators import build_suite

COLUMNS = ["kind", "backend", "graph", "queries", "runtime_s", "qps",
           "visits", "edges_per_q"]

KINDS = ("cc", "kreach", "rw")
BACKENDS = ("engine", "baselines")
K_HOPS = 4
WALK_LEN = 16


def _kwargs(kind):
    if kind == "kreach":
        return {"k": K_HOPS}
    if kind == "rw":
        return {"length": WALK_LEN, "seed": 0}
    return {}


def run(quick: bool = True):
    gname = "snap-tiny" if quick else "social-lj"
    g = build_suite(gname)
    Q = 8 if quick else 32
    # planner default: degree-aware sizing sees the fixture's hub tail
    sess = FPPSession(g).plan(num_queries=Q)
    srcs = sources_for(g, Q, seed=5)
    want_cc = oracles.connected_components(g).astype(np.float32)

    rows = []
    for kind in KINDS:
        kw = _kwargs(kind)
        results = {}
        for backend in BACKENDS:
            sess.run(kind, srcs, backend=backend, **kw)   # warm the jits
            res, secs = timed(sess.run, kind, srcs, backend=backend,
                              repeats=2, **kw)
            results[backend] = res
            rows.append({
                "kind": kind, "backend": backend, "graph": gname,
                "queries": len(srcs),
                "runtime_s": rnd(secs, 4),
                "qps": rnd(len(srcs) / max(secs, 1e-9), 1),
                "visits": res.stats.get("visits", 0),
                "edges_per_q": rnd(float(np.mean(res.edges_processed)), 1),
            })
            if kind == "cc":
                # rows must stay honest: every backend's labels are the
                # union-find labels, bitwise, on every lane
                assert all(np.array_equal(results[backend].values[q], want_cc)
                           for q in range(len(srcs))), backend
        # kreach/rw: deterministic cross-backend bit-parity
        a, b = (results[bk].values for bk in BACKENDS)
        assert np.array_equal(a, b), kind
    mirror_engine_rows("bench_kinds", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick=True), COLUMNS))
