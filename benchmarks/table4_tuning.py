"""Table 4 analogue: scheduling policies and yield-threshold sweeps (BC/Us).

A: scheduling policy {random, max_ops, fifo, priority} with yielding on.
B: yield heuristic 1 sweep {0.25μ, 0.5μ, μ, 2μ, 4μ, ∞}.
C: yield heuristic 2 sweep {0.25Δ, 0.5Δ, Δ, 2Δ, 4Δ, ∞}.
D: planner block-size autotune (the knob every sweep above sits on top of).

All sweeps run through the session front door via the reusable measurement
unit ``repro.fpp.planner.measure_run`` — the same code path the planner's
``tune=True`` uses, so what this table measures is what the system ships.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import rnd, sources_for
from repro.core.yielding import YieldConfig, default_delta
from repro.fpp import FPPSession
from repro.fpp.planner import autotune_block_size, measure_run
from repro.graphs.generators import build_suite


def _record(rows, sweep, setting, row):
    rows.append({"sweep": sweep, "setting": setting,
                 "runtime_s": rnd(row["runtime_s"]),
                 "visits": row["visits"],
                 "edges_per_q": rnd(row["edges_per_q"], 0)})


def run(quick: bool = True):
    g = build_suite("road-ca" if quick else "road-us")
    nq = 16 if quick else 100
    srcs = sources_for(g, nq, seed=7)
    sess = FPPSession(g).plan(num_queries=nq, block_size=256, method="bfs")
    bg, _ = sess.prepared()
    wmax = float(np.nanmax(np.where(np.isfinite(bg.blocks), bg.blocks,
                                    np.nan)))
    delta = default_delta(wmax)
    rows = []
    # A: policies (yielding enabled, Δ)
    for policy in ("random", "max_ops", "fifo", "priority"):
        row = measure_run(sess, "sssp", srcs, schedule=policy,
                          yield_config=YieldConfig(delta=delta))
        _record(rows, "A:policy", policy, row)
    # B: heuristic 1 (edge budget)
    for mf in (0.25, 0.5, 1.0, 2.0, 4.0, None):
        label = f"{mf}mu" if mf else "no_yield"
        row = measure_run(sess, "sssp", srcs,
                          yield_config=YieldConfig(mu_factor=mf))
        _record(rows, "B:mu", label, row)
    # C: heuristic 2 (Δ window)
    for df in (0.25, 0.5, 1.0, 2.0, 4.0, None):
        label = f"{df}delta" if df else "no_yield"
        yc = YieldConfig(delta=None if df is None else df * delta)
        row = measure_run(sess, "sssp", srcs, yield_config=yc)
        _record(rows, "C:delta", label, row)
    # D: block-size autotune (planner objective: modeled traffic)
    best, tune_rows = autotune_block_size(
        sess, "sssp", srcs[: min(8, len(srcs))], sess.mem,
        candidates=(128, 256, 512) if quick else (64, 128, 256, 512, 1024))
    for row in tune_rows:
        label = f"B={row['block_size']}" + \
            (" <- picked" if row["block_size"] == best else "")
        _record(rows, "D:block", label, row)
    return rows


COLUMNS = ["sweep", "setting", "runtime_s", "visits", "edges_per_q"]
