"""Table 4 analogue: scheduling policies and yield-threshold sweeps (BC/Us).

A: scheduling policy {random, max_ops, fifo, priority} with yielding on.
B: yield heuristic 1 sweep {0.25μ, 0.5μ, μ, 2μ, 4μ, ∞}.
C: yield heuristic 2 sweep {0.25Δ, 0.5Δ, Δ, 2Δ, 4Δ, ∞}.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import rnd, sources_for, timed
from repro.core.queries import prepare, run_sssp
from repro.core.yielding import YieldConfig, default_delta
from repro.graphs.generators import build_suite


def run(quick: bool = True):
    g = build_suite("road-ca" if quick else "road-us")
    nq = 16 if quick else 100
    srcs = sources_for(g, nq, seed=7)
    bg, perm = prepare(g, 256)
    wmax = float(np.nanmax(np.where(np.isfinite(bg.blocks), bg.blocks,
                                    np.nan)))
    delta = default_delta(wmax)
    rows = []
    # A: policies (yielding enabled, Δ)
    for policy in ("random", "max_ops", "fifo", "priority"):
        yc = YieldConfig(delta=delta)
        res, secs = timed(run_sssp, bg, perm[srcs], yield_config=yc,
                          schedule=policy)
        rows.append({"sweep": "A:policy", "setting": policy,
                     "runtime_s": rnd(secs), "visits": res.stats.visits,
                     "edges_per_q": rnd(res.edges_processed.mean(), 0)})
    # B: heuristic 1 (edge budget)
    for mf in (0.25, 0.5, 1.0, 2.0, 4.0, None):
        yc = YieldConfig(mu_factor=mf)
        label = f"{mf}mu" if mf else "no_yield"
        res, secs = timed(run_sssp, bg, perm[srcs], yield_config=yc)
        rows.append({"sweep": "B:mu", "setting": label,
                     "runtime_s": rnd(secs), "visits": res.stats.visits,
                     "edges_per_q": rnd(res.edges_processed.mean(), 0)})
    # C: heuristic 2 (Δ window)
    for df in (0.25, 0.5, 1.0, 2.0, 4.0, None):
        yc = YieldConfig(delta=None if df is None else df * delta)
        label = f"{df}delta" if df else "no_yield"
        res, secs = timed(run_sssp, bg, perm[srcs], yield_config=yc)
        rows.append({"sweep": "C:delta", "setting": label,
                     "runtime_s": rnd(secs), "visits": res.stats.visits,
                     "edges_per_q": rnd(res.edges_processed.mean(), 0)})
    return rows


COLUMNS = ["sweep", "setting", "runtime_s", "visits", "edges_per_q"]
