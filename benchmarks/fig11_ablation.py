"""Figure 11 analogue: cumulative effect of individual techniques.

The paper stacks +buffer, +consolidation, +priority, +yielding onto a
Ligra baseline.  Here:

  baseline        global-frontier engine (Ligra t=1 analogue)
  +buffer         buffered partition execution, FIFO schedule, no yielding
                  (consolidation is structural in the dense buffer: the
                  min-write IS the paper's query-centric consolidation, so
                  it cannot be disabled — noted in DESIGN.md §2)
  +priority       priority-based partition scheduling
  +yield          Δ-window + edge-budget yielding (full ForkGraph)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import rnd, sources_for, timed
from repro.core.baselines import global_minplus
from repro.core.queries import prepare, run_sssp
from repro.core.yielding import NO_YIELD, YieldConfig, default_delta
from repro.graphs.generators import build_suite


def run(quick: bool = True):
    rows = []
    graphs = ["road-ca"] if quick else ["road-ca", "road-us", "social-lj"]
    nq = 16 if quick else 64
    for gname in graphs:
        g = build_suite(gname)
        srcs = sources_for(g, nq, seed=6)
        bg, perm = prepare(g, 256)
        wmax = float(np.nanmax(np.where(np.isfinite(bg.blocks),
                                        bg.blocks, np.nan)))
        base, bsecs = timed(global_minplus, bg, perm[srcs])
        variants = [
            ("+buffer(fifo,noyield)",
             dict(schedule="fifo", yield_config=NO_YIELD)),
            ("+priority",
             dict(schedule="priority", yield_config=NO_YIELD)),
            ("+yield(full)",
             dict(schedule="priority",
                  yield_config=YieldConfig(mu_factor=2.0,
                                           delta=default_delta(wmax)))),
        ]
        rows.append({"graph": gname, "variant": "baseline(global)",
                     "runtime_s": rnd(bsecs),
                     "edges_per_q": rnd(base.edges_processed.mean(), 0),
                     "speedup_vs_base": 1.0})
        for name, kw in variants:
            res, secs = timed(run_sssp, bg, perm[srcs], **kw)
            rows.append({
                "graph": gname, "variant": name, "runtime_s": rnd(secs),
                "edges_per_q": rnd(res.edges_processed.mean(), 0),
                "speedup_vs_base": rnd(bsecs / max(secs, 1e-9), 2)})
    return rows


COLUMNS = ["graph", "variant", "runtime_s", "edges_per_q",
           "speedup_vs_base"]
