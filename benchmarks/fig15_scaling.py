"""Figure 15 analogue: throughput scaling with the number of FPP queries.

The paper's finding: throughput grows with more queries (the buffered
execution amortizes partition loads over more queries) — PPR/RW scale
best, SSSP/BFS hold steady.  The distributed rows run the SAME queries
through the shard_map pod runtime (one visit algebra, two runtimes), so the
single-device engine and the superstep program scale side by side.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import rnd, sources_for, timed
from repro.core.queries import prepare, run_ppr, run_rw, run_sssp
from repro.graphs.generators import build_suite


def run(quick: bool = True, graph: str = "social-lj"):
    from repro.core.distributed import run_distributed_ppr
    from repro.fpp.backends import default_mesh

    # any suite name works, including the committed ingested fixture
    # ("snap-tiny") — the scaling sweep is graph-agnostic
    g = build_suite(graph)
    bg, perm = prepare(g, 256)
    counts = (8, 32, 128) if quick else (8, 32, 128, 512)
    mesh = default_mesh()
    ndev = int(np.prod(list(mesh.shape.values())))
    rows = []
    for nq in counts:
        srcs = sources_for(g, nq, seed=8)
        res, secs = timed(run_sssp, bg, perm[srcs])
        rows.append({"query": "SSSP", "n_queries": nq,
                     "runtime_s": rnd(secs),
                     "qps": rnd(nq / max(secs, 1e-9), 1),
                     "visits": res.stats.visits})
        res, secs = timed(run_ppr, bg, perm[srcs], eps=1e-3)
        rows.append({"query": "PPR", "n_queries": nq,
                     "runtime_s": rnd(secs),
                     "qps": rnd(nq / max(secs, 1e-9), 1),
                     "visits": res.stats.visits})
        dres, secs = timed(run_distributed_ppr, bg, perm[srcs], mesh,
                           eps=1e-3)
        rows.append({"query": f"PPR-dist({ndev}dev)", "n_queries": nq,
                     "runtime_s": rnd(secs),
                     "qps": rnd(nq / max(secs, 1e-9), 1),
                     "visits": dres.supersteps})
        wres, secs = timed(run_rw, bg, perm[srcs], length=16)
        rows.append({"query": "RW", "n_queries": nq,
                     "runtime_s": rnd(secs),
                     "qps": rnd(nq / max(secs, 1e-9), 1),
                     "visits": getattr(wres, "visits", "")})
    return rows


COLUMNS = ["query", "n_queries", "runtime_s", "qps", "visits"]
