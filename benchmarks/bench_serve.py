"""Serving microbench: offered-load sweep through the GraphServer pump.

ISSUE 5's serving acceptance: a mixed sssp+ppr, two-tenant, two-graph
workload served end-to-end with per-request stats.  This module offers that
workload at increasing arrival rates (requests per serving round) and
records the latency distribution and throughput at each point — the
saturation curve a capacity planner reads (queue wait dominating p99 is
the signal the autoscaling hint consumes; here capacity is held fixed so
the sweep isolates load, not resize recompiles).

The hot tenant offers 3x the cold tenant's load at equal weight, so the
recorded per-tenant p99 queue waits also document the weighted-fair
admission bound under pressure (tests/test_graph_server.py asserts it; the
bench only reports it).

Rows land in results/bench/bench_serve.json and are mirrored into the
``bench_serve`` section of the top-level ``BENCH_engine.json`` (CI uploads
both in the bench-results artifact), next to the dispatch trajectory.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mirror_engine_rows, rnd, sources_for
from repro.fpp import FPPSession
from repro.graphs.generators import grid2d, rmat
from repro.serve import GraphRequest, GraphServer

COLUMNS = ["load_qpr", "requests", "ok", "expired", "rounds", "runtime_s",
           "qps", "p50_ms", "p99_ms", "hot_wait_p99", "cold_wait_p99",
           "syncs_per_q"]

KINDS = ("sssp", "ppr")


def _workload(road, social, load, rounds_of_arrivals, seed):
    """``rounds_of_arrivals`` batches of ``load`` requests: mixed kinds,
    two graphs, hot tenant at 3x the cold tenant's offered load."""
    rng = np.random.default_rng(seed)
    road_src = sources_for(road, road.n, seed=seed)
    soc_src = sources_for(social, social.n, seed=seed + 1)
    for _ in range(rounds_of_arrivals):
        batch = []
        for i in range(load):
            kind = KINDS[int(rng.integers(len(KINDS)))]
            graph = "road" if rng.random() < 0.5 else "social"
            src = rng.choice(road_src if graph == "road" else soc_src)
            batch.append(GraphRequest(
                kind=kind, source=int(src), graph=graph,
                tenant="hot" if i % 4 else "cold"))
        yield batch


def run(quick: bool = True):
    if quick:
        road, social = grid2d(16, 16, seed=0), rmat(7, 4, seed=1)
        B, cap, loads, arrival_rounds = 32, 4, (1, 4, 8), 6
        eps_note = 1e-3
    else:
        road, social = grid2d(48, 48, seed=0), rmat(10, 8, seed=1)
        B, cap, loads, arrival_rounds = 128, 8, (2, 8, 32), 10
        eps_note = 1e-4

    # shared sessions across sweep points: the plan (and the partitioning
    # cache) is per-graph state, not per-load state
    sess = {"road": FPPSession(road).plan(num_queries=cap, block_size=B),
            "social": FPPSession(social).plan(num_queries=cap, block_size=B)}

    rows = []
    for load in loads:
        server = GraphServer(capacity=cap, k_visits=16, autoscaler=None,
                             eps=eps_note, seed=0)
        server.register_graph("road", sess["road"])
        server.register_graph("social", sess["social"])
        server.register_tenant("hot", 1.0)
        server.register_tenant("cold", 1.0)
        arrivals = _workload(road, social, load, arrival_rounds, seed=load)
        t0 = time.perf_counter()
        out = server.serve_forever(arrivals)
        secs = time.perf_counter() - t0

        ok = [r for r in out.values() if r.status == "ok"]
        lat = np.array([r.stats["latency_s"] for r in ok]) * 1e3
        waits = {t: np.array([r.stats["queue_wait_rounds"]
                              for r in ok if r.tenant == t] or [0.0])
                 for t in ("hot", "cold")}
        rows.append({
            "load_qpr": load,
            "requests": len(out),
            "ok": len(ok),
            "expired": len(out) - len(ok),
            "rounds": server.rounds,
            "runtime_s": rnd(secs, 3),
            "qps": rnd(len(ok) / max(secs, 1e-9), 1),
            "p50_ms": rnd(np.percentile(lat, 50), 2),
            "p99_ms": rnd(np.percentile(lat, 99), 2),
            "hot_wait_p99": rnd(np.percentile(waits["hot"], 99), 1),
            "cold_wait_p99": rnd(np.percentile(waits["cold"], 99), 1),
            "syncs_per_q": rnd(float(np.mean(
                [r.stats["host_syncs"] for r in ok])), 1),
            "eps": eps_note,
        })
        assert len(out) == load * arrival_rounds, \
            "server must answer every offered request"
    mirror_engine_rows("bench_serve", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick=True), COLUMNS))
