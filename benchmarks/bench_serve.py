"""Serving microbench: open-loop SLO sweep through the continuous engine.

Closed-loop serving benches (offer a batch, wait, offer the next) hide
queueing: the driver slows down with the server, so latency looks flat
right up to collapse.  This module drives the continuous-batching
:class:`GraphServer` **open-loop** instead — request arrival times are
drawn from a Poisson process at a fixed offered rate and submitted on
schedule regardless of how the server is doing, the standard
load-testing discipline for SLO curves.  Latency is measured from the
*scheduled* arrival (driver lag counts against the server), and each
sweep point reports throughput, p50/p99, and SLO attainment — the
fraction of all offered requests (expired ones count as misses) answered
under each latency target.

The workload is the serving shape the paper motivates: mixed sssp+ppr
across two graphs, a hot tenant at 3x the cold tenant's offered load,
and sources drawn from a Zipf distribution — the skew that makes both
reuse tiers earn their keep.  Every sweep point runs twice, ``cache=off``
(admission dedup only, the PR 8 baseline) and ``cache=on`` (dedup plus
the completed-answer result cache), with per-row hit counts/rates — the
headline is served QPS and SLO attainment at the highest offered load,
dedup+cache vs dedup-only.

What is deliberately *outside* the timed window: megastep compiles.  The
pools' executables are prewarmed through the shared
:class:`MegastepCache` exactly as a production ``register_graph`` would,
so the sweep measures serving, not tracing; capacity is held fixed
(``autoscaler=None``) so the sweep isolates load.

Rows land in results/bench/bench_serve.json and are mirrored into the
``bench_serve`` section of the top-level ``BENCH_engine.json`` (CI
uploads both in the bench-results artifact), next to the dispatch
trajectory.  The ``bench_notes`` section records the ppr fused-dispatch
regression that ``planner.auto_fused`` encodes.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mirror_engine_rows, rnd, sources_for
from repro.fpp import FPPSession
from repro.graphs.generators import grid2d, rmat
from repro.serve import GraphRequest, GraphServer, MegastepCache

COLUMNS = ["offered_qps", "cache", "requests", "ok", "expired", "coalesced",
           "cached", "hit_rate", "runtime_s", "qps", "p50_ms", "p99_ms",
           "slo_100ms", "slo_250ms", "slo_1s", "syncs_per_q"]

KINDS = ("sssp", "ppr")
SLOS_MS = (100.0, 250.0, 1000.0)

#: committed context for the dispatch-mode auto-select (fpp/planner.py)
NOTES = [{
    "id": "ppr-fused-dispatch-regression",
    "text": ("bench_dispatch K=64: fused ppr runs at ~2500 visits/s vs "
             "~3540 through the XLA megastep (K=8: ~2535 vs ~3088) — the "
             "push algebra's residual+value two-plane update defeats the "
             "fused kernel's single-pass locality, while minplus keeps "
             "the win (sssp 6809 vs 6185 at K=64).  planner.auto_fused "
             "therefore dispatches ppr through the XLA megastep and "
             "sssp/bfs through the fused body; GraphServer(fused='auto') "
             "and plan(fused='auto') inherit this per-kind choice."),
}]


def _zipf_pick(rng, srcs, s=1.1):
    """One source, Zipf-skewed over the candidate ranking."""
    ranks = np.arange(1, len(srcs) + 1, dtype=np.float64)
    p = ranks ** -s
    return int(rng.choice(srcs, p=p / p.sum()))


def _schedule(road_src, soc_src, offered_qps, n_requests, seed,
              deadline_s):
    """Poisson arrival offsets + their requests: mixed kinds/graphs, hot
    tenant at 3x cold, Zipf-skewed sources."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / offered_qps, size=n_requests)
    at = np.cumsum(gaps)
    out = []
    for i in range(n_requests):
        kind = KINDS[int(rng.integers(len(KINDS)))]
        graph = "road" if rng.random() < 0.5 else "social"
        src = _zipf_pick(rng, road_src if graph == "road" else soc_src)
        out.append((float(at[i]), GraphRequest(
            kind=kind, source=src, graph=graph,
            tenant="hot" if i % 4 else "cold", deadline_s=deadline_s)))
    return out


def _drive(server, schedule):
    """Submit each request at its scheduled offset; returns (t0, lag[rid])
    where lag is how late the driver itself submitted (charged to the
    measured latency, as an open loop must)."""
    t0 = time.perf_counter()
    lag = {}
    for dt, req in schedule:
        delay = t0 + dt - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        rid = server.submit(req)
        lag[rid] = time.perf_counter() - (t0 + dt)
    return t0, lag


def run(quick: bool = True):
    if quick:
        road, social = grid2d(16, 16, seed=0), rmat(7, 4, seed=1)
        B, cap, k_visits = 32, 8, 16
        offered = (8, 32, 96, 192)
        n_for = lambda q: int(min(384, max(48, 2 * q)))
        eps, deadline_s = 1e-3, 5.0
    else:
        road, social = grid2d(48, 48, seed=0), rmat(10, 8, seed=1)
        B, cap, k_visits = 128, 16, 32
        offered = (8, 64, 256, 512)
        n_for = lambda q: int(min(1024, max(64, 3 * q)))
        eps, deadline_s = 1e-4, 10.0

    # shared across sweep points: sessions (plan + partition cache) and
    # the megastep cache — per-graph state, not per-load state
    sess = {"road": FPPSession(road).plan(num_queries=cap, block_size=B),
            "social": FPPSession(social).plan(num_queries=cap, block_size=B)}
    cache = MegastepCache()
    road_src = sources_for(road, 64, seed=11)
    soc_src = sources_for(social, 64, seed=12)

    def make_server(use_cache):
        server = GraphServer(capacity=cap, k_visits=k_visits,
                             autoscaler=None, eps=eps, seed=0, cache=cache,
                             result_cache=use_cache)
        server.register_graph("road", sess["road"])
        server.register_graph("social", sess["social"])
        server.register_tenant("hot", 1.0)
        server.register_tenant("cold", 1.0)
        return server

    # prewarm outside every timed window: exactly what register_graph's
    # prewarm= does in production, made synchronous so the first sweep
    # point is as warm as the last
    warm = make_server(False)
    for graph in ("road", "social"):
        for kind in KINDS:
            warm._warm_executable(warm._pool(graph, kind), cap)

    rows = []
    for qps_target in offered:
        # the cache axis: off = admission dedup only (the prior baseline),
        # on = dedup plus the completed-answer result cache.  A fresh
        # server per arm — the result cache must be cold at each arm's
        # warmup so the arms differ only in the tier under test.
        for use_cache in (False, True):
            server = make_server(use_cache).start()
            # untimed warmup: two requests per pool flush the executors'
            # small per-instance jits (lane injection / pending probes) so
            # the timed window measures steady-state serving, not
            # first-touch tracing (with the cache on it also seeds the two
            # hottest Zipf ranks, as any warm production server would be)
            server.submit_all(
                GraphRequest(kind=kind, source=int(srcs[i]), graph=graph)
                for graph, srcs in (("road", road_src), ("social", soc_src))
                for kind in KINDS for i in (0, 1))
            server.wait_drained(timeout=60.0)

            schedule = _schedule(road_src, soc_src, qps_target,
                                 n_for(qps_target), seed=qps_target,
                                 deadline_s=deadline_s)
            t0, lag = _drive(server, schedule)
            server.wait_drained(timeout=120.0)
            secs = time.perf_counter() - t0
            all_resp = server.shutdown()
            out = {rid: all_resp[rid] for rid in lag}  # timed requests only

            ok = [r for r in out.values() if r.status == "ok"]
            cached = sum(bool(r.stats.get("cached")) for r in ok)
            # latency from the *scheduled* arrival: server-side latency
            # plus however late the open-loop driver got the submit in
            lat = np.array([(r.stats["latency_s"] + lag.get(r.rid, 0.0))
                            * 1e3 for r in ok])
            row = {
                "offered_qps": qps_target,
                "cache": "on" if use_cache else "off",
                "requests": len(out),
                "ok": len(ok),
                "expired": len(out) - len(ok),
                "coalesced": sum(bool(r.stats.get("coalesced"))
                                 for r in ok),
                "cached": cached,
                "hit_rate": rnd(cached / max(len(ok), 1), 3),
                "runtime_s": rnd(secs, 3),
                "qps": rnd(len(ok) / max(secs, 1e-9), 1),
                "p50_ms": rnd(np.percentile(lat, 50), 2),
                "p99_ms": rnd(np.percentile(lat, 99), 2),
                "syncs_per_q": rnd(float(np.mean(
                    [r.stats["host_syncs"] for r in ok])), 1),
                "eps": eps,
            }
            for slo in SLOS_MS:
                # attainment over ALL offered requests: expired = missed
                row[f"slo_{int(slo) // 1000}s" if slo >= 1000
                    else f"slo_{int(slo)}ms"] = rnd(
                        float((lat <= slo).sum()) / max(len(out), 1), 3)
            rows.append(row)
            assert len(out) == len(schedule), \
                "server must answer every offered request"
    mirror_engine_rows("bench_serve", rows)
    mirror_engine_rows("bench_notes", NOTES + [_cache_note(rows)])
    return rows


def _cache_note(rows):
    """The headline, computed from this run's measurements: served QPS and
    1s-SLO attainment at the highest offered load, dedup+cache vs
    dedup-only, plus the measured hit rate."""
    top = max(r["offered_qps"] for r in rows)
    off = next(r for r in rows if r["offered_qps"] == top
               and r["cache"] == "off")
    on = next(r for r in rows if r["offered_qps"] == top
              and r["cache"] == "on")
    return {
        "id": "result-cache-serving-win",
        "text": (f"bench_serve @ {top} offered QPS (Zipf-1.1 sources, "
                 f"dedup on in both arms): result cache on serves "
                 f"{on['qps']} QPS vs {off['qps']} dedup-only "
                 f"({on['cached']}/{on['ok']} answers from cache, hit rate "
                 f"{on['hit_rate']}); 1s-SLO attainment {on['slo_1s']} vs "
                 f"{off['slo_1s']}, p99 {on['p99_ms']}ms vs "
                 f"{off['p99_ms']}ms.  Hits bill zero visits/edges and "
                 f"never touch a lane, so the win grows with source skew; "
                 f"GraphServer(result_cache=False) restores the baseline."),
    }


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick=True), COLUMNS))
