"""Figure 9 / Table 3 analogue: overall application performance.

BC / LL / NCP on road + social graphs: ForkGraph vs the global-frontier
baseline (the Ligra-like t=1 scheme).  Both sides go through one
``FPPSession`` — the backend is the only thing that changes — so the
comparison is guaranteed to run identical query sets on identical
partitions.  The paper reports normalized time; we report wall seconds,
speedup, and the modeled-traffic reduction.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import rnd, sources_for, timed
from repro.fpp import FPPSession
from repro.graphs.generators import build_suite


def run(quick: bool = True, graphs=None):
    rows = []
    # "snap-tiny" is the committed SNAP-style fixture: the one graph in the
    # sweep that went through graphs.io.load_edge_list (id compaction,
    # text weights) instead of a generator — CI runs the quick sweep, so
    # every push exercises BC/LL/NCP on really-ingested data
    if graphs is None:
        graphs = ["snap-tiny", "road-ca", "social-lj"] if quick else \
            ["snap-tiny", "road-ca", "road-us", "social-lj", "social-or",
             "web-wk"]
    n_bc = 8 if quick else 32
    n_ll = 16 if quick else 64
    n_ncp = 8 if quick else 32
    for gname in graphs:
        g = build_suite(gname)
        sess = FPPSession(g).plan(num_queries=max(n_bc, n_ll, n_ncp),
                                  block_size=256, method="bfs")
        # --- BC (BFS family) ---
        srcs = sources_for(g, n_bc, seed=2)
        (bc, res), secs = timed(sess.bc, srcs)
        base, bsecs = timed(sess.run, "bfs", srcs, backend="baselines")
        rows.append(_row("BC", gname, len(srcs), secs, res, bsecs, base))
        # --- LL (SSSP family) ---
        lm = sources_for(g, n_ll, seed=3)
        (labels, res), secs = timed(sess.landmarks, lm)
        base, bsecs = timed(sess.run, "sssp", lm, backend="baselines")
        # exactness vs the synchronous baseline (same id space both sides)
        err = float(np.nanmax(np.abs(
            np.where(np.isfinite(res.values), res.values, 0)
            - np.where(np.isfinite(base.values), base.values, 0))))
        r = _row("LL", gname, len(lm), secs, res, bsecs, base)
        r["max_err"] = rnd(err, 6)
        rows.append(r)
        # --- NCP (PPR family) ---
        seeds = sources_for(g, n_ncp, seed=4)
        (profile, res), secs = timed(sess.ncp, seeds, eps=1e-3)
        base, bsecs = timed(sess.run, "ppr", seeds, backend="baselines",
                            eps=1e-3)
        rows.append(_row("NCP", gname, len(seeds), secs, res, bsecs, base))
    return rows


def _row(app, gname, nq, secs, res, bsecs, base):
    fg_bytes = res.stats.get("modeled_bytes", 0.0)
    base_bytes = base.stats.get("modeled_bytes", 0.0)
    return {
        "app": app, "graph": gname, "queries": nq,
        "forkgraph_s": rnd(secs), "baseline_s": rnd(bsecs),
        "speedup": rnd(bsecs / max(secs, 1e-9), 2),
        "fg_traffic_GB": rnd(fg_bytes / 1e9, 4),
        "base_traffic_GB": rnd(base_bytes / 1e9, 4),
        "traffic_red_x": rnd(base_bytes / max(fg_bytes, 1e-9), 1),
    }


COLUMNS = ["app", "graph", "queries", "forkgraph_s", "baseline_s",
           "speedup", "fg_traffic_GB", "base_traffic_GB", "traffic_red_x"]
