"""Figure 16 analogue: effect of partition (block) size.

The paper finds LLC-sized partitions optimal: larger thrashes the cache,
smaller multiplies scheduling overhead.  The TPU analogue sweeps the VMEM
block size B via the planner's measurement unit and marks the size the
planner's autotune objective (modeled traffic — the U-shape driver:
visits x block bytes) would pick.
"""
from __future__ import annotations

from benchmarks.common import rnd, sources_for
from repro.core.partition import edge_cut_fraction
from repro.fpp import FPPSession
from repro.fpp.planner import autotune_block_size
from repro.graphs.generators import build_suite


def run(quick: bool = True):
    g = build_suite("road-ca")
    nq = 16 if quick else 64
    srcs = sources_for(g, nq, seed=9)
    sess = FPPSession(g).plan(num_queries=nq, method="bfs")
    sizes = (128, 256, 512) if quick else (64, 128, 256, 512, 1024)
    best, tune_rows = autotune_block_size(sess, "sssp", srcs, sess.mem,
                                          candidates=sizes)
    rows = []
    for row in tune_rows:
        bs = row["block_size"]
        bg, _ = sess.prepared(block_size=bs)
        rows.append({
            "block_size": bs, "partitions": bg.num_parts,
            "edge_cut": rnd(edge_cut_fraction(bg), 3),
            "runtime_s": rnd(row["runtime_s"]),
            "visits": row["visits"],
            "traffic_GB": rnd(row["traffic_bytes"] / 1e9, 4),
            "edges_per_q": rnd(row["edges_per_q"], 0),
            "picked": "yes" if bs == best else ""})
    return rows


COLUMNS = ["block_size", "partitions", "edge_cut", "runtime_s", "visits",
           "traffic_GB", "edges_per_q", "picked"]
