"""Figure 16 analogue: effect of partition (block) size.

The paper finds LLC-sized partitions optimal: larger thrashes the cache,
smaller multiplies scheduling overhead.  The TPU analogue sweeps the VMEM
block size B; the modeled-traffic curve shows the same U-shape driver
(visits x block bytes).
"""
from __future__ import annotations

from benchmarks.common import rnd, sources_for, timed
from repro.core.partition import edge_cut_fraction
from repro.core.queries import prepare, run_sssp
from repro.graphs.generators import build_suite


def run(quick: bool = True):
    g = build_suite("road-ca")
    nq = 16 if quick else 64
    srcs = sources_for(g, nq, seed=9)
    rows = []
    sizes = (128, 256, 512) if quick else (64, 128, 256, 512, 1024)
    for bs in sizes:
        bg, perm = prepare(g, bs)
        res, secs = timed(run_sssp, bg, perm[srcs])
        rows.append({
            "block_size": bs, "partitions": bg.num_parts,
            "edge_cut": rnd(edge_cut_fraction(bg), 3),
            "runtime_s": rnd(secs), "visits": res.stats.visits,
            "traffic_GB": rnd(res.stats.modeled_bytes / 1e9, 4),
            "edges_per_q": rnd(res.edges_processed.mean(), 0)})
    return rows


COLUMNS = ["block_size", "partitions", "edge_cut", "runtime_s", "visits",
           "traffic_GB", "edges_per_q"]
