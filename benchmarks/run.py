"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick suite
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale-ish
    PYTHONPATH=src python -m benchmarks.run --only table1_profile

Each module's ``run(quick)`` returns rows; results are persisted under
results/bench/<name>.json and summarized here.
"""
from __future__ import annotations

import argparse
import importlib
import time

from benchmarks.common import fmt_table, save_rows

MODULES = [
    "table1_profile",     # Table 1 / Fig 1: parallelism scheme profile
    "fig9_overall",       # Fig 9 / Table 3: BC/LL/NCP overall
    "fig10_work",         # Fig 10: work + traffic vs sequential oracle
    "fig11_ablation",     # Fig 11: cumulative optimizations
    "table4_tuning",      # Table 4: scheduling + yield threshold sweeps
    "fig15_scaling",      # Fig 15: query-count scaling
    "fig16_partition_size",  # Fig 16: partition-size sweep
    "bench_dispatch",     # ISSUE 4: host-loop vs K-visit megastep dispatch
    "bench_serve",        # ISSUE 8: open-loop SLO sweep (continuous batching)
    "bench_kinds",        # ISSUE 10: cc/kreach/rw rows on the ingested fixture
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="module name, or a comma-separated list")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    failures = []
    for name in mods:
        print(f"\n=== {name} {'(full)' if args.full else '(quick)'} ===",
              flush=True)
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(quick=not args.full)
        except Exception as e:                      # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append({"module": name,
                             "error": f"{type(e).__name__}: {e}"})
            # persist the failure where the rows would have gone, so
            # results/bench/ reflects partial runs instead of silence
            save_rows(name, [{"module": name, "status": "failed",
                              "error": f"{type(e).__name__}: {e}"}])
            continue
        path = save_rows(name, rows)
        print(fmt_table(rows, mod.COLUMNS))
        print(f"[{time.perf_counter() - t0:6.1f}s] -> {path}")
    # always write _failures.json (empty on success) so results/bench/
    # reflects THIS run's status rather than a stale earlier failure
    save_rows("_failures", failures)
    if failures:
        raise SystemExit("benchmark failures: "
                         + ", ".join(f["module"] for f in failures))
    print("\nAll benchmarks complete.")


if __name__ == "__main__":
    main()
