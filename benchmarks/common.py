"""Shared benchmark plumbing: timers, graph prep, row records, persistence."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import numpy as np

from repro.core.graph import CSRGraph
from repro.graphs.generators import build_suite

RESULTS_DIR = os.environ.get("BENCH_OUT", os.path.join(
    os.path.dirname(__file__), "..", "results", "bench"))

#: the repo-root perf-trajectory file CI uploads across PRs; sections keyed
#: by benchmark module (bench_dispatch, bench_serve)
ROOT_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_engine.json")


def mirror_engine_rows(section: str, rows: list) -> str:
    """Merge ``rows`` into the top-level BENCH_engine.json under ``section``.

    The file is a dict of benchmark-module -> rows so dispatch and serving
    trajectories coexist; a legacy flat list (pre-serving format) is folded
    in as the ``bench_dispatch`` section.  Other sections are preserved, so
    running one microbench never erases the other's trajectory.
    """
    data = {}
    if os.path.exists(ROOT_BENCH_JSON):
        with open(ROOT_BENCH_JSON) as f:
            cur = json.load(f)
        data = cur if isinstance(cur, dict) else {"bench_dispatch": cur}
    data[section] = rows
    with open(ROOT_BENCH_JSON, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return ROOT_BENCH_JSON


def timed(fn: Callable, *args, repeats: int = 1, **kwargs):
    """Returns (result_of_last, best_seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def sources_for(g: CSRGraph, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # sample from vertices that have outgoing edges
    deg = g.out_degree()
    cand = np.flatnonzero(deg > 0)
    return rng.choice(cand, size=min(n, cand.size), replace=False)


def save_rows(name: str, rows: list):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    return path


def fmt_table(rows: list, cols: list) -> str:
    widths = [max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols]
    out = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    out.append("  ".join("-" * w for w in widths))
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(w)
                             for c, w in zip(cols, widths)))
    return "\n".join(out)


def rnd(x, k=3):
    return float(np.round(float(x), k))
