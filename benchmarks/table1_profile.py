"""Table 1 / Figure 1 analogue: inter- vs intra-query parallelism profile.

The paper profiles 10k PPRs on LiveJournal under three schemes (1 thread;
t=10 intra-query; t=1 inter-query) and shows the t=1 scheme is fastest but
LLC-miss-bound.  Hardware counters don't exist here, so the cache-miss
analogue is the *modeled HBM->VMEM traffic*: blocks streamed x block bytes,
with t=1 counting per-query (uncoordinated) streams and t=10 counting
per-query sequential streams (paper Table 1 columns), vs ForkGraph's
buffered execution (one stream per partition visit shared by all queries).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import rnd, sources_for, timed
from repro.core.baselines import global_push
from repro.core.queries import prepare, run_ppr
from repro.graphs.generators import build_suite


def run(quick: bool = True):
    g = build_suite("social-lj")
    nq = 32 if quick else 128
    srcs = sources_for(g, nq, seed=1)
    bg, perm = prepare(g, block_size=256)
    rows = []

    # ForkGraph buffered execution
    res, secs = timed(run_ppr, bg, perm[srcs], eps=1e-3)
    rows.append({
        "scheme": "forkgraph(buffered)", "queries": nq,
        "runtime_s": rnd(secs), "edges": rnd(res.edges_processed.sum(), 0),
        "modeled_traffic_GB": rnd(res.stats.modeled_bytes / 1e9, 4),
        "visits": res.stats.visits})

    # Global frontier engine: one pass over all queries concurrently
    base, bsecs = timed(global_push, bg, perm[srcs], eps=1e-3)
    rows.append({
        "scheme": "global t=1 (uncoordinated)", "queries": nq,
        "runtime_s": rnd(bsecs), "edges": rnd(base.edges_processed.sum(), 0),
        "modeled_traffic_GB": rnd(base.modeled_bytes / 1e9, 4),
        "visits": base.rounds})
    rows.append({
        "scheme": "global t=10 (shared-lb)", "queries": nq,
        "runtime_s": rnd(bsecs), "edges": rnd(base.edges_processed.sum(), 0),
        "modeled_traffic_GB": rnd(base.modeled_bytes_shared / 1e9, 4),
        "visits": base.rounds})

    fg, un = rows[0]["modeled_traffic_GB"], rows[1]["modeled_traffic_GB"]
    rows.append({"scheme": "traffic_reduction_xN",
                 "queries": nq, "runtime_s": "",
                 "modeled_traffic_GB": rnd(un / max(fg, 1e-12), 1),
                 "edges": "", "visits": ""})
    return rows


COLUMNS = ["scheme", "queries", "runtime_s", "edges",
           "modeled_traffic_GB", "visits"]
