"""Dispatch microbench: per-visit host loop vs the K-visit megastep.

The engine's throughput story (ISSUE 4) is that the scheduler decision is
trivially cheap next to a partition visit, so it belongs on device: the
host-scheduled loop pays one device->host round trip *per visit* (sync
prio/stamp/ops, numpy argmin, dispatch one jitted visit, sync eq back),
the megastep pays one per K visits.  This module measures both — visits/s
and host-syncs-per-run for the host loop and for megastep K in {1, 8, 64},
in both visit-algebra modes — and asserts the O(visits/K) sync bound.

The fused-megastep rows (ISSUE 7) ride the same sweep at K in {8, 64}:
the visit body runs as one Pallas kernel (``fused=True``, dense for both
kinds plus the sparse-frontier mode for sssp), doing identical work —
the visit-count assert pins that — so the row deltas isolate the kernel-
residency effect from the algorithm.

Besides the usual results/bench/bench_dispatch.json row dump, the rows are
mirrored into the ``bench_dispatch`` section of the top-level
``BENCH_engine.json`` (benchmarks/common.mirror_engine_rows) so the
engine-dispatch perf trajectory persists at the repo root across PRs
alongside the serving trajectory (CI uploads both).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import mirror_engine_rows, rnd, sources_for, timed
from repro.core.engine import FPPEngine
from repro.core.partition import partition
from repro.graphs.generators import grid2d, rmat

COLUMNS = ["kind", "dispatch", "K", "visits", "host_syncs", "runtime_s",
           "visits_per_s", "edges_per_q"]

K_SWEEP = (1, 8, 64)


def _row(kind, dispatch, K, res, secs):
    visits = res.stats.visits
    return {
        "kind": kind, "dispatch": dispatch, "K": K,
        "visits": visits, "host_syncs": res.stats.host_syncs,
        "runtime_s": rnd(secs, 4),
        "visits_per_s": rnd(visits / max(secs, 1e-9), 1),
        "edges_per_q": rnd(float(np.mean(res.edges_processed)), 1),
    }


def run(quick: bool = True):
    if quick:
        graphs = {"sssp": grid2d(24, 24, seed=0), "ppr": rmat(8, 6, seed=1)}
        B, Q = 32, 8
    else:
        graphs = {"sssp": grid2d(64, 64, seed=0), "ppr": rmat(12, 8, seed=1)}
        B, Q = 128, 32

    rows = []
    for kind, g in graphs.items():
        mode = "push" if kind == "ppr" else "minplus"
        bg, perm = partition(g, B, method="bfs")
        srcs = perm[sources_for(g, Q)]
        kw = dict(mode=mode, num_queries=len(srcs))
        if kind == "ppr":
            kw["eps"] = 1e-3 if quick else 1e-4

        # --- baseline: the legacy one-sync-per-visit host loop ---
        eng = FPPEngine(bg, k_visits=1, **kw)
        eng.run(srcs, host_loop=True)                   # warm the jit cache
        res, secs = timed(eng.run, srcs, host_loop=True, repeats=2)
        assert res.stats.host_syncs == res.stats.visits, \
            "host loop must sync once per visit"
        rows.append(_row(kind, "host-loop", 0, res, secs))
        base_visits = res.stats.visits

        # --- device-resident scheduling at K in {1, 8, 64} ---
        for K in K_SWEEP:
            eng = FPPEngine(bg, k_visits=K, **kw)
            eng.run(srcs)                               # warm the jit cache
            res, secs = timed(eng.run, srcs, repeats=2)
            # the acceptance bound: O(visits/K) host synchronizations
            # (+1 for the final empty chunk that signals termination)
            assert res.stats.host_syncs <= -(-res.stats.visits // K) + 1, \
                (kind, K, res.stats.host_syncs, res.stats.visits)
            # same work, different dispatch: visit count matches the loop
            # (priority policy is deterministic on both paths)
            assert res.stats.visits == base_visits, (kind, K)
            rows.append(_row(kind, "megastep", K, res, secs))

        # --- fused visit kernel: same megastep, body in one pallas_call ---
        variants = [("fused", {})]
        if mode == "minplus":
            variants.append(("fused-sparse", {"frontier_mode": "sparse"}))
        for K in (8, 64):
            for label, fkw in variants:
                eng = FPPEngine(bg, k_visits=K, fused=True, **fkw, **kw)
                eng.run(srcs)                           # warm the jit cache
                res, secs = timed(eng.run, srcs, repeats=2)
                assert res.stats.host_syncs <= \
                    -(-res.stats.visits // K) + 1, (kind, label, K)
                # bit-parity with the XLA megastep implies identical work
                assert res.stats.visits == base_visits, (kind, label, K)
                rows.append(_row(kind, label, K, res, secs))

    mirror_engine_rows("bench_dispatch", rows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick=True), COLUMNS))
