"""Figure 10 analogue: cache efficiency and work efficiency.

Edges processed per FPP query: ForkGraph vs the global-frontier engine vs
the sequential oracle (Dijkstra / push-PPR edge counts).  The paper's
acceptance band for ForkGraph: 10.4-16.7x sequential on BC/LL and
5.2-9.4x on NCP, while global engines can exceed 129x.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import rnd, sources_for, timed
from repro.core import oracles
from repro.core.baselines import global_minplus, global_push
from repro.core.queries import prepare, run_ppr, run_sssp
from repro.graphs.generators import build_suite


def run(quick: bool = True):
    rows = []
    graphs = ["road-ca", "social-lj"] if quick else \
        ["road-ca", "road-us", "social-lj", "social-or"]
    nq = 8 if quick else 32
    for gname in graphs:
        g = build_suite(gname)
        srcs = sources_for(g, nq, seed=5)
        bg, perm = prepare(g, 256)
        # sequential oracle work
        seq_edges = float(np.mean([oracles.dijkstra(g, int(s))[1]
                                   for s in srcs]))
        res = run_sssp(bg, perm[srcs])
        base = global_minplus(bg, perm[srcs])
        rows.append({
            "app": "LL/SSSP", "graph": gname,
            "seq_edges_per_q": rnd(seq_edges, 0),
            "forkgraph_x_seq": rnd(res.edges_processed.mean()
                                   / max(seq_edges, 1), 1),
            "global_x_seq": rnd(base.edges_processed.mean()
                                / max(seq_edges, 1), 1),
            "fg_traffic_GB": rnd(res.stats.modeled_bytes / 1e9, 4),
            "base_traffic_GB": rnd(base.modeled_bytes / 1e9, 4),
            "traffic_red_x": rnd(base.modeled_bytes
                                 / max(res.stats.modeled_bytes, 1e-9), 1)})
        seq_pedges = float(np.mean([oracles.ppr_push(g, int(s),
                                                     eps=1e-3)[2]
                                    for s in srcs]))
        resp = run_ppr(bg, perm[srcs], eps=1e-3)
        basep = global_push(bg, perm[srcs], eps=1e-3)
        rows.append({
            "app": "NCP/PPR", "graph": gname,
            "seq_edges_per_q": rnd(seq_pedges, 0),
            "forkgraph_x_seq": rnd(resp.edges_processed.mean()
                                   / max(seq_pedges, 1), 1),
            "global_x_seq": rnd(basep.edges_processed.mean()
                                / max(seq_pedges, 1), 1),
            "fg_traffic_GB": rnd(resp.stats.modeled_bytes / 1e9, 4),
            "base_traffic_GB": rnd(basep.modeled_bytes / 1e9, 4),
            "traffic_red_x": rnd(basep.modeled_bytes
                                 / max(resp.stats.modeled_bytes, 1e-9),
                                 1)})
    return rows


COLUMNS = ["app", "graph", "seq_edges_per_q", "forkgraph_x_seq",
           "global_x_seq", "fg_traffic_GB", "base_traffic_GB",
           "traffic_red_x"]
