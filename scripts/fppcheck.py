#!/usr/bin/env python
"""fppcheck — the one CLI over the static-analysis layer (DESIGN.md §7).

    python scripts/fppcheck.py --all                 # every pass family
    python scripts/fppcheck.py --ast --docs          # jax-free families
    python scripts/fppcheck.py --hlo --update-budgets  # refresh baselines
    python scripts/fppcheck.py --all --report out.json

Families: ast, docs, pallas, jaxpr, hlo.  Exit code 1 on any
error-severity finding (budget drift, a bare assert, a callback in a
device loop, ...); allowlisted/warning/info findings never fail.  CI runs
``--all`` under forced host device counts {1, 8} (the distributed budget
rows are keyed ``@d{ndev}``).
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import (FAMILIES, PassContext, Report,  # noqa: E402
                            run_passes)

#: families that need jax (the rest are stdlib-only)
JAX_FAMILIES = ("pallas", "jaxpr", "hlo")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    for fam in FAMILIES:
        ap.add_argument(f"--{fam}", action="store_true",
                        help=f"run the {fam} pass family "
                             f"({', '.join(FAMILIES[fam])})")
    ap.add_argument("--all", action="store_true", help="run every family")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="filter jaxpr/hlo program keys by substring "
                         "(e.g. 'engine/', 'distributed/sssp')")
    ap.add_argument("--update-budgets", action="store_true",
                    help="rewrite analysis/budgets.json from measured "
                         "HLO rows (commit the diff)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="also write the full report as JSON")
    args = ap.parse_args(argv)

    families = [f for f in FAMILIES if args.all or getattr(args, f)]
    if not families:
        ap.error("pick at least one pass family (or --all)")

    ctx = PassContext(root=ROOT, update_budgets=args.update_budgets,
                      only_programs=args.only)
    names = [n for fam in families for n in FAMILIES[fam]]
    report = run_passes(names, ctx)

    report.env = {"argv": sys.argv[1:],
                  "xla_flags": os.environ.get("XLA_FLAGS", "")}
    if any(f in JAX_FAMILIES for f in families):
        import jax
        report.env["backend"] = jax.default_backend()
        report.env["device_count"] = jax.device_count()

    print(report.render())
    if args.report:
        report.write(args.report)
        print(f"fppcheck: report written to {args.report}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
