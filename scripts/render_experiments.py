"""Render EXPERIMENTS.md tables from results/ JSONs.

    PYTHONPATH=src python scripts/render_experiments.py

Prose sections live in this script as templates; tables are generated from
results/dryrun/*.json (+ _baselineA), results/roofline.json, and
results/bench/*.json, so re-running a sweep refreshes the document.
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import base as cfg_base  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.roofline import analyze_record  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load(pattern):
    out = {}
    for p in sorted(glob.glob(os.path.join(ROOT, pattern))):
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def dryrun_table(recs, mesh):
    rows = [
        "| arch | shape | status | peak GB/dev | compile s | "
        "collectives (program, by kind MB) |",
        "|---|---|---|---|---|---|",
    ]
    for arch in cfg_base.list_configs():
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r["status"] == "SKIP":
                rows.append(f"| {arch} | {shape} | SKIP | — | — | "
                            f"{r['reason'][:58]} |")
                continue
            cs = r.get("collective_schedule", {}).get("bytes_by_kind", {})
            css = ", ".join(f"{k.replace('all-', 'a')}:"
                            f"{v / 2 ** 20:.0f}"
                            for k, v in sorted(cs.items()))
            rows.append(
                f"| {arch} | {shape} | {r['status']} | "
                f"{r.get('peak_gb', '?')} | {r.get('compile_s', '?')} | "
                f"{css} |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| useful | roofline % |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in cfg_base.list_configs():
        for shape in SHAPES:
            r = recs.get((arch, shape, "single"))
            if r is None:
                continue
            if r["status"] == "SKIP":
                rows.append(f"| {arch} | {shape} | SKIP (sub-quadratic "
                            "gate) | | | | | |")
                continue
            a = analyze_record(r)
            if not a:
                continue
            rows.append(
                f"| {arch} | {shape} | {a['compute_s']:.4f} | "
                f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
                f"{a['dominant']} | {a['useful_ratio']:.2f} | "
                f"{100 * a['roofline_fraction']:.1f}% |")
    return "\n".join(rows)


def perf_compare_table(before, after, cells):
    rows = [
        "| cell | metric | baseline A | optimized | Δ |",
        "|---|---|---|---|---|",
    ]
    for arch, shape in cells:
        b = before.get((arch, shape, "single"))
        a = after.get((arch, shape, "single"))
        if not (b and a) or "roofline_inputs" not in b \
                or "roofline_inputs" not in a:
            continue
        for metric, key, scale in (
                ("FLOPs/chip", "flops", 1e12),
                ("HBM bytes/chip", "bytes_accessed", 1e12),
                ("collective bytes/chip", "collective_bytes", 1e9)):
            vb = b["roofline_inputs"][key]
            va = a["roofline_inputs"][key]
            unit = "T" if scale == 1e12 else "G"
            rows.append(
                f"| {arch} {shape} | {metric} | {vb / scale:.2f}{unit} | "
                f"{va / scale:.2f}{unit} | "
                f"{100 * (va - vb) / max(vb, 1):+.1f}% |")
        rows.append(f"| {arch} {shape} | peak GB/dev | "
                    f"{b.get('peak_gb')} | {a.get('peak_gb')} | |")
    return "\n".join(rows)


def main():
    after = load("results/dryrun/*.json")
    before = load("results/dryrun_baselineA/*.json")

    n_ok = sum(r["status"].startswith("OK") for r in after.values())
    n_skip = sum(r["status"] == "SKIP" for r in after.values())
    n_fit = sum(r["status"] == "OK" for r in after.values())

    hill_cells = [("mistral-large-123b", "train_4k"),
                  ("falcon-mamba-7b", "train_4k"),
                  ("qwen2-72b", "prefill_32k")]

    tmpl_path = os.path.join(ROOT, "scripts", "experiments_template.md")
    with open(tmpl_path) as f:
        doc = f.read()
    doc = doc.replace("{{DRYRUN_SINGLE}}", dryrun_table(after, "single"))
    doc = doc.replace("{{DRYRUN_MULTI}}", dryrun_table(after, "multi"))
    doc = doc.replace("{{ROOFLINE}}", roofline_table(after))
    doc = doc.replace("{{ROOFLINE_BASELINE}}", roofline_table(before))
    doc = doc.replace("{{PERF_COMPARE}}",
                      perf_compare_table(before, after, hill_cells))
    doc = doc.replace("{{COUNTS}}",
                      f"{n_ok} OK ({n_fit} within 16 GB/dev), "
                      f"{n_skip} SKIP, 0 FAIL of {len(after)} cells")
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(doc)
    print("wrote", out)


if __name__ == "__main__":
    main()
