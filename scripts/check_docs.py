#!/usr/bin/env python
"""CI doc-consistency check: no dangling DESIGN.md § references or stale
README repo-map entries.

The code cites the architecture doc as ``DESIGN.md §N.M`` in docstrings,
and DESIGN.md renumbers sections as the system grows (ISSUE 5 split §4
into §4.1/§4.2) — so every citation is checked against the headings that
actually exist:

  (a) every ``DESIGN.md §N[.M]`` reference in the repo's ``*.py`` files,
      README.md, and CHANGES.md resolves to a real DESIGN.md heading;
  (b) every internal ``§N[.M]`` cross-reference inside DESIGN.md itself
      resolves (references to the *paper's* sections are written
      "paper §N" and are exempt);
  (c) every path named in README's "Repo map" table exists (relative to
      the repo root, or to src/repro/ for bare package entries).

Run from anywhere; no third-party dependencies (CI runs it before the
jax install finishes cooking):

    python scripts/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: a section citation: §N, §N.M (used both with and without the
#: "DESIGN.md " prefix depending on the file being scanned)
SECTION = r"§(\d+(?:\.\d+)*)"
#: directories never scanned for citations
SKIP_DIRS = {".git", "__pycache__", ".github", "results"}


def design_headings() -> set[str]:
    """Section numbers with a real heading in DESIGN.md (## §2, ### §2.1)."""
    text = (ROOT / "DESIGN.md").read_text()
    return set(re.findall(rf"^#{{2,}}\s+{SECTION}", text, re.M))


def iter_source_files():
    for path in sorted(ROOT.rglob("*.py")):
        if not SKIP_DIRS & set(p.name for p in path.parents):
            yield path
    for name in ("README.md", "CHANGES.md"):
        if (ROOT / name).exists():
            yield ROOT / name


def check_design_refs(headings: set[str]) -> list[str]:
    errors = []
    # (a) prefixed references anywhere in the tree
    pat = re.compile(rf"DESIGN\.md\s+{SECTION}")
    for path in iter_source_files():
        text = path.read_text(errors="replace")
        for lineno, line in enumerate(text.splitlines(), 1):
            for ref in pat.findall(line):
                if ref not in headings:
                    errors.append(f"{path.relative_to(ROOT)}:{lineno}: "
                                  f"dangling reference DESIGN.md §{ref}")
    # (b) bare internal cross-references inside DESIGN.md; "paper §N"
    # cites the source paper, not this document (checked over the full
    # text so a citation wrapped across a line break still counts)
    text = (ROOT / "DESIGN.md").read_text()
    for m in re.finditer(SECTION, text):
        pre = text[max(0, m.start() - 10):m.start()]
        if re.search(r"[Pp]aper(?:'s)?[\s-]+$", pre):
            continue
        if m.group(1) not in headings:
            lineno = text.count("\n", 0, m.start()) + 1
            errors.append(f"DESIGN.md:{lineno}: dangling internal "
                          f"cross-reference §{m.group(1)}")
    return errors


def check_repo_map() -> list[str]:
    """Every `path` in README's Repo map table must exist on disk."""
    errors = []
    text = (ROOT / "README.md").read_text()
    m = re.search(r"^## Repo map\n(.*?)(?=^## )", text, re.M | re.S)
    if not m:
        return ["README.md: no '## Repo map' section found"]
    for row in m.group(1).splitlines():
        if not row.startswith("|") or set(row) <= {"|", "-", " "}:
            continue
        first_cell = row.split("|")[1]
        for span in re.findall(r"`([^`]+)`", first_cell):
            if "/" not in span and "." not in span:
                continue
            candidates = (ROOT / span, ROOT / "src" / "repro" / span)
            if not any(p.exists() for p in candidates):
                errors.append(f"README.md repo map: `{span}` does not exist")
    return errors


def main() -> int:
    headings = design_headings()
    if not headings:
        print("check_docs: DESIGN.md has no § headings — parser broken?")
        return 1
    errors = check_design_refs(headings) + check_repo_map()
    if errors:
        print(f"check_docs: {len(errors)} dangling reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"check_docs: OK ({len(headings)} DESIGN.md sections, "
          f"all references resolve, repo map clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
