#!/usr/bin/env python
"""CI doc-consistency check — thin shim over the fppcheck docs pass.

The actual checks live in ``repro.analysis.docs`` (the registered
``docs.refs`` pass, DESIGN.md §7); this script keeps the historical entry
point and exit-code contract so existing CI invocations and docs stay
valid.  Still stdlib-only — ``repro.analysis`` imports no third-party
packages, so this runs before the jax install finishes cooking:

    python scripts/check_docs.py
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import docs  # noqa: E402

if __name__ == "__main__":
    sys.exit(docs.main(ROOT))
