"""Approximate betweenness centrality (paper application BC, §6.1).

BFS-fleet from sampled roots (Eppstein-style approximation; the paper
samples 100 roots) + the Brandes accumulation.

    PYTHONPATH=src python examples/betweenness.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core.applications import betweenness_centrality  # noqa: E402
from repro.graphs.generators import build_suite  # noqa: E402


def main():
    g = build_suite("web-wk")
    rng = np.random.default_rng(3)
    roots = rng.choice(g.n, 16, replace=False)
    bc, res = betweenness_centrality(g, roots)
    top = np.argsort(-bc)[:10]
    print(f"BC on |V|={g.n} with {len(roots)} sampled roots "
          f"({res.stats['visits']} partition visits)")
    print("top-10 central vertices:")
    for v in top:
        print(f"  v={v:6d}  bc={bc[v]:10.2f}")
    assert bc.max() > 0
    print("betweenness OK")


if __name__ == "__main__":
    main()
