"""Multi-tenant graph serving: mixed sssp/ppr traffic through GraphServer.

The serving twin of examples/quickstart.py (DESIGN.md §4.2): two tenants —
one hot, one light — submit a mixed stream of SSSP and PPR requests against
two registered graphs, and the server multiplexes them onto per-(graph,
kind) lane pools with weighted-fair admission at megastep chunk boundaries.
Shown both ways: the continuous engine (start / submit / result / shutdown,
the production path) and the synchronous pump (serve(), the scripting path)
— plus both reuse tiers: a warm repeat of an already-answered source hits
the result cache (cached=True, zero billed work), while twin in-flight
requests on a fresh source coalesce onto one lane (coalesced=True).

    PYTHONPATH=src python examples/serve_graph.py
"""
import numpy as np

from repro.graphs.generators import grid2d, rmat
from repro.serve import GraphRequest, GraphServer


def main():
    road = grid2d(24, 24, seed=0)        # weighted road-like grid
    social = rmat(8, 6, seed=1)          # power-law social-like graph
    rng = np.random.default_rng(0)

    server = GraphServer(capacity=4, k_visits=16)
    server.register_graph("road", road, num_queries=4, block_size=64)
    server.register_graph("social", social, num_queries=4, block_size=64)
    # the hot tenant offers most of the load; equal weights mean fair
    # admission alone keeps the light tenant's queue wait bounded
    server.register_tenant("hot", weight=1.0)
    server.register_tenant("light", weight=1.0)

    road_src = rng.choice(np.flatnonzero(road.out_degree() > 0), 12)
    soc_src = rng.choice(np.flatnonzero(social.out_degree() > 0), 4)
    for s in road_src:
        server.submit(GraphRequest(kind="sssp", source=int(s), graph="road",
                                   tenant="hot"))
    for i, s in enumerate(soc_src):
        server.submit(GraphRequest(kind="ppr", source=int(s), graph="social",
                                   tenant="light",
                                   priority=-1.0 if i == 0 else 0.0))

    out = server.serve()                 # synchronous pump until drained
    ok = [r for r in out.values() if r.status == "ok"]
    assert len(ok) == len(out)
    print(f"served {len(ok)}/{len(out)} requests in {server.rounds} rounds")
    for tenant in ("hot", "light"):
        rs = [r for r in ok if r.tenant == tenant]
        wait = np.array([r.stats["queue_wait_rounds"] for r in rs])
        lat = np.array([r.stats["latency_s"] for r in rs]) * 1e3
        print(f"  {tenant:5s}: {len(rs):2d} ok | queue-wait rounds "
              f"p50/p99 {np.percentile(wait, 50):.0f}/"
              f"{np.percentile(wait, 99):.0f} | latency p50/p99 "
              f"{np.percentile(lat, 50):.1f}/{np.percentile(lat, 99):.1f} ms")
    # per-request accounting is exact: integral edge work, billed host syncs
    r = next(iter(ok))
    print(f"  e.g. rid={r.rid} kind={r.kind} graph={r.graph}: "
          f"visits={r.stats['visits']} edges={r.stats['edges']:.0f} "
          f"host_syncs={r.stats['host_syncs']}")

    # --- the continuous engine: same server, background lanes -----------
    # submit() returns immediately from any thread; result() blocks until
    # the delivery lane hands the response over.
    server.start()

    # a warm repeat: road_src[0] was already answered above, so this hit
    # comes from the result cache — same bits, zero billed work, no lane
    s = int(road_src[0])
    cold = next(r for r in ok if r.kind == "sssp" and r.source == s)
    warm = server.result(server.submit(GraphRequest(
        kind="sssp", source=s, graph="road", tenant="light")), timeout=60)
    np.testing.assert_array_equal(warm.values, cold.values)
    print(f"continuous: rid={warm.rid} cached="
          f"{bool(warm.stats.get('cached'))} visits billed="
          f"{warm.stats['visits']} latency="
          f"{warm.stats['latency_s'] * 1e3:.1f} ms")

    # twin *in-flight* requests on a never-served source instead coalesce
    # onto one lane (the follower's response carries coalesced=True)
    fresh = int(np.setdiff1d(np.flatnonzero(road.out_degree() > 0),
                             road_src)[0])
    r1 = server.submit(GraphRequest(kind="sssp", source=fresh, graph="road",
                                    tenant="hot"))
    r2 = server.submit(GraphRequest(kind="sssp", source=fresh, graph="road",
                                    tenant="light"))
    a, b = server.result(r1, timeout=60), server.result(r2, timeout=60)
    np.testing.assert_array_equal(a.values, b.values)
    print(f"continuous: rid={b.rid} coalesced={bool(b.stats.get('coalesced'))}"
          f" latency={b.stats['latency_s'] * 1e3:.1f} ms")
    st = server.stats()
    print(f"reuse: cache_hits={st['cache_hits']} coalesced={st['coalesced']} "
          f"cache_bytes={st['cache_bytes']}")
    server.shutdown()


if __name__ == "__main__":
    main()
