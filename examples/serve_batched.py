"""Serve a small model with continuously-batched requests (deliverable b).

The decode batch is the serving-side fork-processing pattern: B
independent requests against the shared partitioned KV structure, with
finished slots refilled from the queue (DESIGN.md §4.1).

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-72b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import get_config  # noqa: E402
from repro.models.factory import build_model  # noqa: E402
from repro.serve.engine import ContinuousBatcher, Request  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()   # CPU-sized twin of the arch
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batcher = ContinuousBatcher(model, params, batch_size=args.batch,
                                max_len=64)
    for rid in range(args.requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab,
                                rng.integers(3, 9)).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    out = batcher.run()
    dt = time.perf_counter() - t0
    print(f"{cfg.name} (reduced): served {len(out)} requests / "
          f"{batcher.tokens_out} tokens in {batcher.steps} decode steps, "
          f"{dt:.2f}s")
    for rid in sorted(out)[:4]:
        print(f"  req {rid}: {out[rid]}")
    assert all(len(v) == args.max_new for v in out.values())
    print("serve OK")


if __name__ == "__main__":
    main()
