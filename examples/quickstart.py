"""Quickstart: fork-processing on a graph in five minutes.

Builds a weighted road-like graph, launches a *fork-processing pattern* —
many independent SSSP + PPR queries from random sources — through the
cache-efficient buffered engine (the paper's ForkGraph), and validates
against sequential oracles.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import oracles  # noqa: E402
from repro.core.queries import prepare, run_ppr, run_sssp  # noqa: E402
from repro.graphs.generators import grid2d  # noqa: E402


def main():
    # 1. a weighted graph (64x64 road grid, ~4k vertices)
    g = grid2d(64, 64, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}")

    # 2. partition into VMEM-sized blocks (the paper's LLC-sized
    #    partitions) — BFS clustering keeps the edge cut low
    bg, perm = prepare(g, block_size=256)
    print(f"partitions: {bg.num_parts} x {bg.block_size} vertices")

    # 3. fork 16 independent SSSPs (one FPP)
    rng = np.random.default_rng(0)
    sources = rng.choice(g.n, 16, replace=False)
    res = run_sssp(bg, perm[sources])
    print(f"SSSP fleet: {res.stats.visits} partition visits, "
          f"{res.edges_processed.mean():.0f} edges/query, "
          f"{res.stats.modeled_bytes / 1e6:.1f} MB modeled traffic")

    # 4. exactness vs Dijkstra
    for qi in (0, 7, 15):
        want, _ = oracles.dijkstra(g, int(sources[qi]))
        got = res.values[qi][perm]
        assert np.allclose(np.where(np.isfinite(got), got, -1),
                           np.where(np.isfinite(want), want, -1)), qi
    print("SSSP results match Dijkstra exactly")

    # 5. fork 16 PPRs (the NCP workload)
    resp = run_ppr(bg, perm[sources], eps=1e-4)
    p0 = resp.values[0][perm]
    want_p, want_r, _ = oracles.ppr_push(g, int(sources[0]), eps=1e-4)
    print(f"PPR fleet: {resp.stats.visits} visits; "
          f"query0 |support|={np.sum(p0 > 0)}, "
          f"max|p - oracle| = {np.max(np.abs(p0 - want_p)):.2e} "
          "(both are eps-approximations)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
