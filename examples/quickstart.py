"""Quickstart: fork-processing on a graph in five minutes.

Builds a weighted road-like graph and runs a *fork-processing pattern* —
many independent SSSP + PPR queries from random sources — through the
unified session front door (``FPPSession``: plan → execute → stream,
DESIGN.md §3), validating against sequential oracles.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import oracles  # noqa: E402
from repro.fpp import FPPSession  # noqa: E402
from repro.graphs.generators import grid2d  # noqa: E402


def main():
    # 1. a weighted graph (64x64 road grid, ~4k vertices)
    g = grid2d(64, 64, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}")

    # 2. one session owns the whole pattern: the planner picks a
    #    VMEM-sized partition (the paper's LLC-sized partitions) and the
    #    session hides the vertex reordering — original ids in AND out
    sess = FPPSession(g).plan(num_queries=16, block_size=256)
    plan = sess.current_plan
    print(f"plan: B={plan.block_size} method={plan.method} "
          f"schedule={plan.schedule} "
          f"working_set={plan.working_set_bytes() / 1e6:.1f} MB")

    # 3. fork 16 independent SSSPs (one FPP)
    rng = np.random.default_rng(0)
    sources = rng.choice(g.n, 16, replace=False)
    res = sess.run("sssp", sources)
    print(f"SSSP fleet: {res.stats['visits']} partition visits, "
          f"{res.edges_processed.mean():.0f} edges/query, "
          f"{res.stats['modeled_bytes'] / 1e6:.1f} MB modeled traffic")

    # 4. exactness vs Dijkstra (values already in original vertex ids)
    for qi in (0, 7, 15):
        want, _ = oracles.dijkstra(g, int(sources[qi]))
        got = res.values[qi]
        assert np.allclose(np.where(np.isfinite(got), got, -1),
                           np.where(np.isfinite(want), want, -1)), qi
    print("SSSP results match Dijkstra exactly")

    # 5. the same queries through the global-frontier baseline — one word,
    #    same result contract (this is the paper's comparison system)
    base = sess.run("sssp", sources, backend="baselines")
    print(f"baseline traffic {base.stats['modeled_bytes'] / 1e6:.1f} MB vs "
          f"ForkGraph {res.stats['modeled_bytes'] / 1e6:.1f} MB "
          f"({base.stats['modeled_bytes'] / res.stats['modeled_bytes']:.1f}x"
          " reduction)")

    # 6. fork 16 PPRs (the NCP workload)
    resp = sess.run("ppr", sources, eps=1e-4)
    p0 = resp.values[0]
    want_p, want_r, _ = oracles.ppr_push(g, int(sources[0]), eps=1e-4)
    print(f"PPR fleet: {resp.stats['visits']} visits; "
          f"query0 |support|={np.sum(p0 > 0)}, "
          f"max|p - oracle| = {np.max(np.abs(p0 - want_p)):.2e} "
          "(both are eps-approximations)")

    # 7. queries that arrive over time: stream them into the same engine
    stream = sess.stream("sssp", capacity=8)
    first = stream.submit(sources[:8])
    stream.pump(20)                       # work begins before batch 2 exists
    second = stream.submit(sources[8:])
    answers = stream.run()
    for i, qid in enumerate(first + second):
        assert np.array_equal(answers[qid], res.values[i]), qid
    print(f"streaming: staggered arrivals match one-shot exactly "
          f"({stream.visits} visits)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
