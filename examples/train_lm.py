"""End-to-end LM training driver (deliverable b): ~100M-param dense model,
a few hundred steps, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    (kill it anytime; rerunning resumes from the last checkpoint)

On a pod this is the same code path as launch/train.py with the
production mesh; here it runs on host devices.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs.base import ArchConfig  # noqa: E402
from repro.configs.shapes import ShapeConfig  # noqa: E402
from repro.models.factory import build_model  # noqa: E402
from repro.train.data import batch_for_step  # noqa: E402
from repro.train.loop import LoopConfig, run_loop  # noqa: E402
from repro.train.optimizer import AdamW, warmup_cosine  # noqa: E402
from repro.train.train_step import (init_train_state,  # noqa: E402
                                    make_train_step)

CFG_100M = ArchConfig(
    name="demo-100m", family="dense",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_ff=2816,
    vocab=49152, source="examples/train_lm.py")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    model = build_model(CFG_100M)
    opt = AdamW()
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {CFG_100M.name} with {n / 1e6:.1f}M params")
    shape = ShapeConfig("demo", "train", args.seq, args.batch)
    step = jax.jit(make_train_step(
        model, opt, warmup_cosine(3e-3, args.steps // 10, args.steps)),
        donate_argnums=0)
    lc = LoopConfig(n_steps=args.steps, ckpt_every=25,
                    ckpt_dir=args.ckpt_dir, log_every=10)
    state, stats = run_loop(step, state,
                            lambda s: batch_for_step(CFG_100M, shape, s),
                            lc)
    print(f"done: {stats.steps_run} steps "
          f"(resumed from {stats.restored_step})"
          if stats.restored_step else f"done: {stats.steps_run} steps")


if __name__ == "__main__":
    main()
