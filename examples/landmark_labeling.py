"""Landmark labeling (paper application LL, §6.1).

Pre-computes shortest-path labels from a batch of landmark vertices — one
fork-processing pattern of SSSPs — then answers point-to-point distance
queries from the labels.

    PYTHONPATH=src python examples/landmark_labeling.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import oracles  # noqa: E402
from repro.core.applications import landmark_labeling  # noqa: E402
from repro.graphs.generators import build_suite  # noqa: E402


def main():
    g = build_suite("road-ca")
    rng = np.random.default_rng(1)
    landmarks = rng.choice(g.n, 32, replace=False)
    labels, res = landmark_labeling(g, landmarks)
    print(f"labeled {len(landmarks)} landmarks on |V|={g.n}: "
          f"{res.stats['visits']} partition visits, "
          f"{res.edges_processed.mean():.0f} edges/landmark")

    # distance estimates are upper bounds that tighten with more landmarks
    us = rng.choice(g.n, 8)
    vs = rng.choice(g.n, 8)
    exact = []
    for u, v in zip(us, vs):
        d, _ = oracles.dijkstra(g, int(u))
        exact.append(d[v])
    est = [float(labels.query(int(u), int(v))) for u, v in zip(us, vs)]
    for (u, v, e, x) in zip(us, vs, est, exact):
        ratio = e / x if np.isfinite(x) and x > 0 else float("nan")
        print(f"  d({u:5d},{v:5d})  exact={x:8.2f}  landmark<={e:8.2f} "
              f"({ratio:4.2f}x)")
        assert e >= x - 1e-5, "landmark bound must be an upper bound"
    print("landmark labeling OK")


if __name__ == "__main__":
    main()
