"""Network community profile (paper application NCP, §6.1).

Runs a fleet of personalized PageRanks from random seeds (the paper seeds
0.01% of vertices; tens of thousands at LiveJournal scale) through the
session front door, sweeps each PPR vector for its best conductance cut,
and reports min conductance per cluster-size bin — the NCP curve.

    PYTHONPATH=src python examples/ncp.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.fpp import FPPSession  # noqa: E402
from repro.graphs.generators import build_suite  # noqa: E402


def main():
    g = build_suite("social-lj")
    rng = np.random.default_rng(2)
    n_seeds = max(8, g.n // 10_000)      # paper: 0.01% of |V|, min 8 here
    seeds = rng.choice(g.n, n_seeds, replace=False)
    sess = FPPSession(g).plan(num_queries=n_seeds, block_size=256)
    profile, res = sess.ncp(seeds, eps=1e-3)
    print(f"NCP on |V|={g.n} |E|={g.m} with {n_seeds} PPR seeds: "
          f"{res.stats['visits']} partition visits, "
          f"{res.edges_processed.sum():.0f} edges total")
    print("cluster-size bin -> best conductance:")
    for b, c in enumerate(profile):
        if np.isfinite(c):
            print(f"  2^{b:<2d} .. {2 ** (b + 1) - 1:>6}: {c:.4f}")
    assert np.isfinite(profile).any(), "no finite conductance found"
    print("NCP OK")


if __name__ == "__main__":
    main()
