"""Random-walk contracts: determinism, length/shape invariants, dispatch.

core/randomwalk.py implements the paper's RW query type on the buffered
substrate (walkers buffered per partition, stepped to exit within one
visit).  The walk is stochastic, so correctness here means *contracts*:
a fixed threefry key reproduces the identical trajectory, every walk
either completes ``length`` steps or provably parks on a sink, positions
stay inside the graph, and the session/facade dispatch stays wired (the
fppcheck reachability pass rules this module must not drift dead).
"""
import numpy as np
import pytest

from repro.core.graph import CSRGraph
from repro.core.partition import partition
from repro.core.queries import prepare, run_rw
from repro.core.randomwalk import WalkResult, run_random_walks
from repro.fpp.session import FPPSession
from repro.graphs.generators import erdos_renyi, grid2d


def _prep(g, block_size=32):
    return prepare(g, block_size)


def test_deterministic_under_fixed_key():
    g = grid2d(10, 10, seed=0)
    bg, perm = _prep(g)
    srcs = perm[np.array([0, 17, 42, 99])]
    a = run_random_walks(bg, srcs, length=16, seed=7)
    b = run_random_walks(bg, srcs, length=16, seed=7)
    np.testing.assert_array_equal(a.positions, b.positions)
    np.testing.assert_array_equal(a.steps, b.steps)
    np.testing.assert_array_equal(a.trajectory_hash, b.trajectory_hash)
    assert a.visits == b.visits


def test_different_seeds_diverge():
    g = erdos_renyi(200, avg_deg=6.0, seed=1)
    bg, perm = _prep(g)
    srcs = perm[np.arange(8)]
    a = run_random_walks(bg, srcs, length=24, seed=0)
    b = run_random_walks(bg, srcs, length=24, seed=1)
    # identical trajectories across different keys would mean the key is
    # ignored; hashes are order-sensitive so any step difference shows
    assert not np.array_equal(a.trajectory_hash, b.trajectory_hash)


def test_length_and_shape_contracts():
    g = grid2d(8, 12, seed=2)
    bg, perm = _prep(g)
    q = 5
    srcs = perm[np.array([0, 3, 9, 50, 95])]
    res = run_rw(bg, srcs, length=12, seed=3)
    assert isinstance(res, WalkResult)
    for field in (res.positions, res.steps, res.trajectory_hash):
        assert field.shape == (q,)
    # grid has no sinks: every walk must complete exactly `length` steps
    np.testing.assert_array_equal(res.steps, np.full(q, 12))
    # positions stay inside the padded id space and on real vertices
    assert res.positions.min() >= 0
    assert res.positions.max() < bg.n
    assert res.visits >= 1


def test_sink_walkers_finish_in_place():
    # a 3-vertex path ending in a sink: 0 -> 1 -> 2, no out-edges at 2
    indptr = np.array([0, 1, 2, 2], dtype=np.int64)
    indices = np.array([1, 2], dtype=np.int64)
    weights = np.ones(2, dtype=np.float32)
    g = CSRGraph(indptr=indptr, indices=indices, weights=weights, n=3, m=2)
    bg, perm = partition(g, 2)
    res = run_random_walks(bg, perm[np.array([0])], length=10, seed=0)
    # the walker reaches the sink in 2 steps, then is marked finished
    # (steps set to `length`) without moving again
    assert res.steps[0] == 10
    assert res.positions[0] == perm[2]


def test_zero_length_walk_is_identity():
    g = grid2d(6, 6, seed=0)
    bg, perm = _prep(g, block_size=16)
    srcs = perm[np.array([4, 31])]
    res = run_random_walks(bg, srcs, length=0, seed=0)
    np.testing.assert_array_equal(res.positions, srcs)
    np.testing.assert_array_equal(res.steps, np.zeros(2, dtype=res.steps.dtype))


def test_session_dispatch_original_ids():
    """FPPSession.random_walks round-trips the permutation."""
    g = grid2d(9, 9, seed=4)
    sess = FPPSession(g)
    sess.plan(num_queries=4, block_size=16)
    srcs = np.array([0, 8, 40, 80])
    res = sess.random_walks(srcs, length=10, seed=5)
    assert res.positions.shape == (4,)
    # positions are original vertex ids, not partition-major ones
    assert res.positions.max() < g.n
    np.testing.assert_array_equal(res.steps, np.full(4, 10))
    # determinism survives the session wrapper too
    res2 = sess.random_walks(srcs, length=10, seed=5)
    np.testing.assert_array_equal(res.positions, res2.positions)


def test_reachability_ruling_stays_wired():
    """The fppcheck reachability pass must keep ruling randomwalk wired."""
    from repro.analysis import PassContext, repo_root
    from repro.analysis.pallas_passes import check_reachability
    findings = check_reachability(PassContext(root=repo_root()))
    rw = [f for f in findings
          if f.location == "src/repro/core/randomwalk.py"]
    assert len(rw) == 1
    assert rw[0].code == "wired"
    assert rw[0].severity == "info"
