"""GraphServer: the multi-tenant serving contract (DESIGN.md §4.2).

What serving must never change: answers.  A request served through lane
pools, weighted-fair admission, and chunked megasteps returns values
bit-identical to ``FPPSession.run`` of the same query — for every kind,
because admission only injects source ops a one-shot run would have
started with (the §3.3 exactness argument) and the engine's deterministic
priority schedule makes the visit sequence independent of chunking.

What serving must additionally guarantee, pinned here:
  * a hot tenant at 10x offered load cannot starve another tenant
    (queue-wait bound from start-time fair queueing);
  * deadline-expired requests are rejected with an explicit response,
    never silently dropped;
  * two registered graphs serve interleaved traffic with no state bleed;
  * request priorities plumb through pool arbitration
    (core/scheduler.py ``prefer_older_ties``).
What continuous batching adds, pinned here:
  * multi-threaded submitters against the running lanes get the same
    bit-identical answers (and ``serve_forever`` matches ``serve()``);
  * identical in-flight requests coalesce onto one lane and fan out with
    per-request billing (and ``dedup=False`` turns it off);
  * the warm compile cache is hit, not re-compiled, across pow2 resizes.
"""
import threading

import numpy as np
import pytest

from repro.core.scheduler import PartitionScheduler
from repro.fpp import FPPSession, MemoryModel
from repro.fpp.planner import auto_fused, autoscale_capacity, pow2_bucket
from repro.graphs.generators import grid2d, rmat
from repro.serve import GraphRequest, GraphServer


def _sources(g, k, seed=0):
    cand = np.flatnonzero(g.out_degree() > 0)
    return np.random.default_rng(seed).choice(cand, size=k, replace=False)


# ---------------------------------------------------------------- parity


@pytest.mark.parametrize("kind", ["sssp", "bfs", "ppr"])
def test_served_results_bit_identical_to_session_run(kind):
    g = grid2d(12, 12, seed=3)
    srcs = _sources(g, 4, seed=1)
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    one = sess.run(kind, srcs)
    # registering the session itself guarantees the served plan is the
    # same plan the one-shot run used
    server = GraphServer(capacity=len(srcs), k_visits=16)
    server.register_graph("g", sess)
    rids = [server.submit(GraphRequest(kind=kind, source=int(s), graph="g"))
            for s in srcs]
    server.serve()
    for i, rid in enumerate(rids):
        r = server.poll(rid)
        assert r is not None and r.status == "ok"
        np.testing.assert_array_equal(r.values, one.values[i], err_msg=kind)
        if kind == "ppr":
            np.testing.assert_array_equal(r.residual, one.residual[i])
        # per-request stats: exact integral edge work, billed host syncs
        assert r.stats["edges"] == round(r.stats["edges"])
        assert r.stats["edges"] == one.edges_processed[i]
        assert r.stats["host_syncs"] >= 1
        assert r.stats["visits"] >= 1


def test_mixed_two_tenant_two_graph_workload_end_to_end():
    """The ISSUE 5 acceptance workload: mixed sssp+ppr, two tenants, two
    graphs, interleaved submissions — every request answered, per-request
    stats attached, every answer bit-identical to the session run."""
    road = grid2d(10, 10, seed=6)
    social = rmat(7, 4, seed=7)
    road_s = _sources(road, 3, seed=2)
    soc_s = _sources(social, 3, seed=3)
    sess = {"road": FPPSession(road).plan(num_queries=3, block_size=32),
            "social": FPPSession(social).plan(num_queries=3, block_size=32)}
    want = {("road", "sssp"): sess["road"].run("sssp", road_s),
            ("social", "ppr"): sess["social"].run("ppr", soc_s)}

    server = GraphServer(capacity=3, k_visits=16)
    server.register_graph("road", sess["road"])
    server.register_graph("social", sess["social"])
    rids = []
    for i in range(3):      # interleave graphs, kinds, and tenants
        rids.append((("road", "sssp"), i, server.submit(GraphRequest(
            kind="sssp", source=int(road_s[i]), graph="road",
            tenant="alice" if i % 2 else "bob"))))
        rids.append((("social", "ppr"), i, server.submit(GraphRequest(
            kind="ppr", source=int(soc_s[i]), graph="social",
            tenant="bob" if i % 2 else "alice"))))
    out = server.serve()
    assert len(out) == len(rids)        # nothing dropped, nothing extra
    for key, i, rid in rids:
        r = out[rid]
        assert r.status == "ok"
        np.testing.assert_array_equal(r.values, want[key].values[i])
        for stat in ("visits", "edges", "host_syncs", "queue_wait_s",
                     "queue_wait_rounds", "latency_s"):
            assert stat in r.stats, (key, stat)


# --------------------------------------------------------------- fairness


def test_hot_tenant_cannot_starve_cold_tenant():
    """10x offered load from one tenant: the other tenant's queue wait
    stays bounded by the fair-share interleave, nowhere near the backlog
    a FIFO queue would impose."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 10, seed=5)
    # dedup=False: the hot tenant reuses sources, and coalescing them
    # would dissolve the very backlog this test measures
    server = GraphServer(capacity=2, k_visits=16, autoscaler=None,
                         dedup=False)
    server.register_graph("g", g, num_queries=2, block_size=16)
    hot = [server.submit(GraphRequest(kind="sssp", source=int(srcs[i % 10]),
                                      graph="g", tenant="hot"))
           for i in range(20)]
    cold = [server.submit(GraphRequest(kind="sssp", source=int(s),
                                       graph="g", tenant="cold"))
            for s in srcs[:2]]
    out = server.serve()
    assert all(out[r].status == "ok" for r in hot + cold)
    cold_wait = max(out[r].stats["queue_wait_rounds"] for r in cold)
    hot_wait = max(out[r].stats["queue_wait_rounds"] for r in hot)
    # fair interleave admits a cold request within ~one fair-share cycle
    # of the 2-lane pool; the hot backlog (20 deep) waits far longer
    assert cold_wait <= 4, (cold_wait, hot_wait)
    assert hot_wait > cold_wait


def test_late_joining_tenant_neither_starved_nor_monopolist():
    """A tenant joining mid-serve, after the hot tenant has accrued
    virtual time, is caught up to the live pace: admissions after the
    join interleave instead of the newcomer draining its banked vtime as
    a monopoly burst (or, unfixed the other way, waiting out the whole
    hot backlog)."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 10, seed=15)
    # result_cache off: cold reuses sources hot already finished, and a
    # cache hit skips admission entirely — this test is about admission
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None,
                         result_cache=False)
    server.register_graph("g", g, num_queries=1, block_size=16)
    hot = [server.submit(GraphRequest(kind="sssp", source=int(srcs[i % 10]),
                                      graph="g", tenant="hot"))
           for i in range(8)]
    while len(server.responses) < 4:     # hot accrues vtime mid-serve
        assert server.step()
    join_round = server.rounds
    cold = [server.submit(GraphRequest(kind="sssp", source=int(s),
                                       graph="g", tenant="cold"))
            for s in srcs[:4]]
    out = server.serve()
    assert all(out[r].status == "ok" for r in hot + cold)

    def admit_round(r):
        # queue_wait_rounds is relative to the submit round: 0 for the
        # hot batch, join_round for the cold batch
        return ((0 if r in hot else join_round)
                + out[r].stats["queue_wait_rounds"])

    after = sorted((r for r in hot + cold if admit_round(r) >= join_round),
                   key=admit_round)
    tags = ["cold" if r in cold else "hot" for r in after]
    # post-join admissions interleave: neither the caught-up newcomer nor
    # the incumbent may run away with consecutive lanes
    for k in range(1, len(tags) + 1):
        c, h = tags[:k].count("cold"), tags[:k].count("hot")
        assert abs(c - h) <= 2, tags


def test_tenant_weights_shape_admission_order():
    """weight=2 buys two admissions per unit virtual time: in any prefix
    of the admission order the heavy tenant holds at most its share."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 8, seed=6)
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    server.register_tenant("heavy", weight=2.0)
    server.register_tenant("light", weight=1.0)
    rids = {}
    for i in range(8):
        t = "heavy" if i < 4 else "light"
        rids[server.submit(GraphRequest(kind="sssp", source=int(srcs[i]),
                                        graph="g", tenant=t))] = t
    out = server.serve()
    order = sorted(rids, key=lambda r: out[r].stats["queue_wait_rounds"])
    admitted = [rids[r] for r in order]
    for k in range(1, len(admitted) + 1):
        heavy = admitted[:k].count("heavy")
        # 2:1 fair share, +1 slack for the start-time tie
        assert heavy <= (2 * k) // 3 + 1, admitted


# --------------------------------------------------------------- deadlines


def test_deadline_expired_rejected_not_silently_dropped():
    tick = [0.0]
    g = grid2d(8, 8, seed=4)
    server = GraphServer(capacity=2, k_visits=16, clock=lambda: tick[0],
                         autoscaler=None)
    server.register_graph("g", g, num_queries=2, block_size=16)
    srcs = _sources(g, 2, seed=7)
    keep = server.submit(GraphRequest(kind="sssp", source=int(srcs[0]),
                                      graph="g"))
    doomed = server.submit(GraphRequest(kind="sssp", source=int(srcs[1]),
                                        graph="g", deadline_s=5.0))
    tick[0] = 10.0                       # deadline lapses while queued
    out = server.serve()
    assert len(out) == 2                 # both answered — nothing dropped
    assert out[doomed].status == "expired"
    assert out[doomed].values is None
    assert out[doomed].stats["queue_wait_s"] == pytest.approx(10.0)
    assert out[keep].status == "ok" and out[keep].values is not None


def test_deadline_never_expires_admitted_requests():
    """Once a request holds a lane it runs to completion even if its
    deadline lapses mid-flight (rejection is an admission-time decision)."""
    tick = [0.0]
    g = grid2d(8, 8, seed=4)
    server = GraphServer(capacity=1, k_visits=4, clock=lambda: tick[0],
                         autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    rid = server.submit(GraphRequest(kind="sssp",
                                     source=int(_sources(g, 1, seed=8)[0]),
                                     graph="g", deadline_s=5.0))
    assert server.step()                 # admitted at t=0
    tick[0] = 10.0                       # lapses while in flight
    out = server.serve()
    assert out[rid].status == "ok"


# --------------------------------------------------------------- isolation


def test_multi_graph_isolation_no_state_bleed():
    """Interleaved requests against two different-sized graphs: each
    answer has its own graph's shape and matches that graph's one-shot
    run exactly."""
    a, b = grid2d(9, 9, seed=9), grid2d(12, 12, seed=10)    # 81 vs 144
    sa, sb = _sources(a, 3, seed=11), _sources(b, 3, seed=12)
    sess = {"a": FPPSession(a).plan(num_queries=3, block_size=32),
            "b": FPPSession(b).plan(num_queries=3, block_size=32)}
    one = {"a": sess["a"].run("sssp", sa), "b": sess["b"].run("sssp", sb)}
    server = GraphServer(capacity=3, k_visits=8)
    server.register_graph("a", sess["a"])
    server.register_graph("b", sess["b"])
    rids = []
    for i in range(3):
        rids.append(("a", i, server.submit(GraphRequest(
            kind="sssp", source=int(sa[i]), graph="a"))))
        rids.append(("b", i, server.submit(GraphRequest(
            kind="sssp", source=int(sb[i]), graph="b"))))
    out = server.serve()
    for name, i, rid in rids:
        r = out[rid]
        assert r.values.shape == (sess[name].graph.n,)
        np.testing.assert_array_equal(r.values, one[name].values[i])


# ------------------------------------------------- priorities + arbitration


def test_request_priority_picks_pool_first():
    """A more urgent (lower-priority-value) request pulls its pool to the
    front of arbitration even when another pool queued first."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 2, seed=13)
    server = GraphServer(capacity=1, k_visits=8, autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    server.submit(GraphRequest(kind="sssp", source=int(srcs[0]), graph="g"))
    urgent = server.submit(GraphRequest(kind="bfs", source=int(srcs[1]),
                                        graph="g", priority=-1.0))
    server.step()                        # one round serves exactly one pool
    bfs_pool = server._pools[("g", "bfs")]
    sssp_pool = server._pools[("g", "sssp")]
    assert bfs_pool.exec.visits > 0      # urgent pool won arbitration
    assert sssp_pool.exec.visits == 0
    out = server.serve()
    assert out[urgent].status == "ok"


def test_scheduler_prefer_older_ties():
    """The serving tie-break: among priority ties pick the smallest stamp;
    the default contract (first index) is untouched."""
    sched = PartitionScheduler("priority", 3)
    prio = np.array([1.0, 1.0, 2.0], dtype=np.float32)
    stamp = np.array([7, 2, 0], dtype=np.int64)
    ops = np.array([1, 1, 1])
    assert sched.select(prio, stamp, ops) == 0                  # device rule
    assert sched.select(prio, stamp, ops, prefer_older_ties=True) == 1
    # all-empty still returns None either way
    inf = np.full(3, np.inf, dtype=np.float32)
    assert sched.select(inf, stamp, ops, prefer_older_ties=True) is None


# -------------------------------------------------------------- autoscale


def test_autoscale_capacity_hint_is_memory_clamped():
    mem = MemoryModel()
    kw = dict(mem=mem, n_vertices=1024, block_size=64)
    assert autoscale_capacity(0, 0, **kw) == 1           # idle shrinks
    assert autoscale_capacity(5, 1, **kw) == 8           # next pow2 >= 6
    assert autoscale_capacity(100, 0, max_capacity=16, **kw) == 16
    # a tiny VMEM budget caps the suggestion below raw demand
    tiny = MemoryModel(vmem_bytes=(2 * 64 * 64 + 2 * 8 * 64) * 4)
    got = autoscale_capacity(100, 0, mem=tiny, n_vertices=1024,
                             block_size=64)
    assert got <= 8 and tiny.fits(64, got, 1024)


def test_server_grows_pool_capacity_under_backlog():
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 6, seed=14)
    server = GraphServer(capacity=1, k_visits=16, max_capacity=8)
    server.register_graph("g", g, num_queries=1, block_size=16)
    rids = [server.submit(GraphRequest(kind="sssp", source=int(s),
                                       graph="g")) for s in srcs]
    out = server.serve()
    assert all(out[r].status == "ok" for r in rids)
    # the backlog of 6 should have pulled capacity up to the next pow2
    assert server._pools[("g", "sssp")].capacity == 8


# -------------------------------------------------- continuous batching


def test_concurrent_submitters_bit_identical_and_result_blocks():
    """Three client threads race submissions against the running lanes;
    every blocking ``result`` comes back bit-identical to the one-shot
    session run — a foreign-thread submit lands at a chunk boundary,
    indistinguishable from a quiet one."""
    g = grid2d(12, 12, seed=3)
    srcs = _sources(g, 12, seed=21)
    sess = FPPSession(g).plan(num_queries=4, block_size=32)
    one = sess.run("sssp", srcs)
    server = GraphServer(capacity=4, k_visits=16, autoscaler=None)
    server.register_graph("g", sess)
    server.start()
    try:
        rids, lock = {}, threading.Lock()

        def client(lo):
            for i in range(lo, lo + 4):
                rid = server.submit(GraphRequest(
                    kind="sssp", source=int(srcs[i]), graph="g",
                    tenant=f"t{lo}"))
                with lock:
                    rids[i] = rid
        threads = [threading.Thread(target=client, args=(lo,))
                   for lo in (0, 4, 8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i, rid in rids.items():
            r = server.result(rid, timeout=120)
            assert r.status == "ok"
            np.testing.assert_array_equal(r.values, one.values[i])
        with pytest.raises(KeyError):
            server.result(10_000, timeout=1)
    finally:
        server.shutdown()


def test_serve_forever_matches_synchronous_serve():
    """The same mixed workload through the concurrent lanes and through
    the synchronous pump (the parity oracle): minplus answers are
    bit-identical; push answers agree within the eps the one-shot run
    carries (§3.3 — lane co-residency, and hence float accumulation
    order, legitimately differs across schedules); every request is
    answered with per-request stats."""
    g = grid2d(10, 10, seed=6)
    srcs = _sources(g, 6, seed=22)
    sess = FPPSession(g).plan(num_queries=2, block_size=32)
    reqs = [GraphRequest(kind=("sssp" if i % 2 else "ppr"),
                         source=int(srcs[i]), graph="g",
                         tenant="a" if i % 3 else "b")
            for i in range(6)]

    sync = GraphServer(capacity=2, k_visits=16, autoscaler=None)
    sync.register_graph("g", sess)
    sync_rids = sync.submit_all(reqs)
    sync_out = sync.serve()

    conc = GraphServer(capacity=2, k_visits=16, autoscaler=None)
    conc.register_graph("g", sess)
    conc_out = conc.serve_forever(iter([reqs]))
    assert not conc._running                 # lanes stopped after drain

    assert len(conc_out) == len(sync_out) == len(reqs)
    by_src_sync = {(sync_out[r].kind, sync_out[r].source): sync_out[r]
                   for r in sync_rids}
    for r in conc_out.values():
        assert r.status == "ok"
        want = by_src_sync[(r.kind, r.source)].values
        if r.kind == "ppr":
            np.testing.assert_allclose(r.values, want, atol=1e-3)
        else:
            np.testing.assert_array_equal(r.values, want)
        for stat in ("visits", "edges", "host_syncs", "latency_s"):
            assert stat in r.stats


def test_dedup_coalesces_in_flight_twins_and_bills_everyone():
    """Identical in-flight requests ride one lane: same bits out, the
    lane's work billed to every requester, ``fanout`` on the primary and
    ``coalesced`` on followers — and with one lane + three twins the pool
    only ever runs one query."""
    g = grid2d(10, 10, seed=6)
    src = int(_sources(g, 1, seed=23)[0])
    sess = FPPSession(g).plan(num_queries=1, block_size=32)
    one = sess.run("sssp", np.array([src]))
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    server.register_graph("g", sess)
    rids = [server.submit(GraphRequest(kind="sssp", source=src, graph="g",
                                       tenant=t))
            for t in ("a", "b", "c")]
    out = server.serve()
    assert len(out) == 3
    primary, followers = out[rids[0]], [out[r] for r in rids[1:]]
    assert primary.stats["fanout"] == 2
    assert all(f.stats["coalesced"] for f in followers)
    for r in [primary] + followers:
        assert r.status == "ok"
        np.testing.assert_array_equal(r.values, one.values[0])
        # per-request attribution: every requester billed the lane's work
        assert r.stats["visits"] == primary.stats["visits"] >= 1
        assert r.stats["edges"] == one.edges_processed[0]
    # one lane, one execution: the executor saw exactly one query
    assert server._pools[("g", "sssp")].exec._next_qid == 1


def test_dedup_off_serves_twins_separately():
    g = grid2d(8, 8, seed=4)
    src = int(_sources(g, 1, seed=24)[0])
    server = GraphServer(capacity=2, k_visits=16, autoscaler=None,
                         dedup=False)
    server.register_graph("g", g, num_queries=2, block_size=16)
    rids = [server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
            for _ in range(2)]
    out = server.serve()
    assert all(out[r].status == "ok" for r in rids)
    assert not any(out[r].stats.get("coalesced") for r in rids)
    assert server._pools[("g", "sssp")].exec._next_qid == 2


def test_expired_dedup_primary_promotes_live_follower():
    """A coalescing primary whose deadline lapses while queued is
    rejected; its follower (no deadline) is promoted onto the backlog
    and still gets a real answer."""
    tick = [0.0]
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 2, seed=25)
    server = GraphServer(capacity=1, k_visits=16, clock=lambda: tick[0],
                         autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    # occupy the single lane so the twins stay queued
    blocker = server.submit(GraphRequest(kind="sssp", source=int(srcs[0]),
                                         graph="g"))
    doomed = server.submit(GraphRequest(kind="sssp", source=int(srcs[1]),
                                        graph="g", deadline_s=5.0))
    saved = server.submit(GraphRequest(kind="sssp", source=int(srcs[1]),
                                       graph="g", tenant="other"))
    tick[0] = 10.0                      # lapses while queued
    out = server.serve()
    assert out[doomed].status == "expired"
    assert out[saved].status == "ok" and out[saved].values is not None
    assert out[blocker].status == "ok"


def test_expired_primary_promotion_same_tenant_not_dropped():
    """Regression: policing rebuilds the heap it sweeps, and rejecting an
    expired coalescing primary promotes its follower into that same
    tenant's heap (the common case — the same tenant submitted the
    duplicate).  The promotion must land in the rebuilt heap, not be
    dropped by it, and each expired primary must be rejected exactly
    once even with several lapsing in one sweep."""
    tick = [0.0]
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 3, seed=27)
    server = GraphServer(capacity=1, k_visits=16, clock=lambda: tick[0],
                         autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    # occupy the single lane so everything below stays queued
    blocker = server.submit(GraphRequest(kind="sssp", source=int(srcs[0]),
                                         graph="g"))
    doomed, saved = [], []
    for s in srcs[1:]:
        doomed.append(server.submit(GraphRequest(
            kind="sssp", source=int(s), graph="g", deadline_s=5.0)))
        saved.append(server.submit(GraphRequest(
            kind="sssp", source=int(s), graph="g")))   # same tenant twin
    tick[0] = 10.0                      # both primaries lapse while queued
    out = server.serve()
    for rid in doomed:
        assert out[rid].status == "expired"
    for rid in saved:
        assert out[rid].status == "ok" and out[rid].values is not None
    assert out[blocker].status == "ok"
    assert server.pending == 0


def test_register_graph_invalid_prewarm_has_no_effect():
    """A register_graph rejected for a bad prewarm kind must leave no
    trace: the corrected retry succeeds instead of hitting 'already
    registered'."""
    g = grid2d(8, 8, seed=4)
    server = GraphServer(capacity=1, k_visits=16)
    with pytest.raises(ValueError, match="prewarm kind"):
        server.register_graph("g", g, prewarm=("sssp", "pagerank"),
                              num_queries=1, block_size=16)
    assert "g" not in server._sessions
    server.register_graph("g", g, prewarm=("sssp",),
                          num_queries=1, block_size=16)   # retry works


def test_warm_cache_shared_across_servers_and_resizes():
    """A pow2 capacity bucket's megastep compiles once into the shared
    cache; a second server over the same session resizes into a cache
    hit instead of recompiling — the bench's sweep-point pattern."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 6, seed=26)
    server = GraphServer(capacity=1, k_visits=16, max_capacity=8)
    server.register_graph("g", g, num_queries=1, block_size=16)
    rids = [server.submit(GraphRequest(kind="sssp", source=int(s),
                                       graph="g")) for s in srcs]
    out = server.serve()
    assert all(out[r].status == "ok" for r in rids)
    assert server._pools[("g", "sssp")].capacity == 8   # grew via resize
    compiled = server.cache.stats()["misses"]

    twin = GraphServer(capacity=1, k_visits=16, max_capacity=8,
                       cache=server.cache)
    twin.register_graph("g", server._sessions["g"])     # same session
    rids = [twin.submit(GraphRequest(kind="sssp", source=int(s),
                                     graph="g")) for s in srcs]
    out = twin.serve()
    assert all(out[r].status == "ok" for r in rids)
    stats = twin.cache.stats()
    assert stats["misses"] == compiled, stats   # no new compiles
    assert stats["hits"] >= 1, stats            # twin's resize hit warmth
    # every compiled capacity is a pow2 bucket
    assert all(k[3] == pow2_bucket(k[3]) for k in server.cache._cache)


def test_warm_cache_keys_by_session_not_graph_name():
    """Two servers sharing one cache, each calling a *different* graph by
    the same name: the second must never be handed the first's executable
    (same structure, different weights — a collision would be silently
    wrong values, not a shape error)."""
    from repro.serve import MegastepCache
    g1 = grid2d(8, 8, seed=1)
    g2 = grid2d(8, 8, seed=2)           # same shape, different weights
    src = int(_sources(g1, 1, seed=28)[0])
    cache = MegastepCache()
    s1 = GraphServer(capacity=2, k_visits=16, autoscaler=None, cache=cache)
    s1.register_graph("default", g1, num_queries=2, block_size=16)
    s1._warm_executable(s1._pool("default", "sssp"), 2)   # warm g1's key
    s2 = GraphServer(capacity=2, k_visits=16, autoscaler=None, cache=cache)
    s2.register_graph("default", g2, num_queries=2, block_size=16)
    rid = s2.submit(GraphRequest(kind="sssp", source=src, graph="default"))
    out = s2.serve()
    expected = FPPSession(g2).plan(num_queries=2, block_size=16).run(
        "sssp", [src])
    np.testing.assert_array_equal(out[rid].values, expected.values[0])
    # warming g2's pool lands a second entry, not a name-collision hit
    s2._warm_executable(s2._pool("default", "sssp"), 2)
    assert cache.stats()["size"] == 2


# ------------------------------------------------------- planner dispatch


def test_pow2_bucket_snaps_and_clamps():
    assert pow2_bucket(0) == 1
    assert pow2_bucket(1) == 1
    assert pow2_bucket(5) == 8
    assert pow2_bucket(8) == 8
    assert pow2_bucket(9) == 16
    assert pow2_bucket(10_000, max_capacity=64) == 64
    assert pow2_bucket(2, min_capacity=4) == 4


def test_auto_fused_follows_committed_yardsticks():
    # minplus kinds: fused won both committed K points
    assert auto_fused("sssp", 64) and auto_fused("sssp", 8)
    assert auto_fused("bfs", 64)        # bfs shares sssp's minplus body
    # ppr: the XLA megastep beat fused at both committed K points
    assert not auto_fused("ppr", 64) and not auto_fused("ppr", 8)
    # off-grid K resolves via the nearest committed yardstick
    assert auto_fused("sssp", 16) in (True, False)


def test_auto_fused_guards_dense_block_graphs():
    """Past the planner's dmax budget the fused kernel's pre-gathered
    adjacency grows linearly in dmax; the auto-select must fall back to
    the XLA megastep (an explicit fused=True is never overridden)."""
    from repro.fpp.planner import FUSED_DMAX_BUDGET
    assert auto_fused("sssp", 64, dmax=FUSED_DMAX_BUDGET)
    assert not auto_fused("sssp", 64, dmax=FUSED_DMAX_BUDGET + 1)
    # a dense-partitioned graph resolves to the XLA megastep end to end:
    # an ER graph's block adjacency is near-complete, dmax ~ P-1
    from repro.graphs.generators import erdos_renyi
    g = erdos_renyi(n=1024, avg_deg=4.0, seed=3)
    sess = FPPSession(g).plan(num_queries=2, block_size=32, fused="auto")
    bg, _ = sess.prepared()
    assert bg.nbr_part.shape[1] > FUSED_DMAX_BUDGET
    assert sess.current_plan.resolve_fused(
        "sssp", dmax=bg.nbr_part.shape[1]) is False
    server = GraphServer(capacity=2, k_visits=8)
    server.register_graph("er", sess)
    assert server._warm_params(sess, "sssp")["fused"] is False


def test_plan_fused_auto_resolves_per_kind():
    g = grid2d(8, 8, seed=4)
    sess = FPPSession(g).plan(num_queries=2, block_size=16, fused="auto")
    p = sess.current_plan
    assert p.fused == "auto"
    assert p.resolve_fused("sssp") is True
    assert p.resolve_fused("ppr") is False
    with pytest.raises(ValueError):
        FPPSession(g).plan(num_queries=2, fused="sometimes")


# ------------------------------------------------------------------ misc


def test_submit_validation_and_empty_serve():
    g = grid2d(6, 6, seed=15)
    server = GraphServer(capacity=2)
    server.register_graph("g", g, num_queries=2, block_size=16)
    with pytest.raises(ValueError):
        server.submit(GraphRequest(kind="dfs", source=0, graph="g"))
    with pytest.raises(ValueError):
        server.submit(GraphRequest(kind="sssp", source=0, graph="nope"))
    with pytest.raises(ValueError):
        server.submit(GraphRequest(kind="sssp", source=g.n, graph="g"))
    with pytest.raises(ValueError):
        server.register_graph("g", g)    # duplicate name
    assert server.serve() == {}          # nothing submitted: clean no-op
    assert server.pending == 0
