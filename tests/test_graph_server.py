"""GraphServer: the multi-tenant serving contract (DESIGN.md §4.2).

What serving must never change: answers.  A request served through lane
pools, weighted-fair admission, and chunked megasteps returns values
bit-identical to ``FPPSession.run`` of the same query — for every kind,
because admission only injects source ops a one-shot run would have
started with (the §3.3 exactness argument) and the engine's deterministic
priority schedule makes the visit sequence independent of chunking.

What serving must additionally guarantee, pinned here:
  * a hot tenant at 10x offered load cannot starve another tenant
    (queue-wait bound from start-time fair queueing);
  * deadline-expired requests are rejected with an explicit response,
    never silently dropped;
  * two registered graphs serve interleaved traffic with no state bleed;
  * request priorities plumb through pool arbitration
    (core/scheduler.py ``prefer_older_ties``).
"""
import numpy as np
import pytest

from repro.core.scheduler import PartitionScheduler
from repro.fpp import FPPSession, MemoryModel
from repro.fpp.planner import autoscale_capacity
from repro.graphs.generators import grid2d, rmat
from repro.serve import GraphRequest, GraphServer


def _sources(g, k, seed=0):
    cand = np.flatnonzero(g.out_degree() > 0)
    return np.random.default_rng(seed).choice(cand, size=k, replace=False)


# ---------------------------------------------------------------- parity


@pytest.mark.parametrize("kind", ["sssp", "bfs", "ppr"])
def test_served_results_bit_identical_to_session_run(kind):
    g = grid2d(12, 12, seed=3)
    srcs = _sources(g, 4, seed=1)
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    one = sess.run(kind, srcs)
    # registering the session itself guarantees the served plan is the
    # same plan the one-shot run used
    server = GraphServer(capacity=len(srcs), k_visits=16)
    server.register_graph("g", sess)
    rids = [server.submit(GraphRequest(kind=kind, source=int(s), graph="g"))
            for s in srcs]
    server.serve()
    for i, rid in enumerate(rids):
        r = server.poll(rid)
        assert r is not None and r.status == "ok"
        np.testing.assert_array_equal(r.values, one.values[i], err_msg=kind)
        if kind == "ppr":
            np.testing.assert_array_equal(r.residual, one.residual[i])
        # per-request stats: exact integral edge work, billed host syncs
        assert r.stats["edges"] == round(r.stats["edges"])
        assert r.stats["edges"] == one.edges_processed[i]
        assert r.stats["host_syncs"] >= 1
        assert r.stats["visits"] >= 1


def test_mixed_two_tenant_two_graph_workload_end_to_end():
    """The ISSUE 5 acceptance workload: mixed sssp+ppr, two tenants, two
    graphs, interleaved submissions — every request answered, per-request
    stats attached, every answer bit-identical to the session run."""
    road = grid2d(10, 10, seed=6)
    social = rmat(7, 4, seed=7)
    road_s = _sources(road, 3, seed=2)
    soc_s = _sources(social, 3, seed=3)
    sess = {"road": FPPSession(road).plan(num_queries=3, block_size=32),
            "social": FPPSession(social).plan(num_queries=3, block_size=32)}
    want = {("road", "sssp"): sess["road"].run("sssp", road_s),
            ("social", "ppr"): sess["social"].run("ppr", soc_s)}

    server = GraphServer(capacity=3, k_visits=16)
    server.register_graph("road", sess["road"])
    server.register_graph("social", sess["social"])
    rids = []
    for i in range(3):      # interleave graphs, kinds, and tenants
        rids.append((("road", "sssp"), i, server.submit(GraphRequest(
            kind="sssp", source=int(road_s[i]), graph="road",
            tenant="alice" if i % 2 else "bob"))))
        rids.append((("social", "ppr"), i, server.submit(GraphRequest(
            kind="ppr", source=int(soc_s[i]), graph="social",
            tenant="bob" if i % 2 else "alice"))))
    out = server.serve()
    assert len(out) == len(rids)        # nothing dropped, nothing extra
    for key, i, rid in rids:
        r = out[rid]
        assert r.status == "ok"
        np.testing.assert_array_equal(r.values, want[key].values[i])
        for stat in ("visits", "edges", "host_syncs", "queue_wait_s",
                     "queue_wait_rounds", "latency_s"):
            assert stat in r.stats, (key, stat)


# --------------------------------------------------------------- fairness


def test_hot_tenant_cannot_starve_cold_tenant():
    """10x offered load from one tenant: the other tenant's queue wait
    stays bounded by the fair-share interleave, nowhere near the backlog
    a FIFO queue would impose."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 10, seed=5)
    server = GraphServer(capacity=2, k_visits=16, autoscaler=None)
    server.register_graph("g", g, num_queries=2, block_size=16)
    hot = [server.submit(GraphRequest(kind="sssp", source=int(srcs[i % 10]),
                                      graph="g", tenant="hot"))
           for i in range(20)]
    cold = [server.submit(GraphRequest(kind="sssp", source=int(s),
                                       graph="g", tenant="cold"))
            for s in srcs[:2]]
    out = server.serve()
    assert all(out[r].status == "ok" for r in hot + cold)
    cold_wait = max(out[r].stats["queue_wait_rounds"] for r in cold)
    hot_wait = max(out[r].stats["queue_wait_rounds"] for r in hot)
    # fair interleave admits a cold request within ~one fair-share cycle
    # of the 2-lane pool; the hot backlog (20 deep) waits far longer
    assert cold_wait <= 4, (cold_wait, hot_wait)
    assert hot_wait > cold_wait


def test_late_joining_tenant_neither_starved_nor_monopolist():
    """A tenant joining mid-serve, after the hot tenant has accrued
    virtual time, is caught up to the live pace: admissions after the
    join interleave instead of the newcomer draining its banked vtime as
    a monopoly burst (or, unfixed the other way, waiting out the whole
    hot backlog)."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 10, seed=15)
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    hot = [server.submit(GraphRequest(kind="sssp", source=int(srcs[i % 10]),
                                      graph="g", tenant="hot"))
           for i in range(8)]
    while len(server.responses) < 4:     # hot accrues vtime mid-serve
        assert server.step()
    join_round = server.rounds
    cold = [server.submit(GraphRequest(kind="sssp", source=int(s),
                                       graph="g", tenant="cold"))
            for s in srcs[:4]]
    out = server.serve()
    assert all(out[r].status == "ok" for r in hot + cold)

    def admit_round(r):
        # queue_wait_rounds is relative to the submit round: 0 for the
        # hot batch, join_round for the cold batch
        return ((0 if r in hot else join_round)
                + out[r].stats["queue_wait_rounds"])

    after = sorted((r for r in hot + cold if admit_round(r) >= join_round),
                   key=admit_round)
    tags = ["cold" if r in cold else "hot" for r in after]
    # post-join admissions interleave: neither the caught-up newcomer nor
    # the incumbent may run away with consecutive lanes
    for k in range(1, len(tags) + 1):
        c, h = tags[:k].count("cold"), tags[:k].count("hot")
        assert abs(c - h) <= 2, tags


def test_tenant_weights_shape_admission_order():
    """weight=2 buys two admissions per unit virtual time: in any prefix
    of the admission order the heavy tenant holds at most its share."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 8, seed=6)
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    server.register_tenant("heavy", weight=2.0)
    server.register_tenant("light", weight=1.0)
    rids = {}
    for i in range(8):
        t = "heavy" if i < 4 else "light"
        rids[server.submit(GraphRequest(kind="sssp", source=int(srcs[i]),
                                        graph="g", tenant=t))] = t
    out = server.serve()
    order = sorted(rids, key=lambda r: out[r].stats["queue_wait_rounds"])
    admitted = [rids[r] for r in order]
    for k in range(1, len(admitted) + 1):
        heavy = admitted[:k].count("heavy")
        # 2:1 fair share, +1 slack for the start-time tie
        assert heavy <= (2 * k) // 3 + 1, admitted


# --------------------------------------------------------------- deadlines


def test_deadline_expired_rejected_not_silently_dropped():
    tick = [0.0]
    g = grid2d(8, 8, seed=4)
    server = GraphServer(capacity=2, k_visits=16, clock=lambda: tick[0],
                         autoscaler=None)
    server.register_graph("g", g, num_queries=2, block_size=16)
    srcs = _sources(g, 2, seed=7)
    keep = server.submit(GraphRequest(kind="sssp", source=int(srcs[0]),
                                      graph="g"))
    doomed = server.submit(GraphRequest(kind="sssp", source=int(srcs[1]),
                                        graph="g", deadline_s=5.0))
    tick[0] = 10.0                       # deadline lapses while queued
    out = server.serve()
    assert len(out) == 2                 # both answered — nothing dropped
    assert out[doomed].status == "expired"
    assert out[doomed].values is None
    assert out[doomed].stats["queue_wait_s"] == pytest.approx(10.0)
    assert out[keep].status == "ok" and out[keep].values is not None


def test_deadline_never_expires_admitted_requests():
    """Once a request holds a lane it runs to completion even if its
    deadline lapses mid-flight (rejection is an admission-time decision)."""
    tick = [0.0]
    g = grid2d(8, 8, seed=4)
    server = GraphServer(capacity=1, k_visits=4, clock=lambda: tick[0],
                         autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    rid = server.submit(GraphRequest(kind="sssp",
                                     source=int(_sources(g, 1, seed=8)[0]),
                                     graph="g", deadline_s=5.0))
    assert server.step()                 # admitted at t=0
    tick[0] = 10.0                       # lapses while in flight
    out = server.serve()
    assert out[rid].status == "ok"


# --------------------------------------------------------------- isolation


def test_multi_graph_isolation_no_state_bleed():
    """Interleaved requests against two different-sized graphs: each
    answer has its own graph's shape and matches that graph's one-shot
    run exactly."""
    a, b = grid2d(9, 9, seed=9), grid2d(12, 12, seed=10)    # 81 vs 144
    sa, sb = _sources(a, 3, seed=11), _sources(b, 3, seed=12)
    sess = {"a": FPPSession(a).plan(num_queries=3, block_size=32),
            "b": FPPSession(b).plan(num_queries=3, block_size=32)}
    one = {"a": sess["a"].run("sssp", sa), "b": sess["b"].run("sssp", sb)}
    server = GraphServer(capacity=3, k_visits=8)
    server.register_graph("a", sess["a"])
    server.register_graph("b", sess["b"])
    rids = []
    for i in range(3):
        rids.append(("a", i, server.submit(GraphRequest(
            kind="sssp", source=int(sa[i]), graph="a"))))
        rids.append(("b", i, server.submit(GraphRequest(
            kind="sssp", source=int(sb[i]), graph="b"))))
    out = server.serve()
    for name, i, rid in rids:
        r = out[rid]
        assert r.values.shape == (sess[name].graph.n,)
        np.testing.assert_array_equal(r.values, one[name].values[i])


# ------------------------------------------------- priorities + arbitration


def test_request_priority_picks_pool_first():
    """A more urgent (lower-priority-value) request pulls its pool to the
    front of arbitration even when another pool queued first."""
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 2, seed=13)
    server = GraphServer(capacity=1, k_visits=8, autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=16)
    server.submit(GraphRequest(kind="sssp", source=int(srcs[0]), graph="g"))
    urgent = server.submit(GraphRequest(kind="bfs", source=int(srcs[1]),
                                        graph="g", priority=-1.0))
    server.step()                        # one round serves exactly one pool
    bfs_pool = server._pools[("g", "bfs")]
    sssp_pool = server._pools[("g", "sssp")]
    assert bfs_pool.exec.visits > 0      # urgent pool won arbitration
    assert sssp_pool.exec.visits == 0
    out = server.serve()
    assert out[urgent].status == "ok"


def test_scheduler_prefer_older_ties():
    """The serving tie-break: among priority ties pick the smallest stamp;
    the default contract (first index) is untouched."""
    sched = PartitionScheduler("priority", 3)
    prio = np.array([1.0, 1.0, 2.0], dtype=np.float32)
    stamp = np.array([7, 2, 0], dtype=np.int64)
    ops = np.array([1, 1, 1])
    assert sched.select(prio, stamp, ops) == 0                  # device rule
    assert sched.select(prio, stamp, ops, prefer_older_ties=True) == 1
    # all-empty still returns None either way
    inf = np.full(3, np.inf, dtype=np.float32)
    assert sched.select(inf, stamp, ops, prefer_older_ties=True) is None


# -------------------------------------------------------------- autoscale


def test_autoscale_capacity_hint_is_memory_clamped():
    mem = MemoryModel()
    kw = dict(mem=mem, n_vertices=1024, block_size=64)
    assert autoscale_capacity(0, 0, **kw) == 1           # idle shrinks
    assert autoscale_capacity(5, 1, **kw) == 8           # next pow2 >= 6
    assert autoscale_capacity(100, 0, max_capacity=16, **kw) == 16
    # a tiny VMEM budget caps the suggestion below raw demand
    tiny = MemoryModel(vmem_bytes=(2 * 64 * 64 + 2 * 8 * 64) * 4)
    got = autoscale_capacity(100, 0, mem=tiny, n_vertices=1024,
                             block_size=64)
    assert got <= 8 and tiny.fits(64, got, 1024)


def test_server_grows_pool_capacity_under_backlog():
    g = grid2d(8, 8, seed=4)
    srcs = _sources(g, 6, seed=14)
    server = GraphServer(capacity=1, k_visits=16, max_capacity=8)
    server.register_graph("g", g, num_queries=1, block_size=16)
    rids = [server.submit(GraphRequest(kind="sssp", source=int(s),
                                       graph="g")) for s in srcs]
    out = server.serve()
    assert all(out[r].status == "ok" for r in rids)
    # the backlog of 6 should have pulled capacity up to the next pow2
    assert server._pools[("g", "sssp")].capacity == 8


# ------------------------------------------------------------------ misc


def test_submit_validation_and_empty_serve():
    g = grid2d(6, 6, seed=15)
    server = GraphServer(capacity=2)
    server.register_graph("g", g, num_queries=2, block_size=16)
    with pytest.raises(ValueError):
        server.submit(GraphRequest(kind="dfs", source=0, graph="g"))
    with pytest.raises(ValueError):
        server.submit(GraphRequest(kind="sssp", source=0, graph="nope"))
    with pytest.raises(ValueError):
        server.submit(GraphRequest(kind="sssp", source=g.n, graph="g"))
    with pytest.raises(ValueError):
        server.register_graph("g", g)    # duplicate name
    assert server.serve() == {}          # nothing submitted: clean no-op
    assert server.pending == 0
