"""Hypothesis property tests on the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")  # whole module is property-based
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import oracles
from repro.core.graph import CSRGraph
from repro.core.queries import prepare, run_ppr, run_sssp
from repro.kernels.minplus.ref import minplus_ref
from repro.models.attention import attend
from repro.train.compress import dequantize_int8, quantize_int8

SETTINGS = dict(deadline=None, max_examples=12,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@st.composite
def random_graph(draw):
    n = draw(st.integers(24, 96))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.uniform(1.0, 8.0, m).astype(np.float32)
    keep = src != dst
    return CSRGraph.from_edges(n, src[keep], dst[keep], w[keep],
                               symmetrize=True)


@given(random_graph(), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_sssp_matches_dijkstra_any_graph(g, seed):
    """FPP SSSP == sequential Dijkstra on arbitrary random graphs,
    regardless of the partition layout the graph happens to get."""
    rng = np.random.default_rng(seed)
    srcs = rng.integers(0, g.n, 3)
    bg, perm = prepare(g, 32)
    res = run_sssp(bg, perm[srcs])
    for qi, s in enumerate(srcs):
        want, _ = oracles.dijkstra(g, int(s))
        got = res.values[qi][perm]
        np.testing.assert_allclose(
            np.where(np.isfinite(got), got, -1.0),
            np.where(np.isfinite(want), want, -1.0), rtol=1e-5)


@given(random_graph(), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_ppr_mass_is_conserved(g, seed):
    """p_total + r_total == 1 per query at every point of the push process
    (the buffered execution must not create or destroy probability mass)."""
    rng = np.random.default_rng(seed)
    srcs = rng.integers(0, g.n, 2)
    bg, perm = prepare(g, 32)
    res = run_ppr(bg, perm[srcs], eps=1e-3)
    deg = g.out_degree()
    for qi in range(len(srcs)):
        p = res.values[qi]
        r = res.residual[qi]
        total = float(p.sum() + r.sum())
        # dangling vertices (deg 0) absorb their residual; with symmetrize
        # there are none reachable, so mass is conserved
        np.testing.assert_allclose(total, 1.0, atol=1e-3)


@given(st.integers(0, 2 ** 16), st.integers(1, 4), st.integers(8, 32))
@settings(**SETTINGS)
def test_minplus_is_monotone_and_dominated(seed, q, b):
    """min-plus relaxation never increases distances and is dominated by
    any single-edge path."""
    rng = np.random.default_rng(seed)
    d = jnp.asarray(np.where(rng.random((q, b)) < 0.3, np.inf,
                             rng.uniform(0, 10, (q, b))), jnp.float32)
    w = jnp.asarray(np.where(rng.random((b, b)) < 0.7, np.inf,
                             rng.uniform(0, 5, (b, b))), jnp.float32)
    out = np.asarray(minplus_ref(d, w))
    dn, wn = np.asarray(d), np.asarray(w)
    for qi in range(min(q, 2)):
        for v in range(min(b, 8)):
            want = np.min(dn[qi] + wn[:, v])
            assert out[qi, v] == np.float32(want) or \
                np.isclose(out[qi, v], want, rtol=1e-6)


@given(st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_attend_matches_dense_softmax(seed):
    rng = np.random.default_rng(seed)
    B, S, H, hd = 2, 24, 4, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, 2, hd)), jnp.float32)
    pos = jnp.arange(S)
    got = attend(q, k, v, pos, pos, causal=True, chunk=8)
    # dense reference
    kr = jnp.repeat(k, 2, axis=2)
    vr = jnp.repeat(v, 2, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(hd)
    mask = pos[None, :] <= pos[:, None]
    s = jnp.where(mask[None, None], s, -1e9)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
@settings(**SETTINGS)
def test_quantize_bounds(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q, s = quantize_int8(x)
    err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - x)))
    assert err <= float(s) * 0.5 + 1e-6


_FUSED = {}


def _fused_fixture(mode):
    """One engine + fused-visit closure per mode, shared across examples so
    hypothesis varies data, not compilations (shapes stay fixed)."""
    if mode not in _FUSED:
        from repro.core.engine import FPPEngine
        from repro.graphs.generators import grid2d, rmat
        from repro.kernels.frontier.ops import frontier_tile
        from repro.kernels.fused_visit.ops import make_fused_visit
        from repro.kernels.ppr_push.ops import push_tile
        g = grid2d(10, 10, seed=1) if mode == "minplus" else rmat(7, 5,
                                                                  seed=3)
        bg, perm = prepare(g, 32)
        eng = FPPEngine(bg, mode=mode, num_queries=3, fused=True,
                        k_visits=8, eps=1e-3)
        fv = make_fused_visit(eng.dg, eng.algebra, eng.max_rounds,
                              frontier=frontier_tile, push=push_tile)
        _FUSED[mode] = (g, perm, eng, fv)
    return _FUSED[mode]


@given(st.sampled_from(["minplus", "push"]), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_fused_visit_idempotent_on_converged_partitions(mode, seed):
    """Visiting a converged (+inf priority) partition never changes the
    value plane, processes zero edges and zero rounds, and keeps the
    priority empty; and the visit is a bitwise fixed point from the second
    application on.  The first application may only *consolidate* inert
    buffered state — minplus garbage-collects dominated ops (finite buf
    entries above the current distances), push folds sub-threshold
    residual mass from the buffer into r (the ACL terminal condition),
    conserving total mass — neither is visible to the values, the
    priority, or the edge counters.  Convergence is reached by running the
    fused engine itself, so the metadata handed to the kernel is exactly
    what a real run leaves."""
    g, perm, eng, fv = _fused_fixture(mode)
    rng = np.random.default_rng(seed)
    deg = g.out_degree()
    srcs = rng.choice(np.flatnonzero(deg > 0), 3, replace=False)
    state = eng.init_state(perm[srcs])
    key = jax.random.PRNGKey(0)
    counter, limit = 0, eng.k_visits
    for _ in range(10_000):
        state, ms = eng._megastep(state, jnp.int32(counter),
                                  jnp.int32(limit), key)
        v = int(ms.visits)
        counter += v
        if v < limit:
            break
    assert not np.isfinite(np.asarray(state.prio)).any()  # converged
    pk = fv.pack(state.planes, state.buf, state.prio, state.ops_count,
                 state.stamp)
    for p in range(eng.dg.num_parts):
        pk1, rounds, eq = fv.visit(pk, jnp.int32(p), jnp.int32(counter))
        assert int(rounds) == 0
        assert int(np.asarray(eq).sum()) == 0
        planes1, buf1, prio1, _, _ = fv.unpack(pk1)
        assert not np.isfinite(np.asarray(prio1)).any()
        np.testing.assert_array_equal(np.asarray(state.planes[0]),
                                      np.asarray(planes1[0]))
        if mode == "minplus":
            for a, b in zip(state.planes, planes1):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            P = eng.dg.num_parts
            mass0 = sum(np.asarray(x, np.float64).sum()
                        for x in (*state.planes, state.buf[:P]))
            mass1 = sum(np.asarray(x, np.float64).sum()
                        for x in (*planes1, buf1[:P]))
            np.testing.assert_allclose(mass1, mass0, atol=1e-5)
        # second application: a bitwise fixed point of the whole packed
        # state, scheduler metadata included
        pk2, rounds2, eq2 = fv.visit(pk1, jnp.int32(p), jnp.int32(counter))
        assert int(rounds2) == 0
        assert int(np.asarray(eq2).sum()) == 0
        np.testing.assert_array_equal(np.asarray(pk1.state),
                                      np.asarray(pk2.state))
        np.testing.assert_array_equal(np.asarray(pk1.meta),
                                      np.asarray(pk2.meta))


@given(st.integers(1, 200), st.sampled_from([8, 16, 64]),
       st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_pad_q_identity_padding_is_invisible(q, q_tile, seed):
    """``_pad_q`` pads the query axis with the mode identity so the kernel
    can demand exact tile divisibility; at ANY Q — divisible or not — the
    padded rows must be inert: min-plus bitwise equal to the unpadded ref
    (+inf sources contribute only +inf candidates), the masked matmul
    row-independent (zero rows spread nothing)."""
    from repro.kernels.minplus.ops import (masked_matmul_pallas,
                                           minplus_pallas)
    from repro.kernels.minplus.ref import masked_matmul_ref
    rng = np.random.default_rng(seed)
    b = 32
    d = jnp.asarray(np.where(rng.random((q, b)) < 0.4, np.inf,
                             rng.uniform(0, 9, (q, b))), jnp.float32)
    w = jnp.asarray(np.where(rng.random((b, b)) < 0.7, np.inf,
                             rng.uniform(0, 5, (b, b))), jnp.float32)
    got = minplus_pallas(d, w, q_tile=q_tile)
    want = minplus_ref(d, w)
    assert got.shape == (q, b)
    np.testing.assert_array_equal(
        np.nan_to_num(np.asarray(got), posinf=1e30),
        np.nan_to_num(np.asarray(want), posinf=1e30))
    x = jnp.asarray(rng.uniform(0, 1, (q, b)), jnp.float32)
    got_mm = masked_matmul_pallas(x, w, q_tile=q_tile)
    assert got_mm.shape == (q, b)
    np.testing.assert_allclose(np.asarray(got_mm),
                               np.asarray(masked_matmul_ref(x, w)),
                               atol=1e-6)


@given(random_graph())
@settings(**SETTINGS)
def test_schedule_policies_agree_on_results(g):
    """All four scheduling policies produce identical SSSP distances —
    scheduling affects work, never correctness (paper §5)."""
    rng = np.random.default_rng(0)
    srcs = rng.integers(0, g.n, 2)
    bg, perm = prepare(g, 32)
    outs = {}
    for pol in ("priority", "fifo", "random", "max_ops"):
        res = run_sssp(bg, perm[srcs], schedule=pol)
        outs[pol] = np.where(np.isfinite(res.values), res.values, -1.0)
    base = outs["priority"]
    for pol, v in outs.items():
        np.testing.assert_allclose(v, base, rtol=1e-5, err_msg=pol)
