"""Hypothesis property tests on the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")  # whole module is property-based
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import oracles
from repro.core.graph import CSRGraph
from repro.core.queries import prepare, run_ppr, run_sssp
from repro.kernels.minplus.ref import minplus_ref
from repro.models.attention import attend
from repro.train.compress import dequantize_int8, quantize_int8

SETTINGS = dict(deadline=None, max_examples=12,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@st.composite
def random_graph(draw):
    n = draw(st.integers(24, 96))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.uniform(1.0, 8.0, m).astype(np.float32)
    keep = src != dst
    return CSRGraph.from_edges(n, src[keep], dst[keep], w[keep],
                               symmetrize=True)


@given(random_graph(), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_sssp_matches_dijkstra_any_graph(g, seed):
    """FPP SSSP == sequential Dijkstra on arbitrary random graphs,
    regardless of the partition layout the graph happens to get."""
    rng = np.random.default_rng(seed)
    srcs = rng.integers(0, g.n, 3)
    bg, perm = prepare(g, 32)
    res = run_sssp(bg, perm[srcs])
    for qi, s in enumerate(srcs):
        want, _ = oracles.dijkstra(g, int(s))
        got = res.values[qi][perm]
        np.testing.assert_allclose(
            np.where(np.isfinite(got), got, -1.0),
            np.where(np.isfinite(want), want, -1.0), rtol=1e-5)


@given(random_graph(), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_ppr_mass_is_conserved(g, seed):
    """p_total + r_total == 1 per query at every point of the push process
    (the buffered execution must not create or destroy probability mass)."""
    rng = np.random.default_rng(seed)
    srcs = rng.integers(0, g.n, 2)
    bg, perm = prepare(g, 32)
    res = run_ppr(bg, perm[srcs], eps=1e-3)
    deg = g.out_degree()
    for qi in range(len(srcs)):
        p = res.values[qi]
        r = res.residual[qi]
        total = float(p.sum() + r.sum())
        # dangling vertices (deg 0) absorb their residual; with symmetrize
        # there are none reachable, so mass is conserved
        np.testing.assert_allclose(total, 1.0, atol=1e-3)


@given(st.integers(0, 2 ** 16), st.integers(1, 4), st.integers(8, 32))
@settings(**SETTINGS)
def test_minplus_is_monotone_and_dominated(seed, q, b):
    """min-plus relaxation never increases distances and is dominated by
    any single-edge path."""
    rng = np.random.default_rng(seed)
    d = jnp.asarray(np.where(rng.random((q, b)) < 0.3, np.inf,
                             rng.uniform(0, 10, (q, b))), jnp.float32)
    w = jnp.asarray(np.where(rng.random((b, b)) < 0.7, np.inf,
                             rng.uniform(0, 5, (b, b))), jnp.float32)
    out = np.asarray(minplus_ref(d, w))
    dn, wn = np.asarray(d), np.asarray(w)
    for qi in range(min(q, 2)):
        for v in range(min(b, 8)):
            want = np.min(dn[qi] + wn[:, v])
            assert out[qi, v] == np.float32(want) or \
                np.isclose(out[qi, v], want, rtol=1e-6)


@given(st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_attend_matches_dense_softmax(seed):
    rng = np.random.default_rng(seed)
    B, S, H, hd = 2, 24, 4, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, 2, hd)), jnp.float32)
    pos = jnp.arange(S)
    got = attend(q, k, v, pos, pos, causal=True, chunk=8)
    # dense reference
    kr = jnp.repeat(k, 2, axis=2)
    vr = jnp.repeat(v, 2, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(hd)
    mask = pos[None, :] <= pos[:, None]
    s = jnp.where(mask[None, None], s, -1e9)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
@settings(**SETTINGS)
def test_quantize_bounds(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q, s = quantize_int8(x)
    err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - x)))
    assert err <= float(s) * 0.5 + 1e-6


@given(random_graph())
@settings(**SETTINGS)
def test_schedule_policies_agree_on_results(g):
    """All four scheduling policies produce identical SSSP distances —
    scheduling affects work, never correctness (paper §5)."""
    rng = np.random.default_rng(0)
    srcs = rng.integers(0, g.n, 2)
    bg, perm = prepare(g, 32)
    outs = {}
    for pol in ("priority", "fifo", "random", "max_ops"):
        res = run_sssp(bg, perm[srcs], schedule=pol)
        outs[pol] = np.where(np.isfinite(res.values), res.values, -1.0)
    base = outs["priority"]
    for pol, v in outs.items():
        np.testing.assert_allclose(v, base, rtol=1e-5, err_msg=pol)
