"""The committed SNAP-style fixture and what rides on it.

``tests/data/snap_tiny.txt.gz`` is the one graph in the repo that is
*data*, not a generator: sparse 64-bit vertex ids, tab-separated integer
weights, comment header, hub-heavy degree tail.  These tests pin

  * the ingestion path — ``build_suite("snap-tiny")`` goes through
    ``graphs.io.load_edge_list`` (id compaction, weight parsing) and
    lands on the exact committed shape;
  * the new kinds on really-ingested data — cc / kreach / rw match their
    sequential oracles on the fixture, not just on generator graphs;
  * degree-aware partition sizing — the planner's ``est_dmax`` guard
    (DESIGN.md §3.1) picks a smaller block size than the degree-blind
    model when hubs would drag a mega-neighborhood through VMEM;
  * the fig9 bench path CI runs — ``fig9_overall.run(graphs=[fixture])``
    produces well-formed BC/LL/NCP rows.
"""
import numpy as np
import pytest

from repro.core import oracles
from repro.core.graph import CSRGraph
from repro.fpp.planner import MemoryModel, est_dmax, model_block_size
from repro.fpp.session import FPPSession
from repro.graphs.generators import build_suite


@pytest.fixture(scope="module")
def fixture_graph():
    return build_suite("snap-tiny")


@pytest.fixture(scope="module")
def fixture_sess(fixture_graph):
    return FPPSession(fixture_graph).plan(num_queries=4, block_size=64)


def test_fixture_loads_to_committed_shape(fixture_graph):
    """The committed bytes parse to exactly this graph — a change here
    means the fixture file was regenerated, which must be deliberate."""
    g = fixture_graph
    assert (g.n, g.m) == (960, 4822)
    deg = g.out_degree()
    # the hub tail the degree-aware planner exists for
    assert deg.max() >= 40 * max(1.0, deg.mean())
    # text weights: integers 1..9, parsed not defaulted
    assert set(np.unique(g.weights)) <= set(float(x) for x in range(1, 10))
    assert len(np.unique(g.weights)) > 1


def test_fixture_unweighted_view(fixture_graph):
    gu = build_suite("snap-tiny", weighted=False)
    assert (gu.n, gu.m) == (fixture_graph.n, fixture_graph.m)
    assert np.all(gu.weights == 1.0)


def test_fixture_cc_matches_union_find(fixture_sess):
    want = oracles.connected_components(fixture_sess.graph)
    r = fixture_sess.run("cc", np.array([0, 7, 500]))
    for q in range(3):
        assert np.array_equal(r.values[q], want.astype(np.float32))


def test_fixture_kreach_matches_dijkstra(fixture_sess):
    srcs = np.array([3, 411])
    r = fixture_sess.run("kreach", srcs, k=3)
    for q, s in enumerate(srcs):
        vals, hops, _ = oracles.kreach(fixture_sess.graph, int(s), 3,
                                       stride=fixture_sess.kreach_stride)
        assert np.array_equal(r.values[q], vals)
        assert np.array_equal(r.residual[q], hops)


def test_fixture_rw_replays_host_tape(fixture_sess):
    srcs = np.array([5, 902])
    r = fixture_sess.run("rw", srcs, length=10, seed=3)
    bg, perm = fixture_sess.prepared()
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    for q, s in enumerate(srcs):
        posns = oracles.random_walk(bg, int(perm[s]), 10, seed=3)
        occ = np.zeros(fixture_sess.graph.n, np.float32)
        for p in posns:
            occ[inv[p]] += 1.0
        assert np.array_equal(r.values[q], occ)


# --------------------------------------------- degree-aware partition sizing


def _star(n=4097):
    hub = np.zeros(n - 1, np.int64)
    leaves = np.arange(1, n, dtype=np.int64)
    return CSRGraph.from_edges(n, hub, leaves, symmetrize=True)


def test_est_dmax_sees_hubs():
    g = _star()
    # the hub's edges alone span ~every partition; a uniform ring doesn't
    assert est_dmax(g, 256) >= 8
    ring = CSRGraph.from_edges(
        4096, np.arange(4096, dtype=np.int64),
        (np.arange(4096, dtype=np.int64) + 1) % 4096, symmetrize=True)
    assert est_dmax(ring, 256) <= est_dmax(g, 256)


def test_degree_aware_sizing_shrinks_blocks_on_hub_graphs():
    """On a star, the degree-blind model picks the largest B whose visit
    working set fits; the degree-aware guard must reject candidates whose
    hub *neighborhood* (diagonal + est_dmax boundary blocks) outgrows the
    same VMEM budget and land on a smaller B."""
    g = _star()
    mem = MemoryModel(vmem_bytes=4 * 1024 * 1024)
    blind = model_block_size(g, 8, mem, degree_aware=False)
    aware = model_block_size(g, 8, mem, degree_aware=True)
    assert aware < blind
    # the guard's own arithmetic: the chosen B keeps the neighborhood in
    # budget, the rejected one does not
    assert (1 + est_dmax(g, aware)) * aware * aware * 4 <= mem.vmem_bytes
    assert (1 + est_dmax(g, blind)) * blind * blind * 4 > mem.vmem_bytes


def test_degree_aware_is_noop_on_uniform_graphs(fixture_graph):
    """At the default (large) VMEM budget the guard never binds — even on
    the hub-tailed fixture — so existing plans are unchanged."""
    mem = MemoryModel()
    assert model_block_size(fixture_graph, 8, mem, degree_aware=True) == \
        model_block_size(fixture_graph, 8, mem, degree_aware=False)


# ---------------------------------------------------------- fig9 bench path


def test_fig9_runs_on_the_fixture():
    """The CI bench step runs fig9 quick, whose sweep starts with the
    fixture; pin the row contract on the fixture alone so a fixture or
    session regression fails here, not in a bench artifact."""
    from benchmarks.fig9_overall import COLUMNS, run
    rows = run(quick=True, graphs=["snap-tiny"])
    assert [r["app"] for r in rows] == ["BC", "LL", "NCP"]
    for r in rows:
        assert r["graph"] == "snap-tiny"
        assert set(COLUMNS) <= set(r) | {"max_err"}
        assert r["forkgraph_s"] > 0 and r["baseline_s"] > 0
    # landmark labeling is exact vs the synchronous baseline
    assert rows[1]["max_err"] == 0.0
