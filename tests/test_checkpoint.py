"""Fault tolerance: checkpoint integrity, atomic commit, bitwise resume."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.shapes import ShapeConfig
from repro.models.factory import build_model
from repro.train import checkpoint as ck
from repro.train.data import batch_for_step
from repro.train.loop import LoopConfig, run_loop
from repro.train.optimizer import AdamW, constant
from repro.train.train_step import init_train_state, make_train_step

CFG = get_config("starcoder2-7b").reduced()
SHAPE = ShapeConfig("t", "train", 32, 4)


def _state():
    return init_train_state(build_model(CFG), jax.random.PRNGKey(0),
                            AdamW())


def test_roundtrip(tmp_path):
    state = _state()
    ck.save(str(tmp_path), 3, state, extra={"note": "hi"})
    got, step, extra = ck.restore(str(tmp_path), target=state)
    assert step == 3 and extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_crc_detects_corruption(tmp_path):
    state = _state()
    path = ck.save(str(tmp_path), 1, state)
    # corrupt one leaf file
    files = [f for f in os.listdir(path) if f.endswith(".npy")]
    victim = os.path.join(path, sorted(files)[0])
    with open(victim, "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(IOError, match="CRC"):
        ck.restore(str(tmp_path), target=state)


def test_interrupted_write_leaves_previous_checkpoint(tmp_path):
    state = _state()
    ck.save(str(tmp_path), 1, state)
    # simulate a writer killed mid-save: stray tmp dir with partial files
    tmp_dir = os.path.join(str(tmp_path), "tmp.2")
    os.makedirs(tmp_dir)
    with open(os.path.join(tmp_dir, "partial.npy"), "wb") as f:
        f.write(b"garbage")
    assert ck.latest_step(str(tmp_path)) == 1
    got, step, _ = ck.restore(str(tmp_path), target=state)
    assert step == 1


def test_missing_leaf_raises(tmp_path):
    state = _state()
    ck.save(str(tmp_path), 1, {"only": jnp.zeros(3)})
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), target=state)


def test_async_checkpointer_gc(tmp_path):
    acp = ck.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(8)}
    for s in (1, 2, 3, 4):
        acp.save(s, tree)
        acp.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_bitwise_resume_after_failure(tmp_path):
    model = build_model(CFG)
    opt = AdamW()
    ts = jax.jit(make_train_step(model, opt, constant(3e-3)),
                 donate_argnums=0)
    data = lambda s: batch_for_step(CFG, SHAPE, s)   # noqa: E731
    full, _ = run_loop(ts, _state(), data,
                       LoopConfig(n_steps=8, ckpt_dir=None,
                                  log_every=100), log=lambda *a: None)

    class Boom(Exception):
        pass

    def fault(step):
        if step == 6:
            raise Boom()

    lc = LoopConfig(n_steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                    log_every=100)
    with pytest.raises(Boom):
        run_loop(ts, _state(), data, lc, log=lambda *a: None,
                 fault_hook=fault)
    resumed, stats = run_loop(ts, _state(), data, lc,
                              log=lambda *a: None)
    assert stats.restored_step == 4
    for a, b in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(resumed.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_resharding_restore_dtype_cast(tmp_path):
    """A checkpoint restores onto a target with different leaf dtype
    (elastic re-mesh writes/restores through host arrays)."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    ck.save(str(tmp_path), 1, tree)
    target = {"w": jax.ShapeDtypeStruct((16,), jnp.bfloat16)}
    got, _, _ = ck.restore(str(tmp_path), target=target)
    assert got["w"].dtype == jnp.bfloat16
