"""Application-level tests: BC, LL, NCP, RW, baselines, schedulers."""
import numpy as np
import pytest

from repro.core import applications as apps, oracles
from repro.core.baselines import global_minplus, global_push
from repro.core.partition import edge_cut_fraction, partition
from repro.core.queries import prepare, run_rw, run_sssp
from repro.core.scheduler import PartitionScheduler
from repro.core.yielding import YieldConfig
from repro.graphs.generators import build_suite, grid2d, rmat


def _brandes_oracle(g, sources):
    bc = np.zeros(g.n)
    for s in sources:
        dist, sigma, _ = oracles.bfs_sigma(g, int(s))
        sig, delta = apps._sigma_delta(g, dist)
        np.testing.assert_allclose(sig, sigma)
        delta[s] = 0.0
        bc += delta
    return bc


def test_bc_matches_brandes():
    g = rmat(7, 4, seed=0, weighted=False)
    srcs = np.array([0, 17, 90, 111])
    want = _brandes_oracle(g, srcs)
    got, _ = apps.betweenness_centrality(g, srcs, block_size=32)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_bc_star_graph_analytic():
    """Star: all shortest paths between leaves pass the hub."""
    from repro.core.graph import CSRGraph
    n = 9
    hub = 0
    src = [hub] * (n - 1)
    dst = list(range(1, n))
    g = CSRGraph.from_edges(n, src, dst, symmetrize=True)
    srcs = np.arange(n)
    got, _ = apps.betweenness_centrality(g, srcs, block_size=4)
    # each of the (n-1)(n-2) ordered leaf pairs contributes 1 to the hub
    assert got[hub] == pytest.approx((n - 1) * (n - 2))
    assert np.allclose(got[1:], 0.0)


def test_landmark_labels_triangle_inequality():
    g = grid2d(10, 10, seed=1)
    lms = np.array([0, 55, 99])
    labels, _ = apps.landmark_labeling(g, lms, block_size=32)
    for i, s in enumerate(lms):
        d_or, _ = oracles.dijkstra(g, int(s))
        np.testing.assert_allclose(
            np.nan_to_num(labels.dists[i], posinf=1e30),
            np.nan_to_num(d_or, posinf=1e30), atol=1e-3)
    # landmark estimate is an upper bound on true distance
    rng = np.random.default_rng(0)
    us = rng.choice(g.n, 10)
    vs = rng.choice(g.n, 10)
    est = labels.query(us, vs)
    for u, v, e in zip(us, vs, est):
        d_or, _ = oracles.dijkstra(g, int(u))
        assert e >= d_or[v] - 1e-3


def test_ncp_conductance_valid():
    g = rmat(8, 6, seed=2)
    seeds = np.array([1, 50, 200])
    best, _ = apps.ncp(g, seeds, block_size=64, eps=1e-4)
    finite = best[np.isfinite(best)]
    assert finite.size > 0
    assert (finite >= 0).all() and (finite <= 1.0 + 1e-6).all()


def test_sweep_conductance_whole_graph_is_zero_cut():
    g = grid2d(6, 6, seed=3)
    p = np.ones(g.n)  # whole graph in support
    sizes, cond = apps.sweep_conductance(g, p)
    # the full set has cut 0 but denominator 0 -> inf; the half set is finite
    assert sizes[-1] == g.n
    assert np.isfinite(cond[: g.n // 2]).any()


def test_random_walks_complete_and_deterministic():
    g = rmat(7, 6, seed=4, weighted=False)
    bg, perm = prepare(g, 32, unit_weights=True)
    deg = g.out_degree()
    srcs = perm[np.random.default_rng(1).choice(
        np.flatnonzero(deg > 0), 8, replace=False)]
    r1 = run_rw(bg, srcs, length=12, seed=7)
    r2 = run_rw(bg, srcs, length=12, seed=7)
    assert (r1.steps == 12).all()
    assert (r1.trajectory_hash == r2.trajectory_hash).all()  # deterministic
    # positions are real vertices
    assert (r1.positions < bg.n_padded).all()


def test_baseline_global_minplus_exact():
    g = build_suite("road-ca", seed=0)
    # subsample for speed: use smaller instance
    g = grid2d(14, 14, seed=0)
    bg, perm = partition(g, 32)
    srcs = np.array([0, 50, 170])
    bl = global_minplus(bg, perm[srcs])
    for qi, s in enumerate(srcs):
        d_or, _ = oracles.dijkstra(g, int(s))
        np.testing.assert_allclose(
            np.nan_to_num(bl.values[qi][perm], posinf=1e30),
            np.nan_to_num(d_or, posinf=1e30), atol=1e-3)


def test_baseline_global_push_invariants():
    g = rmat(7, 6, seed=5)
    bg, perm = partition(g, 32)
    deg = g.out_degree()
    srcs = np.random.default_rng(2).choice(np.flatnonzero(deg > 0), 3,
                                           replace=False)
    bl = global_push(bg, perm[srcs], eps=1e-4)
    assert (bl.edges_processed > 0).all()
    assert bl.modeled_bytes >= bl.modeled_bytes_shared


def test_forkgraph_traffic_below_uncoordinated_baseline():
    """The paper's headline: buffered execution cuts memory traffic (Fig 10)."""
    g = grid2d(24, 24, seed=6)
    bg, perm = partition(g, 32)
    srcs = perm[np.random.default_rng(3).choice(g.n, 8, replace=False)]
    res = run_sssp(bg, srcs, yield_config=YieldConfig(delta=4.0))
    bl = global_minplus(bg, srcs)
    assert res.stats.modeled_bytes < bl.modeled_bytes


def test_scheduler_policies_select_validly():
    s = PartitionScheduler("priority", 4)
    prio = np.array([np.inf, 3.0, 1.0, np.inf], np.float32)
    stamp = np.array([9, 5, 7, 9], np.int32)
    ops = np.array([0, 2, 1, 0], np.int32)
    assert s.select(prio, stamp, ops) == 2
    assert PartitionScheduler("fifo", 4).select(prio, stamp, ops) == 1
    assert PartitionScheduler("max_ops", 4).select(prio, stamp, ops) == 1
    assert PartitionScheduler("random", 4).select(prio, stamp, ops) in (1, 2)
    done = np.full(4, np.inf, np.float32)
    assert s.select(done, stamp, ops) is None


def test_priority_schedule_no_worse_work_than_random_on_road():
    """Table 4A's direction: priority <= random on road-like graphs."""
    g = grid2d(20, 20, seed=7)
    bg, perm = partition(g, 32)
    srcs = perm[np.array([0, 399, 210, 25])]
    yc = YieldConfig(delta=2.0)
    w_pri = run_sssp(bg, srcs, yield_config=yc,
                     schedule="priority").edges_processed.sum()
    w_rnd = run_sssp(bg, srcs, yield_config=yc,
                     schedule="random").edges_processed.sum()
    assert w_pri <= w_rnd * 1.2  # allow noise; typically much lower


def test_partition_bfs_beats_random_cut_on_grid():
    g = grid2d(20, 20, seed=8)
    bg_bfs, _ = partition(g, 32, method="bfs")
    bg_rnd, _ = partition(g, 32, method="random")
    assert edge_cut_fraction(bg_bfs) < edge_cut_fraction(bg_rnd)
