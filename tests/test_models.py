"""Per-arch smoke tests (deliverable f) + serve-path consistency.

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU asserting output shapes + no NaNs; the serve
families additionally check prefill+decode == teacher-forced forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.configs.shapes import SHAPES, reduced_shape
from repro.models.factory import build_model, input_specs
from repro.train.data import DataConfig, batch_for_step

ARCHS = list_configs()


def _batch_for(cfg, seq=24, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    S_text = seq - (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, S_text)),
                               jnp.int32)}
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch, S_text)),
                              jnp.int32)
    b["loss_mask"] = jnp.ones((batch, S_text), jnp.float32)
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.asarray(
            0.1 * rng.normal(size=(batch, cfg.num_image_tokens,
                                   cfg.d_model)), cfg.cdtype)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            0.1 * rng.normal(size=(batch, 1500, cfg.d_model)), cfg.cdtype)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    # axes tree matches params tree structure
    assert (jax.tree.structure(jax.tree.map(lambda x: 0, params))
            == jax.tree.structure(jax.tree.map(
                lambda t: 0, axes,
                is_leaf=lambda x: isinstance(x, tuple)
                and all(isinstance(e, (str, type(None))) for e in x))))
    batch = _batch_for(cfg)
    logits, aux = model.logits(params, batch, remat=False)
    B, S_lab = batch["labels"].shape
    assert logits.shape[:2] == (B, S_lab)
    assert logits.shape[2] >= cfg.vocab
    assert np.isfinite(np.asarray(logits)).all()
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    # one SGD-free gradient exists and is finite
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_reduces_loss(arch):
    from repro.train.optimizer import AdamW, constant
    from repro.train.train_step import init_train_state, make_train_step
    from repro.configs.shapes import ShapeConfig
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    opt = AdamW()
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    shape = ShapeConfig("t", "train", 24, 2)
    ts = jax.jit(make_train_step(model, opt, constant(3e-3)))
    losses = []
    for step in range(6):
        state, m = ts(state, batch_for_step(cfg, shape, step,
                                            DataConfig(seed=3)))
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    import dataclasses
    # f32 compute: the check is then strict equivalence (bf16 exposes only
    # reorder noise); MoE runs dropless — capacity drops are legitimately
    # sequence-length-dependent (Switch semantics)
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S, T = 2, 16, 9
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            0.1 * rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)),
            cfg.cdtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            0.1 * rng.normal(size=(B, 1500, cfg.d_model)), cfg.cdtype)
    logits_fwd, _ = model.logits(params, batch, remat=False)
    pre = dict(batch)
    pre["tokens"] = tokens[:, :T]
    max_len = S + 4 + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    last, state = model.prefill(params, pre, max_len=max_len)
    errs = [float(jnp.max(jnp.abs(last - logits_fwd[:, T - 1])))]
    for t in range(T, S):
        lg, state = model.decode(params, tokens[:, t:t + 1], state)
        errs.append(float(jnp.max(jnp.abs(lg - logits_fwd[:, t]))))
    assert max(errs) < 1e-3, errs


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    assert "tokens" in specs
    B = shape.global_batch
    assert specs["tokens"].shape[0] == B
    if shape.kind == "decode":
        assert specs["tokens"].shape == (B, 1)
        st = build_model(cfg).decode_state_specs(B, shape.seq_len)
        leaves = jax.tree.leaves(st)
        assert leaves, "decode state must be non-empty"


def test_moe_capacity_drops_are_bounded():
    import dataclasses
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, seq=32)
    # higher capacity factor must not reduce quality drastically
    lo, _ = model.loss(params, batch)
    cfg_hi = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    hi, _ = build_model(cfg_hi).loss(params, batch)
    assert np.isfinite(float(lo)) and np.isfinite(float(hi))


def test_vlm_prefix_is_bidirectional():
    """Image-prefix positions must see each other (prefix-LM mask)."""
    cfg = get_config("paligemma-3b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P = 1, cfg.num_image_tokens
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)), jnp.int32)
    img = jnp.asarray(0.1 * rng.normal(size=(B, P, cfg.d_model)),
                      cfg.cdtype)
    base, _ = model.logits(params, {"tokens": tokens,
                                    "image_embeds": img}, remat=False)
    # changing the LAST image patch must change the logits at text pos 0
    img2 = img.at[:, -1].add(1.0)
    pert, _ = model.logits(params, {"tokens": tokens,
                                    "image_embeds": img2}, remat=False)
    assert float(jnp.max(jnp.abs(base[:, 0] - pert[:, 0]))) > 0
