"""Elastic re-mesh: checkpoint on mesh A, resume on mesh B, identical run.

Subprocess (needs 8 fake devices before jax init)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import compat_make_mesh, set_mesh
    from repro.configs.base import get_config
    from repro.configs.shapes import ShapeConfig
    from repro.models.factory import build_model
    from repro.launch.elastic import reshard_restore
    from repro.launch.steps import rules_for
    from repro.train import checkpoint as ck
    from repro.train.data import batch_for_step
    from repro.train.optimizer import AdamW, constant
    from repro.train.train_step import (init_train_state, make_train_step,
                                        state_shardings, batch_shardings)

    cfg = get_config("qwen2-72b").reduced()
    shape = ShapeConfig("t", "train", 32, 4)
    model = build_model(cfg)
    opt = AdamW()
    data = lambda s: batch_for_step(cfg, shape, s)

    def run_steps(state, mesh, rules, n, start):
        ts = make_train_step(model, opt, constant(1e-3), rules=rules)
        if mesh is not None:
            with set_mesh(mesh):
                ts = jax.jit(ts)
                for s in range(start, start + n):
                    state, m = ts(state, data(s))
        else:
            ts = jax.jit(ts)
            for s in range(start, start + n):
                state, m = ts(state, data(s))
        return state, float(m["loss"])

    # reference: 6 steps on one device
    ref, ref_loss = run_steps(
        init_train_state(model, jax.random.PRNGKey(0), opt), None, None,
        6, 0)

    # elastic: 3 steps on mesh (2,4), checkpoint, resume 3 on mesh (4,2)
    meshA = compat_make_mesh((2, 4), ("data", "model"))
    meshB = compat_make_mesh((4, 2), ("data", "model"))
    rulesA = rules_for(cfg, meshA)
    stA, _ = run_steps(init_train_state(model, jax.random.PRNGKey(0), opt),
                       meshA, rulesA, 3, 0)
    tmp = tempfile.mkdtemp()
    ck.save(tmp, 3, stA)
    stB, rulesB, step = reshard_restore(tmp, cfg, meshB)
    assert step == 3
    # restored leaves live on meshB shardings
    leaf = jax.tree.leaves(stB.params)[0]
    assert leaf.sharding.mesh.devices.shape == (4, 2), leaf.sharding
    stB, lossB = run_steps(stB, meshB, rulesB, 3, 3)

    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(ref.params),
                            jax.tree.leaves(stB.params)))
    print("elastic remesh param delta:", d, "loss", ref_loss, lossB)
    # bf16 reduction orders differ across meshes; 6 steps amplify to ~7e-3
    assert d < 2e-2, d
    assert abs(ref_loss - lossB) < 5e-2
    print("ALL OK")
""")


@pytest.mark.slow
def test_elastic_remesh_roundtrip():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL OK" in out.stdout
