"""fppcheck seeded-violation tests (DESIGN.md §7).

Each pass family is fed a deliberately broken input and must catch it:
an injected io_callback inside a while body, an f64 promotion, an
oversized BlockSpec, a reintroduced bare assert, a budget-exceeding
metric row.  The clean-repo integration tests then pin that the *real*
tree stays green — the same invariant CI's analysis job enforces.
"""
import pathlib
import textwrap

import numpy as np
import pytest

from repro.analysis import Finding, PassContext, Report, repo_root, run_passes
from repro.analysis.ast_passes import check_asserts, check_host_jnp_loops
from repro.analysis.hlo_passes import check_row
from repro.analysis.pallas_passes import check_contract
from repro.kernels.contract import KernelContract, TileSpec

ROOT = repo_root()


def _mini_repo(tmp_path: pathlib.Path) -> pathlib.Path:
    """A minimal repo skeleton the path-scanning passes can run over."""
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    (tmp_path / "DESIGN.md").write_text(
        "# design\n\n## §1 Overview\n\nbody\n\n## §2 Engine\n\nbody\n")
    (tmp_path / "README.md").write_text(
        "# readme\n\n## Repo map\n\n| path | role |\n|---|---|\n"
        "| `src/repro/core/` | core |\n\n## Next\n\nnothing\n")
    return tmp_path


# ------------------------------------------------------------------- ast


def test_bare_assert_caught_and_escape_respected(tmp_path):
    root = _mini_repo(tmp_path)
    (root / "src" / "repro" / "mod.py").write_text(textwrap.dedent("""\
        def f(x):
            assert x > 0
            assert x < 10  # fppcheck: allow-assert
            return x
    """))
    findings = check_asserts(PassContext(root=root))
    assert [f.code for f in findings] == ["bare-assert"]
    assert findings[0].severity == "error"
    assert findings[0].location == "src/repro/mod.py:2"


def test_asserts_exempt_under_tests_dir(tmp_path):
    root = _mini_repo(tmp_path)
    (root / "src" / "repro" / "tests").mkdir()
    (root / "src" / "repro" / "tests" / "t.py").write_text(
        "def f():\n    assert True\n")
    assert check_asserts(PassContext(root=root)) == []


def test_jnp_in_host_loop_caught(tmp_path):
    root = _mini_repo(tmp_path)
    (root / "src" / "repro" / "core" / "hot.py").write_text(
        textwrap.dedent("""\
            import jax.numpy as jnp

            def slow(xs):
                out = []
                for x in xs:
                    out.append(jnp.add(x, 1))        # flagged
                    out.append(jnp.int32(0))         # scalar ctor: fine
                    out.append(jnp.exp(x))  # fppcheck: allow-host-jnp
                return out

            def traced(xs):
                for _ in range(3):
                    def body(c):
                        return jnp.add(c, 1)         # nested def: fine
                return body
        """))
    findings = check_host_jnp_loops(PassContext(root=root))
    assert [f.code for f in findings] == ["jnp-in-host-loop"]
    assert findings[0].location == "src/repro/core/hot.py:6"
    assert "jnp.add" in findings[0].message


def test_jnp_in_host_loop_caught_under_serve(tmp_path):
    # the serving lanes are host-side by design (admission / delivery /
    # result cache) — a per-iteration dispatch there stalls every tenant,
    # so the lint polices serve/ with the same rules as core/
    root = _mini_repo(tmp_path)
    (root / "src" / "repro" / "serve").mkdir()
    (root / "src" / "repro" / "serve" / "lane.py").write_text(
        textwrap.dedent("""\
            import jax.numpy as jnp

            def drain(batches):
                out = []
                while batches:
                    out.append(jnp.stack(batches.pop()))   # flagged
                    out.append(jnp.asarray(0))             # ctor: fine
                return out
        """))
    findings = check_host_jnp_loops(PassContext(root=root))
    assert [f.location for f in findings] == ["src/repro/serve/lane.py:6"]
    assert "jnp.stack" in findings[0].message


# ------------------------------------------------------------------ docs


def test_dangling_design_ref_caught(tmp_path):
    root = _mini_repo(tmp_path)
    # assembled so the docs pass scanning THIS file doesn't see a citation
    dangling = "DESIGN.md " + chr(0xA7) + "9.3"
    (root / "src" / "repro" / "mod.py").write_text(
        f'"""See {dangling} for details."""\n')
    from repro.analysis.docs import run_pass
    findings = run_pass(PassContext(root=root))
    assert any(f.code == "dangling-ref" and "9.3" in f.message
               and f.severity == "error" for f in findings)


def test_stale_repo_map_entry_caught(tmp_path):
    root = _mini_repo(tmp_path)
    readme = root / "README.md"
    readme.write_text(readme.read_text().replace(
        "`src/repro/core/`", "`src/repro/gone.py`"))
    from repro.analysis.docs import run_pass
    findings = run_pass(PassContext(root=root))
    assert any("gone.py" in f.message for f in findings)


# ---------------------------------------------------------------- pallas


def _contract(**kw):
    base = dict(
        name="fake", module="repro.kernels.fake.fake", grid=(4,),
        in_tiles=(TileSpec("a", (256, 64), (64, 64)),),
        out_tiles=(TileSpec("o", (256, 64), (64, 64)),),
        wired=False)
    base.update(kw)
    return KernelContract(**base)


class _Mem:
    """Stand-in MemoryModel: tiny working set, real-sized VMEM."""
    vmem_bytes = 100 * 2 ** 20

    def working_set(self, block_size, num_queries):
        return 64 * 1024

    def covers(self, fp, block_size, num_queries):
        return fp <= self.working_set(block_size, num_queries)


def test_contract_clean_passes():
    assert check_contract(_contract(), _Mem()) == []


def test_tile_divisibility_violation_caught():
    c = _contract(in_tiles=(TileSpec("a", (100, 64), (64, 64)),))
    findings = check_contract(c, _Mem())
    assert any(f.code == "tile-divisibility" and f.severity == "error"
               for f in findings)


def test_grid_coverage_violation_caught():
    c = _contract(grid=(2,))   # 4 output blocks, only 2 programs
    findings = check_contract(c, _Mem())
    assert any(f.code == "grid-coverage" and f.severity == "error"
               for f in findings)


def test_vmem_overflow_caught():
    big = TileSpec("a", (8192, 8192), (8192, 8192))   # 256 MiB > VMEM
    c = _contract(in_tiles=(big,))
    findings = check_contract(c, _Mem())
    assert any(f.code == "vmem-overflow" and f.severity == "error"
               for f in findings)


def test_model_overflow_caught_for_wired_kernel():
    # fits VMEM but blows the planner's modeled working set
    big = TileSpec("a", (256, 256), (256, 256))       # 256 KiB > 64 KiB
    c = _contract(in_tiles=(big,),
                  out_tiles=(TileSpec("o", (256, 256), (256, 256)),),
                  grid=(1,), wired=True, block_size=64, num_queries=64)
    findings = check_contract(c, _Mem())
    assert any(f.code == "model-overflow" and f.severity == "error"
               for f in findings)


def test_wired_kernel_within_model_reports_footprint():
    c = _contract(grid=(4,), wired=True, block_size=64, num_queries=64)
    findings = check_contract(c, _Mem())
    assert [f.code for f in findings] == ["footprint"]
    assert findings[0].severity == "info"


# ----------------------------------------------------------------- jaxpr


def _program(fn, args, **kw):
    from repro.analysis.programs import Program
    return Program(key="seeded/test", backend="test", kind="test",
                   fn=fn, args=args, **kw)


def _codes(findings):
    return {f.code for f in findings}


def test_io_callback_in_while_body_caught():
    import jax
    from jax.experimental import io_callback

    from repro.analysis.jaxpr_passes import check_program

    def fn(x):
        def body(c):
            io_callback(lambda v: None, None, c)
            return c + 1
        return jax.lax.while_loop(lambda c: c < 10, body, x)

    findings = check_program(_program(fn, (np.int32(0),)))
    hits = [f for f in findings if f.code == "host-callback-in-loop"]
    assert len(hits) == 1 and hits[0].severity == "error"


def test_callback_outside_loop_is_warning_only():
    import jax
    from jax.experimental import io_callback

    from repro.analysis.jaxpr_passes import check_program

    def fn(x):
        io_callback(lambda v: None, None, x)
        return x + 1

    findings = check_program(_program(fn, (np.int32(0),)))
    assert _codes(findings) == {"host-callback"}
    assert all(f.severity == "warning" for f in findings)


def test_f64_promotion_caught():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_passes import check_program

    def fn(x):
        return x.astype(jnp.float64) * 2.0

    with jax.experimental.enable_x64():
        findings = check_program(
            _program(fn, (jax.ShapeDtypeStruct((8,), jnp.float32),)))
    assert "x64-promotion" in _codes(findings)
    assert any(f.severity == "error" for f in findings)


def test_weak_output_caught():
    import jax.numpy as jnp

    from repro.analysis.jaxpr_passes import check_program

    def fn(x):
        return jnp.add(1.0, 2.0)      # literal-only: weakly typed output

    findings = check_program(
        _program(fn, (np.zeros(4, np.float32),)))
    assert "weak-output" in _codes(findings)


def test_counter_dtype_contract_enforced():
    import jax.numpy as jnp

    from repro.analysis.jaxpr_passes import check_program

    def fn(x):
        return x.sum()                # float32 "counter"

    findings = check_program(_program(
        fn, (np.zeros(4, np.float32),),
        counters=lambda out: {"eq": out}))
    assert "counter-dtype" in _codes(findings)


def test_donation_aval_drift_caught():
    import jax.numpy as jnp

    from repro.analysis.jaxpr_passes import check_program

    def fn(x):
        return x[:2]                  # state comes back a different shape

    findings = check_program(_program(
        fn, (np.zeros(4, np.float32),),
        donation=lambda args, out: [("state", args[0], out)]))
    assert "donation-unsafe" in _codes(findings)


def test_clean_program_has_no_findings():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_passes import check_program

    def fn(x):
        return jax.lax.while_loop(
            lambda c: c[0] < jnp.int32(10),
            lambda c: (c[0] + jnp.int32(1), c[1] * jnp.float32(0.5)), x)

    findings = check_program(_program(
        fn, ((np.int32(0), np.float32(1.0)),),
        donation=lambda args, out: [("state", args[0], out)]))
    assert findings == []


# ------------------------------------------------------------------- hlo


_BASE = {"ops_total": 100, "while_body_total": 20, "op_copy": 3}


def test_budget_exceeded_caught():
    row = dict(_BASE, op_copy=4)      # one extra copy past the ceiling
    findings = check_row("engine/test", row, _BASE)
    assert [f.code for f in findings] == ["budget-exceeded"]
    assert findings[0].severity == "error"
    assert "op_copy: 4 > 3" in findings[0].message


def test_budget_is_a_ceiling_not_an_equality():
    row = dict(_BASE, ops_total=90)   # shrinking never fails
    findings = check_row("engine/test", row, _BASE)
    assert [f.code for f in findings] == ["within-budget"]


def test_unbudgeted_metric_warns():
    row = dict(_BASE, op_scatter=1)
    findings = check_row("engine/test", row, _BASE)
    codes = [f.code for f in findings]
    assert "unbudgeted-metric" in codes
    sev = {f.code: f.severity for f in findings}
    assert sev["unbudgeted-metric"] == "warning"


def test_committed_budgets_cover_full_matrix():
    import json
    budgets = json.loads(
        (ROOT / "src" / "repro" / "analysis" / "budgets.json").read_text())
    kinds = ("sssp", "bfs", "ppr", "cc", "kreach", "rw")
    want = {f"{b}/{k}" for b in ("engine", "streaming", "baselines")
            for k in kinds}
    want |= {f"engine-serve/{k}" for k in kinds}
    want |= {f"engine-fused/{k}" for k in kinds if k != "rw"}
    want |= {f"distributed/{k}@d{d}" for k in kinds for d in (1, 8)}
    assert want <= set(budgets)
    for key, row in budgets.items():
        assert row["ops_total"] > 0, key


# ------------------------------------------------- clean-repo integration


def test_fast_families_clean_on_real_repo():
    """ast + docs + pallas must be green on the committed tree."""
    report = run_passes(["ast.asserts", "ast.host-jnp", "docs.refs",
                         "pallas.contracts", "pallas.reachability"],
                        PassContext(root=ROOT))
    assert report.ok, report.render()


def test_report_severity_model():
    r = Report(findings=[
        Finding("p", "c", "warning", "loc", "m"),
        Finding("p", "c", "allowlisted", "loc", "m"),
        Finding("p", "c", "info", "loc", "m")], passes_run=["p"])
    assert r.ok                       # only errors fail
    r2 = Report(findings=[Finding("p", "c", "error", "loc", "m")],
                passes_run=["p"])
    assert not r2.ok
    assert r2.as_dict()["counts"]["error"] == 1
