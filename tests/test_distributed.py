"""Distributed FPP runtime: correctness on a multi-device host mesh.

Runs in a subprocess because the 8-device XLA host-platform flag must be set
before jax initializes (the main test process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.graphs.generators import grid2d, rmat
    from repro.core.partition import partition
    from repro.core.distributed import run_distributed_sssp
    from repro.core import oracles
    from repro.core.yielding import YieldConfig

    failures = []
    for gname, g in [("grid", grid2d(16, 16, seed=7)),
                     ("rmat", rmat(8, 4, seed=8))]:
        bg, perm = partition(g, 32, method="bfs")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        srcs_old = np.array([0, 30, 100, 200])
        res = run_distributed_sssp(bg, perm[srcs_old], mesh,
                                   yield_config=YieldConfig(delta=4.0))
        for qi, s in enumerate(srcs_old):
            d_or, _ = oracles.dijkstra(g, int(s))
            d_eng = res.values[qi][perm]
            if not np.allclose(np.nan_to_num(d_or, posinf=1e30),
                               np.nan_to_num(d_eng, posinf=1e30), atol=1e-3):
                failures.append((gname, qi))
        assert res.supersteps > 0
        # query shards are independent: edges accounted per query
        assert (res.edges_processed >= 0).all()
    assert not failures, failures
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_distributed_sssp_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DISTRIBUTED_OK" in out.stdout
