"""Distributed FPP runtime: correctness on a multi-device host mesh.

Runs in a subprocess because the 8-device XLA host-platform flag must be set
before jax initializes (the main test process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.graphs.generators import grid2d, rmat
    from repro.core.partition import partition
    from repro.core.distributed import run_distributed_sssp
    from repro.core import oracles
    from repro.core.yielding import YieldConfig

    failures = []
    for gname, g in [("grid", grid2d(16, 16, seed=7)),
                     ("rmat", rmat(8, 4, seed=8))]:
        bg, perm = partition(g, 32, method="bfs")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        srcs_old = np.array([0, 30, 100, 200])
        res = run_distributed_sssp(bg, perm[srcs_old], mesh,
                                   yield_config=YieldConfig(delta=4.0))
        for qi, s in enumerate(srcs_old):
            d_or, oedges = oracles.dijkstra(g, int(s))
            d_eng = res.values[qi][perm]
            if not np.allclose(np.nan_to_num(d_or, posinf=1e30),
                               np.nan_to_num(d_eng, posinf=1e30), atol=1e-3):
                failures.append((gname, qi))
            # counts sum over ALL devices' partitions (psum over the model
            # axis): every reachable vertex relaxes its out-row at least
            # once, so a per-query total below the sequential oracle's
            # count means a device's shard was dropped
            assert res.edges_processed[qi] >= oedges, (
                gname, qi, res.edges_processed[qi], oedges)
        assert res.supersteps > 0
    assert not failures, failures
    print("DISTRIBUTED_OK")
""")


_PPR_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.graphs.generators import rmat
    from repro.core.partition import partition
    from repro.core.distributed import run_distributed_ppr
    from repro.core import oracles

    g = rmat(7, 6, seed=5)
    deg = g.out_degree()
    bg, perm = partition(g, 32, method="bfs")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    srcs = np.random.default_rng(0).choice(np.flatnonzero(deg > 0), 4,
                                           replace=False)
    eps = 1e-4
    res = run_distributed_ppr(bg, perm[srcs], mesh, eps=eps)
    assert res.supersteps > 0
    for qi, s in enumerate(srcs):
        want_p, _, _ = oracles.ppr_push(g, int(s), eps=eps)
        p_d = res.values[qi][perm]
        r_d = res.residual[qi][perm]
        # mass conservation: un-consolidated ops fold into the residual
        assert abs(p_d.sum() + r_d.sum() - 1.0) < 5e-3, qi
        # ACL terminal condition after the pmax convergence; sinks
        # (deg==0) can never push, so the bound only applies to deg>0
        assert (r_d[deg > 0] <= eps * deg[deg > 0] + 1e-6).all(), qi
        err = np.abs(p_d - want_p) / np.maximum(deg, 1)
        assert err.max() <= 2 * eps, (qi, float(err.max()))
    # exact integral edge accounting survives the (hi, lo) int32 carry
    assert (res.edges_processed == np.round(res.edges_processed)).all()
    assert (res.edges_processed > 0).all()
    print("DISTRIBUTED_PPR_OK")
""")


def _run_sub(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


@pytest.mark.slow
def test_distributed_sssp_8_devices():
    out = _run_sub(_SCRIPT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DISTRIBUTED_OK" in out.stdout


@pytest.mark.slow
def test_distributed_ppr_8_devices():
    """The push family on the pod runtime: same superstep, + instead of min."""
    out = _run_sub(_PPR_SCRIPT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DISTRIBUTED_PPR_OK" in out.stdout
