"""Per-kernel shape/dtype sweeps: pallas_call (interpret=True) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.frontier.ops import frontier_pallas
from repro.kernels.frontier.ref import frontier_ref
from repro.kernels.ppr_push.ops import ppr_push_pallas
from repro.kernels.ppr_push.ref import ppr_push_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,hd,causal,window", [
    (2, 64, 64, 4, 4, 32, True, None),     # MHA causal
    (1, 48, 80, 4, 2, 16, True, None),     # GQA, cross lengths, pad path
    (2, 32, 32, 8, 1, 64, False, None),    # MQA non-causal
    (1, 128, 128, 4, 4, 32, True, 32),     # windowed (recurrentgemma)
    (1, 16, 300, 2, 2, 8, False, None),    # KV padding (1500-frame-like)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Skv, H, Hkv, hd, causal, window,
                               dtype):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    g = H // Hkv
    kr, vr = jnp.repeat(k, g, 2), jnp.repeat(v, g, 2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = kr.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    vf = vr.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    want = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    want = want.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol)


@pytest.mark.parametrize("Q,B", [(8, 32), (17, 64), (128, 128), (1, 16)])
@pytest.mark.parametrize("delta", [0.5, 2.0, np.inf])
def test_frontier_sweep(Q, B, delta):
    buf = jnp.asarray(np.where(RNG.random((Q, B)) < 0.6, np.inf,
                               RNG.random((Q, B)) * 9), jnp.float32)
    dist = jnp.asarray(np.where(RNG.random((Q, B)) < 0.5, np.inf,
                                RNG.random((Q, B)) * 9), jnp.float32)
    got = frontier_pallas(buf, dist, delta=float(delta))
    want = frontier_ref(buf, dist, delta=float(delta))
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.nan_to_num(np.asarray(g), posinf=1e30),
            np.nan_to_num(np.asarray(w), posinf=1e30), rtol=1e-6)


@pytest.mark.parametrize("Q,B", [(8, 32), (25, 64), (128, 128)])
@pytest.mark.parametrize("alpha,eps", [(0.15, 1e-4), (0.5, 1e-2)])
def test_ppr_push_sweep(Q, B, alpha, eps):
    p = jnp.asarray(RNG.random((Q, B)), jnp.float32) * 0.05
    r = jnp.asarray(RNG.random((Q, B)), jnp.float32) * 0.02
    acc = jnp.asarray(RNG.random((Q, B)), jnp.float32) * 0.01
    w = jnp.asarray(np.where(RNG.random((B, B)) < 0.85, np.inf,
                             RNG.random((B, B))), jnp.float32)
    deg = jnp.asarray(np.isfinite(np.asarray(w)).sum(1), jnp.float32)
    got = ppr_push_pallas(p, r, acc, w, deg, alpha=alpha, eps=eps)
    want = ppr_push_ref(p, r, acc, w, deg[None], alpha=alpha, eps=eps)
    for g, wv in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wv),
                                   atol=1e-6)


@pytest.mark.parametrize("Q,B", [(3, 16), (8, 32), (64, 64)])
@pytest.mark.parametrize("u_chunk", [4, 8, 16])
def test_minplus_tile_skip_inactive_bitwise(Q, B, u_chunk):
    """The fused visit's in-kernel relax: chunked, chunk-skipping, and
    single-chunk paths of ``minplus_tile`` all agree with the ref down to
    the bit — skipping an all-+inf source chunk contributes only +inf
    candidates, and chunking only reassociates an exact min."""
    from repro.kernels.minplus.minplus import minplus_tile
    from repro.kernels.minplus.ref import minplus_ref
    d = jnp.asarray(np.where(RNG.random((Q, B)) < 0.7, np.inf,
                             RNG.random((Q, B)) * 9), jnp.float32)
    w = jnp.asarray(np.where(RNG.random((B, B)) < 0.8, np.inf,
                             RNG.random((B, B)) * 5), jnp.float32)
    want = np.nan_to_num(np.asarray(minplus_ref(d, w)), posinf=1e30)
    for kw in ({"u_chunk": u_chunk}, {"u_chunk": u_chunk,
                                      "skip_inactive": True},
               {"u_chunk": B}, {"u_chunk": B, "skip_inactive": True}):
        got = np.nan_to_num(np.asarray(minplus_tile(d, w, **kw)),
                            posinf=1e30)
        np.testing.assert_array_equal(got, want, err_msg=str(kw))


@pytest.mark.parametrize("Q,B", [(3, 16), (64, 64)])
@pytest.mark.parametrize("delta", [0.5, 2.0, np.inf])
def test_frontier_tile_matches_ref(Q, B, delta):
    """The fused visit's consolidation op: tile == ref on every output,
    including the extra [QT, 1] alpha row the kernel path keeps."""
    from repro.kernels.frontier.frontier import frontier_tile
    from repro.kernels.frontier.ref import frontier_ref
    buf = jnp.asarray(np.where(RNG.random((Q, B)) < 0.6, np.inf,
                               RNG.random((Q, B)) * 9), jnp.float32)
    dist = jnp.asarray(np.where(RNG.random((Q, B)) < 0.5, np.inf,
                                RNG.random((Q, B)) * 9), jnp.float32)
    d1, srcs, alpha, pending, active = frontier_tile(buf, dist,
                                                     delta=float(delta))
    assert alpha.shape == (Q, 1)
    want = frontier_ref(buf, dist, delta=float(delta))
    for g, w in zip((d1, srcs), want[:2]):
        np.testing.assert_array_equal(
            np.nan_to_num(np.asarray(g), posinf=1e30),
            np.nan_to_num(np.asarray(w), posinf=1e30))


@pytest.mark.parametrize("Q,B", [(3, 16), (16, 64)])
def test_push_tile_lane_mask(Q, B):
    """The fused visit's per-query edge-budget gate: an all-true lane mask
    is bitwise the unmasked op; an all-false mask freezes the tile (no
    pushes, p/r/acc unchanged, empty active set)."""
    from repro.kernels.ppr_push.push import push_tile
    p = jnp.asarray(RNG.random((Q, B)), jnp.float32) * 0.05
    r = jnp.asarray(RNG.random((Q, B)), jnp.float32) * 0.02
    acc = jnp.asarray(RNG.random((Q, B)), jnp.float32) * 0.01
    w = jnp.asarray(np.where(RNG.random((B, B)) < 0.85, np.inf,
                             RNG.random((B, B))), jnp.float32)
    deg = jnp.asarray(np.isfinite(np.asarray(w)).sum(1), jnp.float32)
    kw = dict(alpha=0.15, eps=1e-4)
    base = push_tile(p, r, acc, w, deg, **kw)
    ones = push_tile(p, r, acc, w, deg,
                     lane_mask=jnp.ones((Q, B), bool), **kw)
    for g, want in zip(ones, base):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))
    p1, r1, acc1, active = push_tile(p, r, acc, w, deg,
                                     lane_mask=jnp.zeros((Q, B), bool),
                                     **kw)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r))
    np.testing.assert_array_equal(np.asarray(acc1), np.asarray(acc))
    assert not np.asarray(active).any()


def test_flash_attention_used_as_model_attention():
    """The kernel slots into the model attention contract (same output as
    models/attention.attend)."""
    from repro.models.attention import attend
    B, S, H, Hkv, hd = 2, 32, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    pos = jnp.arange(S)
    want = attend(q, k, v, pos, pos, causal=True, chunk=8)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)
