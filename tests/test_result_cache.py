"""The serving result-cache tier (DESIGN.md §4.2, serve/result_cache.py).

What answer reuse must never change: answers.  A cache hit returns the
*same* plane the populating response carried — bit-identical to a fresh
``FPPSession.run`` for every kind, because the entry IS a lane-computed
answer (minplus kinds are exactness-pinned, and a ppr hit is exact
against its own cold twin by construction: same plane, same bits).

What it must additionally guarantee, pinned here:
  * hits are visible and billed honestly: ``cached: True``, zero
    visits/edges/host_syncs, exact queue wait; ``result()``/``poll()``
    behave exactly as for lane-computed answers;
  * ``update_graph`` bumps the name's epoch, so planes computed against
    the replaced graph are unservable (the staleness bound) and the new
    graph's answers are correct;
  * the byte budget holds: exact per-entry accounting, LRU eviction
    order, oversized entries refused rather than flushing the cache;
  * the warm megastep cache is LRU-bounded too (``max_entries``).
"""
import numpy as np
import pytest

from repro.fpp import FPPSession, MemoryModel
from repro.fpp.planner import result_cache_budget
from repro.graphs.generators import grid2d, rmat
from repro.serve import (CacheEntry, GraphRequest, GraphServer, MegastepCache,
                         ResultCache, result_key)
from repro.serve.compile_cache import session_uid


def _sources(g, k, seed=0):
    cand = np.flatnonzero(g.out_degree() > 0)
    return np.random.default_rng(seed).choice(cand, size=k, replace=False)


# ------------------------------------------------------------ unit: cache


def _entry_arrays(nbytes, seed=0):
    """A float64 plane of exactly ``nbytes`` bytes."""
    return np.random.default_rng(seed).random(nbytes // 8)


def test_lru_eviction_order_and_recency_refresh():
    cache = ResultCache(budget_bytes=3 * 800)
    for i in range(3):
        assert cache.put(("s", 0, "sssp", i, 0.15, 1e-4),
                         _entry_arrays(800, seed=i))
    # touch key 0: it becomes most-recent, so key 1 is now LRU
    assert cache.get(("s", 0, "sssp", 0, 0.15, 1e-4)) is not None
    assert cache.put(("s", 0, "sssp", 3, 0.15, 1e-4), _entry_arrays(800))
    assert cache.get(("s", 0, "sssp", 1, 0.15, 1e-4)) is None   # evicted
    assert cache.get(("s", 0, "sssp", 0, 0.15, 1e-4)) is not None
    assert cache.get(("s", 0, "sssp", 2, 0.15, 1e-4)) is not None
    s = cache.stats()
    assert s["evictions"] == 1 and s["entries"] == 3
    assert s["bytes"] == 3 * 800 <= s["budget_bytes"]


def test_byte_budget_exact_accounting_and_oversize_refused():
    cache = ResultCache(budget_bytes=1000)
    vals, res = _entry_arrays(400), _entry_arrays(400, seed=1)
    assert cache.put(("a",), vals, res)
    assert cache.bytes == vals.nbytes + res.nbytes == 800
    # an entry bigger than the whole budget must not flush the hot one
    assert not cache.put(("b",), _entry_arrays(1600))
    assert cache.get(("a",)) is not None
    # same-key refresh replaces, never double-counts
    assert cache.put(("a",), _entry_arrays(800, seed=2))
    assert cache.bytes == 800 and len(cache) == 1


def test_invalidate_session_frees_bytes():
    cache = ResultCache(budget_bytes=10_000)
    cache.put(result_key(7, 0, "sssp", 1, 0.15, 1e-4), _entry_arrays(160))
    cache.put(result_key(7, 0, "sssp", 2, 0.15, 1e-4), _entry_arrays(160))
    cache.put(result_key(8, 0, "sssp", 1, 0.15, 1e-4), _entry_arrays(160))
    assert cache.invalidate_session(7) == 2
    assert cache.bytes == 160 and len(cache) == 1
    assert cache.get(result_key(8, 0, "sssp", 1, 0.15, 1e-4)) is not None
    assert cache.stats()["invalidations"] == 2


def test_cached_arrays_are_frozen():
    cache = ResultCache(budget_bytes=10_000)
    vals = _entry_arrays(160)
    cache.put(("k",), vals)
    hit = cache.get(("k",))
    assert hit.values is vals          # reuse, not a copy
    with pytest.raises(ValueError):
        hit.values[0] = 99.0           # mutation fails loudly


def test_reserve_grows_never_shrinks():
    cache = ResultCache(budget_bytes=100)
    assert cache.reserve(500) == 500
    assert cache.reserve(50) == 500


# --------------------------------------------------------- server: parity


@pytest.mark.parametrize("kind", ["sssp", "bfs", "ppr"])
def test_cached_hit_bit_identical_to_fresh_run(kind):
    """The bit-parity contract: a warm repeat returns the same plane the
    cold request computed — which is itself bit-identical to
    ``session.run`` — so hit bits == fresh bits, minplus and push alike."""
    g = grid2d(12, 12, seed=3)
    srcs = _sources(g, 3, seed=11)
    sess = FPPSession(g).plan(num_queries=3, block_size=32)
    one = sess.run(kind, srcs)
    server = GraphServer(capacity=3, k_visits=16)
    server.register_graph("g", sess)
    cold = [server.submit(GraphRequest(kind=kind, source=int(s), graph="g"))
            for s in srcs]
    server.serve()
    warm = [server.submit(GraphRequest(kind=kind, source=int(s), graph="g"))
            for s in srcs]
    out = server.serve()
    for i, (c, w) in enumerate(zip(cold, warm)):
        assert out[w].status == "ok"
        assert out[w].stats.get("cached") is True
        assert not out[c].stats.get("cached")
        np.testing.assert_array_equal(out[w].values, one.values[i],
                                      err_msg=kind)
        np.testing.assert_array_equal(out[w].values, out[c].values)
        if kind == "ppr":
            np.testing.assert_array_equal(out[w].residual, one.residual[i])
        # a hit never touched a lane: zero billed work, but honest waits
        assert out[w].stats["visits"] == 0
        assert out[w].stats["edges"] == 0.0
        assert out[w].stats["host_syncs"] == 0
        assert out[w].stats["queue_wait_s"] >= 0.0
    s = server.stats()
    assert s["cache_hits"] == 3 and s["cache_misses"] == 3
    assert s["cache_bytes"] > 0


def test_hit_skips_the_lane_entirely():
    g = grid2d(10, 10, seed=6)
    src = int(_sources(g, 1, seed=12)[0])
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=32)
    r1 = server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    r2 = server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    assert server.poll(r2).stats.get("cached") is True
    np.testing.assert_array_equal(server.poll(r2).values,
                                  server.poll(r1).values)
    # the executor only ever saw the cold query
    assert server._pools[("g", "sssp")].exec._next_qid == 1


def test_result_and_poll_parity_on_hits_through_running_lanes():
    """A hit rides the delivery lane: blocking ``result()`` and
    ``poll()`` behave exactly as for a lane-computed answer."""
    g = grid2d(10, 10, seed=6)
    src = int(_sources(g, 1, seed=13)[0])
    server = GraphServer(capacity=2, k_visits=16, autoscaler=None)
    server.register_graph("g", g, num_queries=2, block_size=32)
    server.start()
    try:
        cold = server.result(server.submit(
            GraphRequest(kind="sssp", source=src, graph="g")), timeout=120)
        rid = server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
        warm = server.result(rid, timeout=120)
        assert warm.status == "ok" and warm.stats.get("cached") is True
        np.testing.assert_array_equal(warm.values, cold.values)
        assert server.poll(rid) is warm
        assert server.wait_drained(timeout=10)
    finally:
        server.shutdown()


def test_result_cache_off_recomputes():
    g = grid2d(8, 8, seed=4)
    src = int(_sources(g, 1, seed=14)[0])
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None,
                         result_cache=False)
    server.register_graph("g", g, num_queries=1, block_size=16)
    server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    r2 = server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    assert not server.poll(r2).stats.get("cached")
    assert server._pools[("g", "sssp")].exec._next_qid == 2
    assert server.stats()["cache_hits"] == 0


# ----------------------------------------------------- server: invalidation


def test_update_graph_epoch_invalidates_and_serves_new_answers():
    """The staleness bound: after ``update_graph`` the same (kind, source)
    is a miss, and the recomputed answer matches a fresh run on the NEW
    graph — never the cached plane of the old one."""
    g_old = grid2d(10, 10, seed=6)
    g_new = grid2d(10, 10, seed=60)     # same n, different weights
    src = int(_sources(g_old, 1, seed=15)[0])
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    server.register_graph("g", g_old, num_queries=1, block_size=32)
    r1 = server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    old_vals = server.poll(r1).values

    server.update_graph("g", g_new, num_queries=1, block_size=32)
    assert server.stats()["epochs"]["g"] == 1
    r2 = server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    fresh = server.poll(r2)
    assert not fresh.stats.get("cached")         # post-update hit is a miss
    want = FPPSession(g_new).plan(num_queries=1, block_size=32).run(
        "sssp", np.array([src]))
    np.testing.assert_array_equal(fresh.values, want.values[0])
    assert not np.array_equal(fresh.values, old_vals)
    # the old session's entries were dropped eagerly, the new one cached
    r3 = server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    assert server.poll(r3).stats.get("cached") is True
    np.testing.assert_array_equal(server.poll(r3).values, want.values[0])
    assert server.stats()["result_cache"]["invalidations"] >= 1


def test_update_graph_same_session_epoch_still_invalidates():
    """Even re-registering the *same session object* (uid unchanged —
    e.g. graph weights mutated in place) bumps the epoch, so pre-update
    planes cannot be served."""
    g = grid2d(8, 8, seed=4)
    src = int(_sources(g, 1, seed=16)[0])
    sess = FPPSession(g).plan(num_queries=1, block_size=16)
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    server.register_graph("g", sess)
    server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    server.update_graph("g", sess)
    r2 = server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    assert not server.poll(r2).stats.get("cached")


def test_update_graph_validation():
    g = grid2d(8, 8, seed=4)
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    with pytest.raises(ValueError, match="not registered"):
        server.update_graph("nope", g, num_queries=1, block_size=16)
    server.register_graph("g", g, num_queries=1, block_size=16)
    src = int(_sources(g, 1, seed=17)[0])
    server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    with pytest.raises(RuntimeError, match="drain first"):
        server.update_graph("g", g, num_queries=1, block_size=16)
    server.serve()                      # drained: now the update is legal
    server.update_graph("g", g, num_queries=1, block_size=16)
    assert server.stats()["epochs"]["g"] == 1


# ----------------------------------------------------- server: byte budget


def test_server_cache_bytes_budget_enforced():
    """A budget sized for ~one plane holds one entry: the second distinct
    source evicts the first (LRU), and the counters say so."""
    g = grid2d(10, 10, seed=6)
    srcs = _sources(g, 2, seed=18)
    sess = FPPSession(g).plan(num_queries=1, block_size=32)
    one_plane = sess.run("sssp", srcs[:1]).values[0].nbytes
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None,
                         cache_bytes=int(one_plane * 1.5))
    server.register_graph("g", sess)
    for s in srcs:
        server.submit(GraphRequest(kind="sssp", source=int(s), graph="g"))
        server.serve()
    s = server.stats()
    assert s["result_cache"]["entries"] == 1
    assert s["cache_evictions"] == 1
    assert s["cache_bytes"] <= int(one_plane * 1.5)
    # srcs[1] is resident, srcs[0] was evicted
    r_hit = server.submit(GraphRequest(kind="sssp", source=int(srcs[1]),
                                       graph="g"))
    server.serve()
    assert server.poll(r_hit).stats.get("cached") is True


def test_default_budget_comes_from_planner():
    g = rmat(7, 4, seed=7)
    sess = FPPSession(g).plan(num_queries=2, block_size=32)
    server = GraphServer(capacity=2, k_visits=16)
    server.register_graph("g", sess)
    want = result_cache_budget(sess.mem, sess.graph.n,
                               sess.current_plan.block_size)
    assert server.result_cache.budget_bytes == want
    assert want == 16 * sess.mem.state_bytes(sess.graph.n, 1,
                                             sess.current_plan.block_size)


def test_shared_result_cache_across_servers():
    """A shared cache serves one server's completed plane to another
    server of the *same session* — and keys by session uid, so a
    different graph under the same registered name can never hit."""
    g = grid2d(10, 10, seed=6)
    src = int(_sources(g, 1, seed=19)[0])
    sess = FPPSession(g).plan(num_queries=1, block_size=32)
    shared = ResultCache()
    s1 = GraphServer(capacity=1, k_visits=16, autoscaler=None,
                     result_cache=shared)
    s1.register_graph("g", sess)
    s1.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    s1.serve()
    s2 = GraphServer(capacity=1, k_visits=16, autoscaler=None,
                     result_cache=shared)
    s2.register_graph("g", sess)        # same session -> same uid
    r = s2.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    s2.serve()
    assert s2.poll(r).stats.get("cached") is True
    # same name, different graph: a different session uid, so no hit
    other = FPPSession(grid2d(10, 10, seed=61)).plan(num_queries=1,
                                                     block_size=32)
    s3 = GraphServer(capacity=1, k_visits=16, autoscaler=None,
                     result_cache=shared)
    s3.register_graph("g", other)
    r3 = s3.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    s3.serve()
    assert not s3.poll(r3).stats.get("cached")


# ------------------------------------------------------- server: counters


def test_stats_surface_cache_and_dedup_counters():
    g = grid2d(10, 10, seed=6)
    src = int(_sources(g, 1, seed=20)[0])
    server = GraphServer(capacity=1, k_visits=16, autoscaler=None)
    server.register_graph("g", g, num_queries=1, block_size=32)
    # three in-flight twins: one primary + two coalesced followers
    for t in ("a", "b", "c"):
        server.submit(GraphRequest(kind="sssp", source=src, graph="g",
                                   tenant=t))
    server.serve()
    # one warm repeat: a result-cache hit
    server.submit(GraphRequest(kind="sssp", source=src, graph="g"))
    server.serve()
    s = server.stats()
    assert s["coalesced"] == 2 and s["fanout"] == 2
    assert s["cache_hits"] == 1
    assert s["cache_misses"] >= 1
    assert s["cache_evictions"] == 0
    assert s["cache_bytes"] == s["result_cache"]["bytes"] > 0
    assert s["compile_cache"]["max_entries"] >= 1
    assert s["cache"] == s["compile_cache"]    # legacy alias


# ------------------------------------------------- megastep cache bounding


def test_megastep_cache_lru_eviction():
    cache = MegastepCache(max_entries=2)
    g = grid2d(6, 6, seed=1)
    sess = FPPSession(g).plan(num_queries=1, block_size=16)
    for cap in (1, 2):
        cache.get_or_build(sess, "g", "sssp", cap, k_visits=8)
    assert len(cache) == 2
    # touch cap=1 so cap=2 is LRU, then insert a third capacity
    k1 = cache.get_or_build(sess, "g", "sssp", 1, k_visits=8)
    cache.get_or_build(sess, "g", "sssp", 4, k_visits=8)
    st = cache.stats()
    assert st["size"] == 2 and st["evictions"] == 1
    # cap=1 survived (refreshed); cap=2 was dropped and would recompile
    assert cache.get_or_build(sess, "g", "sssp", 1, k_visits=8) is k1
    before = st["misses"]
    cache.get_or_build(sess, "g", "sssp", 2, k_visits=8)
    assert cache.stats()["misses"] == before + 1


def test_megastep_cache_rejects_bad_max_entries():
    with pytest.raises(ValueError, match="max_entries"):
        MegastepCache(max_entries=0)
