"""Hypothesis properties of the new visit-algebra workloads.

Generalizes the fixed-seed differential pins in test_workloads_oracle.py
to arbitrary random graphs: cc == union-find everywhere and is
permutation-equivariant, kreach == the f32 Dijkstra oracle bitwise for
any hop budget, and rw trajectories replay the host tape regardless of
layout.  Skips wholesale where hypothesis is unavailable (the
deterministic twins still run).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core import oracles
from repro.core.graph import CSRGraph
from repro.fpp.session import FPPSession

SETTINGS = dict(deadline=None, max_examples=8,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@st.composite
def small_graph(draw):
    n = draw(st.integers(16, 64))
    m = draw(st.integers(n // 2, 3 * n))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.integers(1, 8, m).astype(np.float64)
    return CSRGraph.from_edges(n, src, dst, w)


@given(small_graph())
@settings(**SETTINGS)
def test_cc_fixpoint_equals_union_find(g):
    sess = FPPSession(g).plan(num_queries=1, block_size=16)
    r = sess.run("cc", np.zeros(1, dtype=np.int64))
    assert np.array_equal(
        r.values[0], oracles.connected_components(g).astype(np.float32))


@given(small_graph(), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_cc_is_permutation_equivariant(g, seed):
    """Two vertices share a component in g iff their images share one in
    the vertex-relabeled graph."""
    rng = np.random.default_rng(seed)
    sigma = rng.permutation(g.n)
    src, dst, w = g.edges()
    gp = CSRGraph.from_edges(g.n, sigma[src], sigma[dst], w)
    a = FPPSession(g).plan(num_queries=1, block_size=16).run(
        "cc", np.zeros(1, dtype=np.int64)).values[0]
    b = FPPSession(gp).plan(num_queries=1, block_size=16).run(
        "cc", np.zeros(1, dtype=np.int64)).values[0]
    for u in range(0, g.n, 7):
        same_a = a == a[u]
        same_b = b[sigma] == b[sigma[u]]
        assert np.array_equal(same_a, same_b)


@given(small_graph(), st.integers(1, 5))
@settings(**SETTINGS)
def test_kreach_any_graph_matches_oracle(g, k):
    sess = FPPSession(g).plan(num_queries=2, block_size=16)
    srcs = np.array([0, g.n - 1])
    r = sess.run("kreach", srcs, k=k)
    for q, s in enumerate(srcs):
        vals, hops, _ = oracles.kreach(g, int(s), k,
                                       stride=sess.kreach_stride)
        assert np.array_equal(r.values[q], vals)
        assert np.array_equal(r.residual[q], hops)


@given(small_graph(), st.integers(0, 2 ** 10), st.integers(1, 20))
@settings(**SETTINGS)
def test_rw_replays_host_tape_any_graph(g, seed, length):
    sess = FPPSession(g).plan(num_queries=1, block_size=16)
    src = np.array([g.n // 2])
    r = sess.run("rw", src, length=length, seed=seed)
    bg, perm = sess.prepared()
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    posns = oracles.random_walk(bg, int(perm[src[0]]), length, seed=seed)
    occ = np.zeros(g.n, np.float32)
    for p in posns:
        occ[inv[p]] += 1.0
    assert np.array_equal(r.values[0], occ)
