"""The shared visit algebra (core/visit.py): the one Algorithm-2 skeleton.

What these tests pin (ISSUE 3):
  * the engine's minplus/push visits and the distributed superstep are
    instantiations of the same algebra — operator laws (combine identity,
    pending/priority consistency) hold for both operator sets;
  * state initialization is shared: the source op lives in the buffer for
    both modes, so one-shot init and streaming admission are the same code;
  * edge accounting is integral (int32 on device, float64 on host) — counts
    are exact integers, never drifted float32 sums.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import visit as V  # noqa: E402
from repro.core.engine import FPPEngine  # noqa: E402
from repro.core.partition import partition  # noqa: E402
from repro.graphs.generators import grid2d  # noqa: E402

ALGEBRAS = {
    "minplus": V.minplus_algebra(np.inf),
    "push": V.push_algebra(0.15, 1e-3),
}


@pytest.mark.parametrize("name", list(ALGEBRAS))
def test_combine_identity_law(name):
    """combine(identity, x) == x — padded emission slots must be no-ops."""
    alg = ALGEBRAS[name]
    x = jnp.asarray(np.random.default_rng(0).uniform(0, 2, (3, 8))
                    .astype(np.float32))
    ident = jnp.full_like(x, alg.identity)
    np.testing.assert_array_equal(np.asarray(alg.combine(x, ident)),
                                  np.asarray(x))


@pytest.mark.parametrize("name", list(ALGEBRAS))
def test_source_injection_is_buffered(name):
    """Both modes start a query as ONE buffered op — identical to streaming
    admission, so late arrivals and one-shot inits share one code path."""
    alg = ALGEBRAS[name]
    planes, buf = V.init_dense_state(alg, num_parts=4, num_queries=3,
                                     block_size=8, sources=np.array([1, 9, 30]))
    assert buf.shape == (5, 3, 8)          # trash row included
    for x, v in zip(planes, alg.plane_init):
        assert (x == v).all()              # planes hold no mass yet
    hits = np.argwhere(buf != alg.identity)
    np.testing.assert_array_equal(
        hits, [[0, 0, 1], [1, 1, 1], [3, 2, 6]])
    assert (buf[hits[:, 0], hits[:, 1], hits[:, 2]]
            == alg.source_value).all()


@pytest.mark.parametrize("name", list(ALGEBRAS))
def test_prio_consistent_with_pending(name):
    """prio_of is finite exactly when pending ops exist — the invariant the
    host scheduler and the distributed argmin both rely on."""
    alg = ALGEBRAS[name]
    P, Q, B = 3, 2, 8
    deg = jnp.asarray(np.random.default_rng(1).integers(0, 4, (P, B))
                      .astype(np.int32))
    planes, buf = V.init_dense_state(alg, P, Q, B, np.array([2, 17]))
    planes = tuple(jnp.asarray(x) for x in planes)
    buf = jnp.asarray(buf)
    prio, ops, stamp = V.state_meta(alg, planes, buf, deg)
    pend = np.asarray(alg.pending(buf[:P], planes, deg))
    for p in range(P):
        has = bool(pend[p].any())
        assert np.isfinite(float(prio[p])) == has, (name, p)
        assert (int(ops[p]) > 0) == has, (name, p)


def test_engine_modes_share_one_generic_kernel():
    """make_minplus_visit / make_push_visit are instantiations of the single
    core/visit.py skeleton — no per-mode visit bodies left in core/engine.py."""
    import inspect

    from repro.core import engine as E
    for fn in (E.make_minplus_visit, E.make_push_visit):
        src = inspect.getsource(fn)
        assert "_visit.make_visit" in src, fn.__name__
        # no hand-written relax/emit loop bodies remain in the wrappers
        assert "while_loop" not in src and "fori_loop" not in src, fn.__name__
    import repro.core.distributed as D
    dsrc = inspect.getsource(D)
    assert "_visit.superstep" in dsrc
    assert "def _superstep_minplus" not in dsrc


def test_edge_counts_are_exact_integers():
    """int32-per-visit / float64-on-host accounting returns exact integral
    per-query totals (the float32 2^24 ceiling no longer applies)."""
    g = grid2d(12, 12, seed=3)
    bg, perm = partition(g, 32, method="bfs")
    srcs = perm[np.array([0, 70, 143])]
    for mode, kw in (("minplus", {}), ("push", {"eps": 1e-3})):
        eng = FPPEngine(bg, mode=mode, num_queries=len(srcs), **kw)
        res = eng.run(srcs)
        assert res.edges_processed.dtype == np.float64
        assert (res.edges_processed == np.round(res.edges_processed)).all()
        assert (res.edges_processed > 0).all()


def test_engine_rejects_wrong_batch_size_with_actionable_error():
    g = grid2d(6, 6, seed=4)
    bg, perm = partition(g, 16, method="natural")
    eng = FPPEngine(bg, mode="minplus", num_queries=2)
    with pytest.raises(ValueError, match="num_queries=3"):
        eng.run(perm[np.array([0, 1, 2])])
    with pytest.raises(ValueError, match="unknown engine mode"):
        FPPEngine(bg, mode="pull")
