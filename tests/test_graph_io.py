"""Graph I/O round-trips."""
import numpy as np

from repro.graphs.generators import rmat
from repro.graphs.io import (load_edge_list, load_npz, save_edge_list,
                             save_npz)


def test_npz_roundtrip(tmp_path):
    g = rmat(8, 4, seed=3)
    p = str(tmp_path / "g.npz")
    save_npz(p, g)
    g2 = load_npz(p)
    assert g2.n == g.n and g2.m == g.m
    assert np.array_equal(g2.indptr, g.indptr)
    assert np.array_equal(g2.indices, g.indices)
    assert np.allclose(g2.weights, g.weights)


def test_edge_list_roundtrip(tmp_path):
    g = rmat(7, 4, seed=4)
    p = str(tmp_path / "g.txt.gz")
    save_edge_list(p, g)
    # the file already contains both directions: no re-symmetrize
    g2 = load_edge_list(p, symmetrize=False)
    assert g2.m == g.m
    # loader compacts ids (isolated vertices vanish): compare under the
    # same compaction
    s1, d1, w1 = g.edges()
    ids = np.unique(np.concatenate([s1, np.asarray(d1)]))
    remap = np.zeros(int(ids.max()) + 1, np.int64)
    remap[ids] = np.arange(ids.size)
    s2, d2, w2 = g2.edges()
    o1 = np.lexsort((np.asarray(d1), remap[s1]))
    o2 = np.lexsort((np.asarray(d2), np.asarray(s2)))
    assert np.array_equal(remap[s1][o1], np.asarray(s2)[o2])
    assert np.array_equal(remap[np.asarray(d1)][o1],
                          np.asarray(d2)[o2])
    # %.6g text round-trip: weights match to ~1e-4 relative
    np.testing.assert_allclose(np.asarray(w1)[o1], np.asarray(w2)[o2],
                               rtol=1e-4)


def test_edge_list_comments_and_unweighted(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text("# a comment\n0 1\n1 2\n2 0\n")
    g = load_edge_list(str(p), symmetrize=False)
    assert g.n == 3 and g.m == 3
    assert np.all(g.weights == 1.0)


def test_edge_list_mixed_arity_raises(tmp_path):
    """Inferring weightedness from the first line silently dropped the
    weights of every later 3-column line; a mix must fail loudly."""
    import pytest
    p = tmp_path / "g.txt"
    p.write_text("0 1\n1 2 3.5\n")
    with pytest.raises(ValueError, match="inconsistent edge-list arity"):
        load_edge_list(str(p))


def test_edge_list_explicit_weighted_stays_lenient(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text("0 1\n1 2 3.5\n2 0 2.0 extra-col-ignored\n")
    g = load_edge_list(str(p), symmetrize=False, weighted=True)
    s, d, w = g.edges()
    got = {(int(a), int(b)): float(c) for a, b, c in zip(s, d, w)}
    assert got == {(0, 1): 1.0, (1, 2): 3.5, (2, 0): 2.0}
    gu = load_edge_list(str(p), symmetrize=False, weighted=False)
    assert np.all(gu.weights == 1.0)


def test_edge_list_compacts_sparse_64bit_ids(tmp_path):
    """SNAP dumps carry sparse 64-bit vertex ids; compaction is a sorted
    search, never a dense [0, max_id] table (which would OOM here)."""
    big = 10 ** 14
    p = tmp_path / "g.txt"
    p.write_text(f"{big} {big + 7}\n{big + 7} 12\n12 {big}\n")
    g = load_edge_list(str(p), symmetrize=False)
    assert g.n == 3 and g.m == 3
    # ids map order-preserving: 12 -> 0, big -> 1, big+7 -> 2
    s, d, _ = g.edges()
    assert {(int(a), int(b)) for a, b in zip(s, d)} == \
        {(1, 2), (2, 0), (0, 1)}


def test_edge_list_gz_fuzz_roundtrip(tmp_path):
    """Deterministic fuzz of the text ⇄ CSRGraph ⇄ npz loop: duplicate
    edges, self-loops, isolated vertices, comments, gz compression.  The
    reloaded graph equals the saved one edge-for-edge under the loader's
    id compaction (isolated vertices vanish, self-loops drop, duplicates
    fold by min weight — all of which from_edges already canonicalized on
    the way in, so the round trip is the identity)."""
    from repro.core.graph import CSRGraph
    for seed in range(6):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(8, 40))
        m = int(rng.integers(n, 5 * n))
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)      # self-loops + duplicates likely
        w = rng.integers(1, 100, m).astype(np.float64)  # %.6g-exact
        g = CSRGraph.from_edges(n, src, dst, w)
        ids = np.unique(np.concatenate([src[src != dst], dst[src != dst]]))

        p = str(tmp_path / f"g{seed}.txt.gz")
        save_edge_list(p, g)
        g2 = load_edge_list(p, symmetrize=False)
        assert g2.n == ids.size          # isolated vertices compact away
        assert g2.m == g.m
        s1, d1, w1 = g.edges()
        remap = {int(v): i for i, v in enumerate(ids)}
        e1 = sorted((remap[int(a)], remap[int(b)], float(c))
                    for a, b, c in zip(s1, d1, w1))
        s2, d2, w2 = g2.edges()
        e2 = sorted((int(a), int(b), float(c))
                    for a, b, c in zip(s2, d2, w2))
        assert e1 == e2

        pz = str(tmp_path / f"g{seed}.npz")
        save_npz(pz, g2)
        g3 = load_npz(pz)
        assert np.array_equal(g3.indptr, g2.indptr)
        assert np.array_equal(g3.indices, g2.indices)
        assert np.array_equal(g3.weights, g2.weights)


def test_from_edges_is_idempotent_under_its_own_canonicalization():
    """Feeding a canonicalized graph's edges back through from_edges is
    the identity: dedup, self-loop dropping, and sorting are stable."""
    from repro.core.graph import CSRGraph
    rng = np.random.default_rng(42)
    src = rng.integers(0, 30, 200)
    dst = rng.integers(0, 30, 200)
    w = rng.uniform(0.5, 4.0, 200)
    g = CSRGraph.from_edges(30, src, dst, w)
    s, d, ww = g.edges()
    g2 = CSRGraph.from_edges(30, np.asarray(s), np.asarray(d),
                             np.asarray(ww))
    assert np.array_equal(g.indptr, g2.indptr)
    assert np.array_equal(g.indices, g2.indices)
    assert np.array_equal(g.weights, g2.weights)
