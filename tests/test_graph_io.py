"""Graph I/O round-trips."""
import numpy as np

from repro.graphs.generators import rmat
from repro.graphs.io import (load_edge_list, load_npz, save_edge_list,
                             save_npz)


def test_npz_roundtrip(tmp_path):
    g = rmat(8, 4, seed=3)
    p = str(tmp_path / "g.npz")
    save_npz(p, g)
    g2 = load_npz(p)
    assert g2.n == g.n and g2.m == g.m
    assert np.array_equal(g2.indptr, g.indptr)
    assert np.array_equal(g2.indices, g.indices)
    assert np.allclose(g2.weights, g.weights)


def test_edge_list_roundtrip(tmp_path):
    g = rmat(7, 4, seed=4)
    p = str(tmp_path / "g.txt.gz")
    save_edge_list(p, g)
    # the file already contains both directions: no re-symmetrize
    g2 = load_edge_list(p, symmetrize=False)
    assert g2.m == g.m
    # loader compacts ids (isolated vertices vanish): compare under the
    # same compaction
    s1, d1, w1 = g.edges()
    ids = np.unique(np.concatenate([s1, np.asarray(d1)]))
    remap = np.zeros(int(ids.max()) + 1, np.int64)
    remap[ids] = np.arange(ids.size)
    s2, d2, w2 = g2.edges()
    o1 = np.lexsort((np.asarray(d1), remap[s1]))
    o2 = np.lexsort((np.asarray(d2), np.asarray(s2)))
    assert np.array_equal(remap[s1][o1], np.asarray(s2)[o2])
    assert np.array_equal(remap[np.asarray(d1)][o1],
                          np.asarray(d2)[o2])
    # %.6g text round-trip: weights match to ~1e-4 relative
    np.testing.assert_allclose(np.asarray(w1)[o1], np.asarray(w2)[o2],
                               rtol=1e-4)


def test_edge_list_comments_and_unweighted(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text("# a comment\n0 1\n1 2\n2 0\n")
    g = load_edge_list(str(p), symmetrize=False)
    assert g.n == 3 and g.m == 3
    assert np.all(g.weights == 1.0)
