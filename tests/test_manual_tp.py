"""Manual (shard_map) TP blocks == auto-GSPMD forward, bit-for-bit-ish.

Subprocess: needs 8 fake devices before jax init.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import compat_make_mesh, set_mesh
    from repro.configs.base import get_config
    from repro.models.factory import build_model
    from repro.launch.steps import rules_for
    from repro.models import manual_tp

    mesh = compat_make_mesh((2, 4), ("data", "model"))

    for arch in ("qwen2-72b", "stablelm-12b"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)
        batch = {"tokens": tokens}

        rules = rules_for(cfg, mesh)
        assert manual_tp.mlp_eligible(cfg, rules), (arch, cfg.d_ff)
        assert manual_tp.attn_eligible(cfg, rules), (
            arch, cfg.n_heads, cfg.n_kv_heads)

        base, _ = model.logits(params, batch, remat=False)   # no rules

        rules.rules["manual_tp"] = True
        with set_mesh(mesh):
            got, _ = jax.jit(lambda p, b: model.logits(
                p, b, rules=rules, remat=False))(params, batch)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - base.astype(jnp.float32))))
        print(arch, "manual-vs-auto max err:", err)
        # bf16 psum-reorder noise; with compute_dtype=float32 the same
        # comparison lands at 1.8e-6 (verified during bring-up)
        assert err < 6e-2, (arch, err)
    print("ALL OK")
""")


@pytest.mark.slow
def test_manual_tp_matches_auto():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL OK" in out.stdout
