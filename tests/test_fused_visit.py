"""Kernel-resident visit (ISSUE 7): the differential kernel-parity harness.

What these tests pin (DESIGN.md §2.4):
  * the fused Pallas visit (``FPPEngine(fused=True)``) == the XLA megastep
    == the legacy per-visit host loop, bit for bit, for minplus (weighted
    sssp AND unit-weight bfs) under every deterministic policy — value
    planes, exact (hi, lo) edge counters, visit order, visit count;
  * push (ppr): bit-identical to the XLA megastep under the deterministic
    policies AND under ``random`` (both draw the same on-device threefry
    stream, so the visit sequences coincide); eps-parity against the
    sequential ACL push oracle always;
  * sparse-frontier mode == dense mode bitwise — skipping all-+inf source
    chunks is a work optimization, never a numeric one;
  * all of it runs in Pallas interpret mode on CPU, and identically under
    a forced 8-device host platform (subprocess, as in test_distributed —
    the flag must be set before jax initializes).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import oracles  # noqa: E402
from repro.core.engine import FPPEngine  # noqa: E402
from repro.core.graph import CSRGraph  # noqa: E402
from repro.core.partition import partition  # noqa: E402
from repro.core.scheduler import POLICIES  # noqa: E402
from repro.core.yielding import YieldConfig  # noqa: E402
from repro.graphs.generators import grid2d, rmat  # noqa: E402

DET_POLICIES = tuple(p for p in POLICIES if p != "random")


def _norm(x):
    return np.nan_to_num(np.asarray(x), posinf=1e30)


def _minplus_setup(unit_weights=False):
    g = grid2d(12, 12, seed=0)
    if unit_weights:
        g = CSRGraph(indptr=g.indptr, indices=g.indices,
                     weights=np.ones_like(g.weights), n=g.n, m=g.m)
    bg, perm = partition(g, 32, method="bfs")
    return g, bg, perm, perm[np.array([0, 70, 143])]


def _push_setup():
    g = rmat(8, 6, seed=5)
    bg, perm = partition(g, 64, method="bfs")
    deg = g.out_degree()
    srcs_o = np.random.default_rng(0).choice(np.flatnonzero(deg > 0), 3,
                                             replace=False)
    return g, bg, perm, srcs_o, perm[srcs_o]


def _assert_identical(a, b, values_only=False):
    """Full-result bit parity: planes, exact counters, order, stats."""
    np.testing.assert_array_equal(_norm(a.values), _norm(b.values))
    if a.residual is not None or b.residual is not None:
        np.testing.assert_array_equal(np.asarray(a.residual),
                                      np.asarray(b.residual))
    if values_only:
        return
    np.testing.assert_array_equal(a.edges_processed, b.edges_processed)
    assert a.visit_order == b.visit_order
    assert a.stats.visits == b.stats.visits
    assert a.stats.rounds == b.stats.rounds


# --------------------------------------------------------- minplus family

@pytest.mark.parametrize("policy", DET_POLICIES)
@pytest.mark.parametrize("K", [1, 8, 64])
def test_fused_minplus_bit_identical(policy, K):
    """fused == megastep == host loop for weighted SSSP: the exact-min
    reassociation argument (fused.py docstring) means every path candidate
    is the same f32 sum, so even the kernel's different round/emission
    order must reproduce the oracle down to the bit."""
    _, bg, _, srcs = _minplus_setup()
    kw = dict(mode="minplus", num_queries=len(srcs), schedule=policy,
              k_visits=K, yield_config=YieldConfig(delta=2.0))
    eng = FPPEngine(bg, **kw)
    fus = FPPEngine(bg, fused=True, **kw)
    host = eng.run(srcs, host_loop=True, record_order=True)
    mega = eng.run(srcs, record_order=True)
    got = fus.run(srcs, record_order=True)
    _assert_identical(got, mega)
    _assert_identical(got, host)
    # the counters are integral and exact (the (hi, lo) int32 carry)
    assert (got.edges_processed == np.round(got.edges_processed)).all()
    assert (got.edges_processed > 0).all()


def test_fused_bfs_unit_weights_and_oracle():
    """BFS = minplus over unit weights with the level-synchronous Δ=1
    window; fused must match the host loop bitwise and the BFS levels
    exactly (small integers are exact in f32)."""
    g, bg, perm, srcs = _minplus_setup(unit_weights=True)
    kw = dict(mode="minplus", num_queries=len(srcs),
              yield_config=YieldConfig(delta=1.0))
    eng = FPPEngine(bg, **kw)
    fus = FPPEngine(bg, fused=True, **kw)
    host = eng.run(srcs, host_loop=True, record_order=True)
    got = fus.run(srcs, record_order=True)
    _assert_identical(got, host)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    for qi, s in enumerate(srcs):
        want, _ = oracles.dijkstra(g, int(inv[s]))
        np.testing.assert_array_equal(_norm(got.values[qi][perm]),
                                      _norm(want))


@pytest.mark.parametrize("unit_weights", [False, True])
def test_fused_sparse_frontier_agrees_with_dense(unit_weights):
    """Chunk-skipping on all-+inf source chunks is bit-invisible: the
    sparse mode must replay the dense mode's planes, counters, and visit
    order exactly (min over a skipped chunk's +inf candidates is the
    identity)."""
    _, bg, _, srcs = _minplus_setup(unit_weights=unit_weights)
    delta = 1.0 if unit_weights else 2.0
    kw = dict(mode="minplus", num_queries=len(srcs), fused=True,
              yield_config=YieldConfig(delta=delta))
    dense = FPPEngine(bg, frontier_mode="dense", **kw)
    sparse = FPPEngine(bg, frontier_mode="sparse", **kw)
    _assert_identical(sparse.run(srcs, record_order=True),
                      dense.run(srcs, record_order=True))


def test_fused_sparse_rejects_push():
    _, bg, _, _, srcs = _push_setup()
    with pytest.raises(ValueError, match="sparse"):
        FPPEngine(bg, mode="push", num_queries=len(srcs), fused=True,
                  frontier_mode="sparse")


# ------------------------------------------------------------ push family

@pytest.mark.parametrize("policy", DET_POLICIES)
def test_fused_push_bit_identical_and_oracle(policy):
    """Deterministic push: the fused kernel replays the exact visit
    sequence, so the float arithmetic is the same arithmetic — planes and
    residuals bit-identical to megastep AND host loop; the sequential ACL
    oracle bounds the answer within eps as always."""
    g, bg, perm, srcs_o, srcs = _push_setup()
    eps = 1e-4
    deg = np.maximum(g.out_degree(), 1)
    kw = dict(mode="push", num_queries=len(srcs), schedule=policy, eps=eps)
    eng = FPPEngine(bg, **kw)
    fus = FPPEngine(bg, fused=True, **kw)
    host = eng.run(srcs, host_loop=True, record_order=True)
    mega = eng.run(srcs, record_order=True)
    got = fus.run(srcs, record_order=True)
    _assert_identical(got, mega)
    _assert_identical(got, host)
    for qi, s in enumerate(srcs_o):
        want_p, _, _ = oracles.ppr_push(g, int(s), eps=eps)
        err = np.abs(got.values[qi][perm] - want_p) / deg
        assert err.max() <= 2 * eps, (policy, qi)
        mass = got.values[qi].sum() + got.residual[qi].sum()
        assert abs(mass - 1.0) < 5e-3, (policy, qi)


def test_fused_push_random_policy():
    """Under ``random`` the fused and XLA megasteps split the same seeded
    threefry key per visit, so they take identical visit sequences and
    stay bit-identical to each other; the host loop draws from a different
    (host-side) stream, so parity there is the eps guarantee, not bits."""
    g, bg, perm, srcs_o, srcs = _push_setup()
    eps = 1e-4
    deg = np.maximum(g.out_degree(), 1)
    kw = dict(mode="push", num_queries=len(srcs), schedule="random",
              eps=eps, seed=11)
    mega = FPPEngine(bg, **kw).run(srcs, record_order=True)
    got = FPPEngine(bg, fused=True, **kw).run(srcs, record_order=True)
    _assert_identical(got, mega)
    for qi, s in enumerate(srcs_o):
        want_p, _, _ = oracles.ppr_push(g, int(s), eps=eps)
        err = np.abs(got.values[qi][perm] - want_p) / deg
        assert err.max() <= 2 * eps, qi


# ------------------------------------------------- device-count agnosticism

_DEVCOUNT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.core.engine import FPPEngine
    from repro.core.partition import partition
    from repro.core.yielding import YieldConfig
    from repro.graphs.generators import grid2d, rmat

    def norm(x):
        return np.nan_to_num(np.asarray(x), posinf=1e30)

    g = grid2d(12, 12, seed=0)
    bg, perm = partition(g, 32, method="bfs")
    srcs = perm[np.array([0, 70, 143])]
    kw = dict(mode="minplus", num_queries=3,
              yield_config=YieldConfig(delta=2.0))
    host = FPPEngine(bg, **kw).run(srcs, host_loop=True, record_order=True)
    got = FPPEngine(bg, fused=True, **kw).run(srcs, record_order=True)
    np.testing.assert_array_equal(norm(got.values), norm(host.values))
    np.testing.assert_array_equal(got.edges_processed, host.edges_processed)
    assert got.visit_order == host.visit_order

    g2 = rmat(8, 6, seed=5)
    bg2, perm2 = partition(g2, 64, method="bfs")
    srcs2 = perm2[np.array([0, 10, 33])]
    kw2 = dict(mode="push", num_queries=3, eps=1e-4)
    h2 = FPPEngine(bg2, **kw2).run(srcs2, record_order=True)
    g2r = FPPEngine(bg2, fused=True, **kw2).run(srcs2, record_order=True)
    np.testing.assert_array_equal(g2r.values, h2.values)
    np.testing.assert_array_equal(g2r.residual, h2.residual)
    assert g2r.visit_order == h2.visit_order
    print("FUSED_8DEV_OK")
""")


@pytest.mark.slow
def test_fused_parity_under_8_host_devices():
    """The fused kernel is single-device code; a multi-device host platform
    (the distributed tests' environment) must not perturb its bits.  The
    in-process suite above covers device count 1."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DEVCOUNT_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "FUSED_8DEV_OK" in out.stdout
