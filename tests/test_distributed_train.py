"""Distributed LM runtime on a multi-device host mesh (subprocess: the
8-device XLA flag must precede jax init; the main test process keeps 1)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.distributed import _shard_map as shard_map
    from repro.launch.mesh import compat_make_mesh, set_mesh

    mesh = compat_make_mesh((2, 4), ("data", "model"))

    # ---- 1. compressed_psum == f32 psum within quantization tolerance ----
    from repro.train.compress import compressed_psum
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)),
                    jnp.float32)
    def f(x):
        return compressed_psum(x, "model")
    got = shard_map(f, mesh=mesh, in_specs=P(None, "model"),
                    out_specs=P(None, "model"))(x)
    def g(x):
        return jax.lax.psum(x, "model")
    want = shard_map(g, mesh=mesh, in_specs=P(None, "model"),
                     out_specs=P(None, "model"))(x)
    err = float(jnp.max(jnp.abs(got - want)))
    rel = err / float(jnp.max(jnp.abs(want)))
    assert rel < 0.02, f"compressed psum rel err {rel}"
    print("compressed_psum ok", rel)

    # ---- 2. sharded train step == single-device train step --------------
    from repro.configs.base import get_config
    from repro.configs.shapes import ShapeConfig
    from repro.models.factory import build_model, input_specs
    from repro.launch.steps import rules_for, build_train_setup
    from repro.train.optimizer import AdamW, constant
    from repro.train.train_step import (init_train_state, make_train_step,
                                        state_shardings, batch_shardings)
    from repro.train.data import batch_for_step

    cfg = get_config("qwen2-72b").reduced()
    shape = ShapeConfig("t", "train", 32, 4)
    model = build_model(cfg)
    opt = AdamW()
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    batch = batch_for_step(cfg, shape, 0)

    # same microbatch count: the mb-averaged CE metric (mean of per-mb
    # ratios) differs from the single-batch ratio-of-sums when doc-length
    # masks are uneven across microbatches
    plain = jax.jit(make_train_step(model, opt, constant(1e-3),
                                    microbatches=2))
    s1, m1 = plain(state, batch)

    rules = rules_for(cfg, mesh)
    box = {}
    def finit(k):
        p, a = model.init(k)
        box["axes"] = a
        return p
    jax.eval_shape(finit, jax.random.PRNGKey(0))
    st_sh = state_shardings(state, box["axes"], rules)
    b_sh = batch_shardings({k: v for k, v in batch.items()}, rules)
    sharded = jax.jit(make_train_step(model, opt, constant(1e-3),
                                      rules=rules, microbatches=2),
                      in_shardings=(st_sh, b_sh))
    with set_mesh(mesh):
        s2, m2 = sharded(state, batch)
    # microbatched grad averaging reorders float sums: tolerance not exact
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(s1.params),
                            jax.tree.leaves(s2.params)))
    print("sharded-vs-plain param delta:", d)
    assert d < 5e-3, d
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3

    # ---- 3. partitioned-KV decode == local decode ------------------------
    from repro.models import attention as A
    rng = np.random.default_rng(1)
    B, S, H, Hkv, hd = 4, 32, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    length = jnp.asarray([5, 17, 32, 9], jnp.int32)
    want = A.decode_attend_local(q, k, v, jnp.arange(S), length)
    with set_mesh(mesh):
        got = A.decode_attend_partitioned(q, k, v, length, mesh,
                                          batch_axes=("data",))
    err = float(jnp.max(jnp.abs(got - want)))
    print("partitioned decode err:", err)
    assert err < 1e-5
    print("ALL OK")
""")


@pytest.mark.slow
def test_distributed_train_and_decode():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL OK" in out.stdout
