"""Train substrate: optimizer math, compression, data, loop behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, get_config
from repro.configs.shapes import ShapeConfig
from repro.models.factory import build_model
from repro.train.compress import (compress_with_error_feedback,
                                  dequantize_int8, quantize_int8)
from repro.train.data import DataConfig, batch_for_step, host_slice
from repro.train.loop import LoopConfig, run_loop
from repro.train.optimizer import (AdamW, clip_by_global_norm, constant,
                                   global_norm, rsqrt, warmup_cosine)
from repro.train.train_step import init_train_state, make_train_step

CFG = get_config("starcoder2-7b").reduced()
SHAPE = ShapeConfig("t", "train", 32, 4)


def test_adamw_matches_reference_update():
    """One AdamW step on a scalar matches the closed-form update."""
    opt = AdamW(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                clip_norm=None)
    params = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.5])}
    st = opt.init(params)
    new_p, st2 = opt.update(g, st, params, lr=0.1)
    m = 0.1 * 0.5 / (1 - 0.9)          # bias-corrected first moment
    v = 0.01 * 0.25 / (1 - 0.99)
    want = 2.0 - 0.1 * m / (np.sqrt(v) + 1e-8)
    assert np.allclose(float(new_p["w"][0]), want, rtol=1e-6)
    assert int(st2.count) == 1


def test_adamw_weight_decay_pulls_to_zero():
    opt = AdamW(weight_decay=0.5, clip_norm=None)
    params = {"w": jnp.asarray([10.0])}
    st = opt.init(params)
    p, st = opt.update({"w": jnp.zeros(1)}, st, params, lr=0.1)
    assert float(p["w"][0]) < 10.0


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    n = float(global_norm(tree))
    clipped = clip_by_global_norm(tree, n / 2)
    assert np.isclose(float(global_norm(clipped)), n / 2, rtol=1e-5)
    same = clip_by_global_norm(tree, n * 2)
    assert np.isclose(float(global_norm(same)), n, rtol=1e-6)


def test_master_weights_bf16_params_converge():
    """bf16 params + f32 master: training still reduces loss."""
    import dataclasses
    cfg = dataclasses.replace(CFG, param_dtype="bfloat16")
    model = build_model(cfg)
    opt = AdamW()
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    assert state.opt.master is not None
    ts = jax.jit(make_train_step(model, opt, constant(3e-3)))
    losses = []
    for step in range(8):
        state, m = ts(state, batch_for_step(cfg, SHAPE, step))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    # params remain the bf16 image of the master weights
    leaf = jax.tree.leaves(state.params)[0]
    assert leaf.dtype == jnp.bfloat16


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)) * 3.0, jnp.float32)
    q, s = quantize_int8(x)
    err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - x)))
    assert err <= float(s) / 2 + 1e-7


def test_error_feedback_is_unbiased_over_steps():
    """With EF, the accumulated applied update approaches the true sum."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.normal(size=(64,)), jnp.float32)
              for _ in range(50)]
    ef = {"g": jnp.zeros((64,))}
    applied = jnp.zeros((64,))
    for g in g_true:
        out, ef_new = compress_with_error_feedback({"g": g}, ef)
        ef = ef_new
        applied = applied + out["g"]
    true_sum = sum(g_true)
    # residual bounded by one quantization step, not accumulating
    resid = float(jnp.max(jnp.abs(applied + ef["g"] - true_sum)))
    assert resid < 1e-4


def test_compressed_training_converges():
    model = build_model(CFG)
    opt = AdamW()
    state = init_train_state(model, jax.random.PRNGKey(0), opt,
                             compression=True)
    ts = jax.jit(make_train_step(model, opt, constant(3e-3),
                                 compression=True))
    losses = []
    for step in range(10):
        state, m = ts(state, batch_for_step(CFG, SHAPE, step))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_data_is_deterministic_and_host_shardable():
    b1 = batch_for_step(CFG, SHAPE, 7)
    b2 = batch_for_step(CFG, SHAPE, 7)
    assert all(bool(jnp.all(b1[k] == b2[k])) for k in b1)
    b3 = batch_for_step(CFG, SHAPE, 8)
    assert not bool(jnp.all(b1["tokens"] == b3["tokens"]))
    s0 = host_slice(b1, 0, 2)
    s1 = host_slice(b1, 1, 2)
    assert s0["tokens"].shape[0] == SHAPE.global_batch // 2
    assert bool(jnp.all(jnp.concatenate([s0["tokens"], s1["tokens"]])
                        == b1["tokens"]))


def test_loop_detects_stragglers():
    import time
    model = build_model(CFG)
    opt = AdamW()
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    ts = jax.jit(make_train_step(model, opt, constant(1e-3)))
    events = []
    counter = {"n": 0}

    def slow_step(state, batch):
        counter["n"] += 1
        if counter["n"] == 15:
            time.sleep(1.0)       # simulated slow host inside the step
        return ts(state, batch)

    lc = LoopConfig(n_steps=16, ckpt_dir=None, log_every=100,
                    straggler_factor=3.0)
    _, stats = run_loop(slow_step, state,
                        lambda s: batch_for_step(CFG, SHAPE, s), lc,
                        log=lambda *a: None,
                        on_straggler=lambda *a: events.append(a))
    assert stats.straggler_events >= 1 and events


def test_lr_schedules():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert np.isclose(float(lr(jnp.int32(10))), 1.0, atol=0.01)
    assert float(lr(jnp.int32(100))) < 0.2
    r = rsqrt(1.0, warmup=100)
    assert float(r(jnp.int32(400))) == pytest.approx(0.5)
