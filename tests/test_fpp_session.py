"""FPPSession front door: planning, backend agreement, streaming.

The session contract under test (DESIGN.md §3):
  * the planner's block size fits the device memory model;
  * the same query set through engine / distributed / baselines matches
    core/oracles.py, with identical result dtypes and shapes;
  * a staggered-arrival streaming run returns the same answers as the
    one-shot run of the union.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import oracles
from repro.fpp import FPPSession, MemoryModel
from repro.fpp.planner import model_block_size
from repro.graphs.generators import grid2d, rmat


# ---------------------------------------------------------------- planning


def test_planner_block_size_fits_memory_model():
    g = grid2d(32, 32, seed=0)
    for vmem in (1 << 20, 8 << 20, 96 << 20):
        mem = MemoryModel(vmem_bytes=vmem)
        b = model_block_size(g, num_queries=64, mem=mem)
        assert mem.working_set(b, 64) <= vmem
    # tighter budget can never pick a larger block
    assert (model_block_size(g, 64, MemoryModel(vmem_bytes=1 << 20))
            <= model_block_size(g, 64, MemoryModel(vmem_bytes=96 << 20)))


def test_planner_keeps_enough_partitions():
    g = grid2d(12, 12, seed=1)           # 144 vertices
    b = model_block_size(g, 4, MemoryModel())
    assert -(-g.n // b) >= 2             # never collapses to one partition


def test_plan_tune_measures_and_picks_feasible():
    g = grid2d(16, 16, seed=2)
    srcs = np.array([0, 100, 200, 255])
    sess = FPPSession(g).plan(num_queries=4, tune=True, tune_sources=srcs)
    plan = sess.current_plan
    assert plan.tuned and len(plan.tuning_rows) >= 1
    assert plan.mem.fits(plan.block_size, 4, g.n)
    # the tuned pick minimizes the recorded traffic objective
    rows = [dict(r) for r in plan.tuning_rows]
    best = min(rows, key=lambda r: (r["traffic_bytes"], r["runtime_s"]))
    assert plan.block_size == best["block_size"]


# ------------------------------------------------------- backend agreement


def _oracle_sssp(g, srcs):
    return np.stack([oracles.dijkstra(g, int(s))[0] for s in srcs])


def test_engine_and_baselines_match_oracles_same_contract():
    g = grid2d(12, 12, seed=3)
    srcs = np.array([0, 70, 143, 5])
    want = _oracle_sssp(g, srcs)
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    for backend in ("engine", "baselines"):
        res = sess.run("sssp", srcs, backend=backend)
        assert res.values.dtype == np.float32          # identical dtypes
        assert res.values.shape == (len(srcs), g.n)    # identical shapes
        assert res.edges_processed.dtype == np.float64
        assert res.edges_processed.shape == (len(srcs),)
        np.testing.assert_allclose(
            np.nan_to_num(res.values, posinf=1e30),
            np.nan_to_num(want, posinf=1e30), atol=1e-3)


def test_bfs_both_backends_match_oracle():
    g = rmat(7, 4, seed=4, weighted=False)
    srcs = np.array([0, 17, 90])
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    for backend in ("engine", "baselines"):
        res = sess.run("bfs", srcs, backend=backend)
        for qi, s in enumerate(srcs):
            want, _ = oracles.bfs(g, int(s))
            got = np.where(np.isfinite(res.values[qi]),
                           res.values[qi], -1).astype(np.int32)
            assert (got == want).all(), (backend, qi)


def test_ppr_backends_contract_and_accuracy():
    """Three-way PPR parity: engine vs distributed vs baselines, all against
    the sequential ACL oracle with the same tolerance (one visit algebra)."""
    g = rmat(7, 6, seed=5)
    deg = g.out_degree()
    srcs = np.random.default_rng(0).choice(np.flatnonzero(deg > 0), 3,
                                           replace=False)
    eps = 1e-4
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    outs = {}
    for backend in ("engine", "distributed", "baselines"):
        res = sess.run("ppr", srcs, backend=backend, eps=eps)
        assert res.values.dtype == np.float32
        assert res.values.shape == (len(srcs), g.n)
        assert res.residual is not None and res.residual.dtype == np.float32
        outs[backend] = res
    for qi, s in enumerate(srcs):
        want_p, _, _ = oracles.ppr_push(g, int(s), eps=eps)
        for backend, res in outs.items():
            err = np.abs(res.values[qi] - want_p) / np.maximum(deg, 1)
            assert err.max() <= 2 * eps, (backend, qi)
            if backend != "baselines":   # Jacobi baseline reports residual=0
                # buffered runtimes conserve p + r mass exactly
                mass = res.values[qi].sum() + res.residual[qi].sum()
                assert abs(mass - 1.0) < 5e-3, (backend, qi)


def test_every_backend_kind_pair_dispatches():
    """No (backend, kind) combination raises — the visit algebra serves both
    families on every execution path (ISSUE 3 acceptance)."""
    from repro.fpp.backends import BACKENDS, KINDS
    g = grid2d(8, 8, seed=9)
    srcs = np.array([0, 63])
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=16)
    for backend in BACKENDS:
        for kind in KINDS:
            res = sess.run(kind, srcs, backend=backend, eps=1e-3)
            assert res.values.shape == (len(srcs), g.n), (backend, kind)
            assert res.edges_processed.dtype == np.float64, (backend, kind)
            # counts are exact integers, not drifted float32 sums
            assert (res.edges_processed
                    == np.round(res.edges_processed)).all(), (backend, kind)


_DISTRIBUTED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.core import oracles
    from repro.fpp import FPPSession
    from repro.graphs.generators import grid2d

    g = grid2d(12, 12, seed=3)
    srcs = np.array([0, 70, 143, 5])
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    res = sess.run("sssp", srcs, backend="distributed")
    assert res.values.dtype == np.float32, res.values.dtype
    assert res.values.shape == (len(srcs), g.n), res.values.shape
    assert res.edges_processed.dtype == np.float64
    for qi, s in enumerate(srcs):
        want, _ = oracles.dijkstra(g, int(s))
        np.testing.assert_allclose(np.nan_to_num(res.values[qi], posinf=1e30),
                                   np.nan_to_num(want, posinf=1e30), atol=1e-3)
    assert res.stats["supersteps"] > 0

    # push kind through the same distributed path (same algebra, + not min)
    eps = 1e-3
    deg = np.maximum(g.out_degree(), 1)
    pres = sess.run("ppr", srcs, backend="distributed", eps=eps)
    assert pres.values.dtype == np.float32 and pres.residual is not None
    for qi, s in enumerate(srcs):
        want_p, _, _ = oracles.ppr_push(g, int(s), eps=eps)
        err = np.abs(pres.values[qi] - want_p) / deg
        assert err.max() <= 2 * eps, (qi, float(err.max()))
    print("SESSION_DISTRIBUTED_OK")
""")


def test_distributed_backend_matches_oracles_eight_device_mesh():
    """Same queries (sssp AND ppr) through the shard_map runtime on a
    forced-8-device CPU mesh — the ISSUE 3 acceptance configuration.

    Subprocess because the host-platform device-count flag must be set
    before jax initializes (same pattern as tests/test_distributed.py).
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DISTRIBUTED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SESSION_DISTRIBUTED_OK" in out.stdout


# ----------------------------------------------------------------- stream


def test_streaming_staggered_matches_one_shot():
    g = grid2d(12, 12, seed=6)
    srcs = np.array([0, 40, 80, 120, 143, 7])
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    one = sess.run("sssp", srcs)
    # capacity below the union size forces admission-queue + lane recycling
    stream = sess.stream("sssp", capacity=4)
    first = stream.submit(srcs[:3])
    stream.pump(3)                        # in-flight work between arrivals
    second = stream.submit(srcs[3:])
    out = stream.run()
    assert len(out) == len(srcs)
    for i, qid in enumerate(first + second):
        q = stream.result(qid)
        assert q.done and q.values.dtype == np.float32
        np.testing.assert_array_equal(out[qid], one.values[i])


def test_streaming_ppr_staggered_matches_one_shot_union():
    """The push twin of the minplus staggered-vs-one-shot property: late
    arrivals answer within the same eps tolerance the one-shot union carries
    (push visit order affects rounding, not the ACL guarantee)."""
    g = grid2d(10, 10, seed=11)
    deg = g.out_degree()
    srcs = np.array([0, 33, 55, 77, 99])
    eps = 1e-3
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    one = sess.run("ppr", srcs, eps=eps)
    # capacity below the union size forces admission-queue + lane recycling
    stream = sess.stream("ppr", capacity=3, eps=eps)
    first = stream.submit(srcs[:2])
    stream.pump(3)                        # in-flight work between arrivals
    second = stream.submit(srcs[2:])
    out = stream.run()
    assert len(out) == len(srcs)
    degc = np.maximum(deg, 1)
    for i, qid in enumerate(first + second):
        q = stream.result(qid)
        assert q.done and q.values.dtype == np.float32
        # each run sits within 2eps of the truth, so mutually within 4eps
        diff = np.abs(out[qid] - one.values[i]) / degc
        assert diff.max() <= 4 * eps, (i, diff.max())
        mass = q.values.sum() + q.residual.sum()
        assert abs(mass - 1.0) < 5e-3, i


def test_streaming_ppr_invariants():
    g = grid2d(10, 10, seed=7)
    srcs = np.array([0, 55, 99])
    eps = 1e-3
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    stream = sess.stream("ppr", capacity=2, eps=eps)
    qids = stream.submit(srcs[:2])
    stream.pump(2)
    qids += stream.submit(srcs[2:])
    out = stream.run()
    deg = g.out_degree()
    for qid, s in zip(qids, srcs):
        q = stream.result(qid)
        # mass conservation and the ACL terminal condition hold per lane
        assert abs(q.values.sum() + q.residual.sum() - 1.0) < 5e-3
        assert (q.residual <= eps * np.maximum(deg, 1) + 1e-6).all()
        want_p, _, _ = oracles.ppr_push(g, int(s), eps=eps)
        err = np.abs(q.values - want_p) / np.maximum(deg, 1)
        assert err.max() <= 2 * eps


def test_streaming_empty_run_terminates():
    g = grid2d(6, 6, seed=8)
    sess = FPPSession(g).plan(num_queries=2, block_size=16)
    stream = sess.stream("sssp", capacity=2)
    assert stream.run() == {}
    assert stream.visits == 0
