"""Serving engine: continuous batching exactness + slot lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.factory import build_model
from repro.serve.engine import ContinuousBatcher, Request, insert_slot


def _gen_alone(model, params, prompt, n, max_len, extras=None):
    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    if extras:
        batch.update({k: jnp.asarray(v[None]) for k, v in extras.items()})
    last, st = model.prefill(params, batch, max_len=max_len)
    out = [int(jnp.argmax(last, -1)[0])]
    for _ in range(n - 1):
        lg, st = model.decode(
            params, jnp.asarray([[out[-1]]], jnp.int32), st)
        out.append(int(jnp.argmax(lg, -1)[0]))
    return out


@pytest.mark.parametrize("arch", ["starcoder2-7b", "falcon-mamba-7b",
                                  "recurrentgemma-2b"])
def test_continuous_batching_matches_sequential(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    max_len = 48
    prompts = [rng.integers(0, cfg.vocab, T).astype(np.int32)
               for T in (5, 8, 6, 7)]
    refs = [_gen_alone(model, params, p, 5, max_len) for p in prompts]
    b = ContinuousBatcher(model, params, batch_size=2, max_len=max_len)
    for i, p in enumerate(prompts):
        b.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    got = b.run()
    assert all(got[i] == refs[i] for i in range(len(prompts)))


def test_eos_frees_slot_early():
    cfg = get_config("starcoder2-7b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 5).astype(np.int32)
    ref = _gen_alone(model, params, prompt, 8, 48)
    eos = ref[2]   # the third generated token acts as EOS
    b = ContinuousBatcher(model, params, batch_size=1, max_len=48)
    b.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, eos_id=eos))
    out = b.run()
    assert out[0] == ref[:3]
    assert b.slots[0].rid == -1


def test_insert_slot_isolation():
    """Inserting a prefill into slot 1 must not perturb slot 0."""
    cfg = get_config("starcoder2-7b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    state = model.decode_state_init(2, 32)
    p0 = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    _, ps0 = model.prefill(params, {"tokens": jnp.asarray(p0[None],
                                                          jnp.int32)},
                           max_len=32)
    state = insert_slot(state, ps0, 0)
    before = jax.tree.map(lambda t: np.asarray(t).copy(), state)
    p1 = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    _, ps1 = model.prefill(params, {"tokens": jnp.asarray(p1[None],
                                                          jnp.int32)},
                           max_len=32)
    state = insert_slot(state, ps1, 1)
    after = jax.tree.map(np.asarray, state)
    k_b, k_a = before.kv.k, after.kv.k
    assert np.array_equal(k_b[:, 0], k_a[:, 0])        # slot 0 untouched
    assert not np.array_equal(k_b[:, 1], k_a[:, 1])    # slot 1 filled
    assert int(after.kv.length[0]) == 6
    assert int(after.kv.length[1]) == 9
