"""Oracle-differential layer for the new visit-algebra workloads.

Every new kind (cc, kreach, rw) is pinned three ways:

  * backend differential — engine / baselines / distributed must agree
    *bitwise* (cc and kreach run integer-valued f32 minplus; rw replays a
    per-(source, step) tape), so any divergence is a real defect, never
    tolerance noise;
  * sequential oracle — union-find (cc), f32 Dijkstra over hop-shifted
    weights (kreach), host tape replay (rw) in ``core/oracles.py``;
  * serving differential — a ``GraphServer`` lane must hand back the very
    bits ``session.run`` computes, including on a result-cache hit.

Property tests (hypothesis) cover the invariants a fixed fixture can't:
cc labelings are permutation-equivariant, kreach distances are monotone
in the hop budget, and the cc fixpoint equals union-find on arbitrary
random graphs.
"""
import numpy as np
import pytest

from repro.core import oracles
from repro.core.graph import CSRGraph
from repro.fpp.session import FPPSession

BACKENDS3 = ("engine", "baselines", "distributed")
K = 3
WALK_LEN = 12
WALK_SEED = 7


def _random_graph(n=96, m=500, seed=0, weighted=True):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.integers(1, 10, m).astype(np.float64) if weighted else None
    return CSRGraph.from_edges(n, src, dst, w)


@pytest.fixture(scope="module")
def sess():
    return FPPSession(_random_graph()).plan(num_queries=4, block_size=16)


@pytest.fixture(scope="module")
def sources():
    return np.array([0, 5, 17, 63])


# ------------------------------------------------------------------ cc


@pytest.mark.parametrize("backend", BACKENDS3)
def test_cc_matches_union_find_bitwise(sess, sources, backend):
    """Every backend's cc plane == union-find labels, every lane."""
    want = oracles.connected_components(sess.graph).astype(np.float32)
    r = sess.run("cc", sources, backend=backend)
    assert r.values.shape == (len(sources), sess.graph.n)
    for q in range(len(sources)):
        assert np.array_equal(r.values[q], want), backend


def test_label_prop_oracle_agrees_with_union_find(sess):
    """The sequential min-label twin converges to the union-find labels."""
    labels, rounds = oracles.label_prop(sess.graph)
    assert rounds >= 1
    assert np.array_equal(labels, oracles.connected_components(sess.graph))


def test_cc_terminates_without_visit_ceiling(sess, sources):
    """Zero-weight propagation must reach a fixpoint on its own: equal
    re-sent labels may not keep partitions pending (the strict-pending
    rule in ``visit.minplus_algebra``) — a livelock here shows up as a
    visit count at the engine's max_visits ceiling."""
    r = sess.run("cc", sources, backend="engine")
    bg, _ = sess.prepared(weights="zero")
    assert r.stats["visits"] < 2000 * bg.num_parts


# -------------------------------------------------------------- kreach


@pytest.mark.parametrize("backend", BACKENDS3)
def test_kreach_matches_dijkstra_oracle_bitwise(sess, sources, backend):
    r = sess.run("kreach", sources, backend=backend, k=K)
    for q, s in enumerate(sources):
        vals, hops, _ = oracles.kreach(sess.graph, int(s), K,
                                       stride=sess.kreach_stride)
        assert np.array_equal(r.values[q], vals), (backend, s)
        assert np.array_equal(r.residual[q], hops), (backend, s)


def test_kreach_hop_budget_monotone(sess, sources):
    """Raising k only adds reachable vertices, never changes a distance:
    the k-budget is a post-filter on one packed lex-(hops, dist) plane."""
    prev = None
    for k in range(1, 5):
        r = sess.run("kreach", sources, k=k)
        finite = np.isfinite(r.values)
        if prev is not None:
            pfin, pvals = prev
            assert (finite | ~pfin).all()          # reach set grows
            assert np.array_equal(r.values[pfin], pvals[pfin])
        prev = (finite, r.values)


def test_kreach_respects_hop_budget_exactly(sess, sources):
    r = sess.run("kreach", sources, k=K)
    finite = np.isfinite(r.values)
    assert (r.residual[finite] <= K).all()
    # a reachable vertex past the budget is reported unreachable
    over = np.isfinite(r.residual) & (r.residual > K)
    assert not np.isfinite(r.values[over]).any()


# ------------------------------------------------------------------ rw


def test_rw_backends_bitwise_identical(sess, sources):
    rs = [sess.run("rw", sources, backend=bk, length=WALK_LEN,
                   seed=WALK_SEED) for bk in BACKENDS3]
    for r in rs[1:]:
        assert np.array_equal(rs[0].values, r.values)
        assert np.array_equal(rs[0].edges_processed, r.edges_processed)


def test_rw_matches_host_tape_replay(sess, sources):
    """Occupancy planes == the sequential per-(source, step) tape replay,
    mapped back through the partition permutation."""
    r = sess.run("rw", sources, length=WALK_LEN, seed=WALK_SEED)
    bg, perm = sess.prepared()
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    n = sess.graph.n
    for q, s in enumerate(sources):
        posns = oracles.random_walk(bg, int(perm[s]), WALK_LEN,
                                    seed=WALK_SEED)
        occ = np.zeros(n, np.float32)
        for p in posns:
            occ[inv[p]] += 1.0
        assert np.array_equal(r.values[q], occ), s
        assert r.edges_processed[q] == len(posns) - 1


def test_rw_trajectory_independent_of_batch_composition(sess, sources):
    """A walker's tape depends only on (graph, seed, source, length):
    running a source alone, in a different lane, or alongside different
    co-walkers returns the same bits."""
    full = sess.run("rw", sources, length=WALK_LEN, seed=WALK_SEED)
    solo = sess.run("rw", sources[2:3], length=WALK_LEN, seed=WALK_SEED)
    assert np.array_equal(full.values[2], solo.values[0])
    flipped = sess.run("rw", sources[::-1], length=WALK_LEN, seed=WALK_SEED)
    assert np.array_equal(full.values, flipped.values[::-1])


# ----------------------------------------------------- streaming lanes


@pytest.mark.parametrize("kind", ("cc", "kreach"))
def test_streaming_matches_oneshot(sess, sources, kind):
    ex = sess.stream(kind, capacity=2, k=K)
    qids = ex.submit(sources)
    out = ex.run()
    ref = sess.run(kind, sources, k=K)
    for i, qid in enumerate(qids):
        assert np.array_equal(out[qid], ref.values[i]), (kind, i)


def test_walk_executor_matches_oneshot(sess, sources):
    """The rw serving lane (WalkExecutor) is slot- and visit-order
    independent: admitting through a 2-lane pool returns the same bits
    as the one-shot batched run, including per-walk step counts."""
    ex = sess.stream("rw", capacity=2, length=WALK_LEN, seed=WALK_SEED)
    qids = ex.submit(sources)
    out = ex.run()
    ref = sess.run("rw", sources, length=WALK_LEN, seed=WALK_SEED)
    for i, qid in enumerate(qids):
        assert np.array_equal(out[qid], ref.values[i])
        assert ex.result(qid).edges == ref.edges_processed[i]


# ------------------------------------------------------------- serving


def test_served_kinds_match_session(sess, sources):
    from repro.serve.graph_server import GraphRequest, GraphServer
    srv = GraphServer(capacity=4, k=K, length=WALK_LEN, walk_seed=WALK_SEED)
    srv.register_graph("g", sess)
    kinds = ("cc", "kreach", "rw")
    rids = [srv.submit(GraphRequest(kind=kd, source=int(s), graph="g"))
            for kd in kinds for s in sources]
    srv.serve()
    for i, kd in enumerate(kinds):
        ref = sess.run(kd, sources, k=K, length=WALK_LEN, seed=WALK_SEED)
        for j, s in enumerate(sources):
            resp = srv.poll(rids[i * len(sources) + j])
            assert resp.status == "ok", (kd, s)
            assert np.array_equal(resp.values, ref.values[j]), (kd, s)


def test_result_cache_hit_is_bit_identical(sess, sources):
    """Satellite: a repeat submit after completion is served from the
    result cache with the *same* bits the cold run produced."""
    from repro.serve.graph_server import GraphRequest, GraphServer
    srv = GraphServer(capacity=4, k=K, length=WALK_LEN, walk_seed=WALK_SEED)
    srv.register_graph("g", sess)
    s = int(sources[1])
    for kd in ("cc", "rw"):
        cold = srv.submit(GraphRequest(kind=kd, source=s, graph="g"))
        srv.serve()
        warm = srv.submit(GraphRequest(kind=kd, source=s, graph="g"))
        srv.serve()
        c, w = srv.poll(cold), srv.poll(warm)
        assert c.status == w.status == "ok"
        assert w.stats.get("cached") is True, kd
        assert np.array_equal(c.values, w.values), kd


def test_result_cache_keys_do_not_collide_across_kinds(sess, sources):
    """cc and sssp on the same source must key distinctly — ``kind`` is
    part of the cache identity, so a cc plane can never answer an sssp."""
    from repro.serve.graph_server import GraphRequest, GraphServer
    srv = GraphServer(capacity=4, k=K, length=WALK_LEN, walk_seed=WALK_SEED)
    srv.register_graph("g", sess)
    s = int(sources[0])
    r1 = srv.submit(GraphRequest(kind="cc", source=s, graph="g"))
    srv.serve()
    r2 = srv.submit(GraphRequest(kind="sssp", source=s, graph="g"))
    srv.serve()
    a, b = srv.poll(r1), srv.poll(r2)
    assert a.status == b.status == "ok"
    assert not b.stats.get("cached")
    assert not np.array_equal(a.values, b.values)


# ------------------------------------- deterministic property variants
# (the hypothesis generalizations live in test_workloads_property.py and
# skip wholesale where hypothesis is unavailable; these fixed-seed twins
# always run)


def test_cc_is_permutation_equivariant_fixed_seed():
    """Relabeling the vertices relabels the components and nothing else:
    two vertices share a component in g iff their images share one in the
    permuted graph."""
    g = _random_graph(n=48, m=140, seed=11)
    rng = np.random.default_rng(3)
    sigma = rng.permutation(g.n)
    src, dst, w = g.edges()
    gp = CSRGraph.from_edges(g.n, sigma[src], sigma[dst], w)
    a = FPPSession(g).plan(num_queries=1, block_size=16).run(
        "cc", np.zeros(1, dtype=np.int64)).values[0]
    b = FPPSession(gp).plan(num_queries=1, block_size=16).run(
        "cc", np.zeros(1, dtype=np.int64)).values[0]
    for u in range(0, g.n, 5):
        same_a = a == a[u]
        same_b = b[sigma] == b[sigma[u]]
        assert np.array_equal(same_a, same_b)


def test_cc_on_disconnected_and_isolated_vertices():
    """Isolated vertices keep their own label; components never merge
    across a missing edge."""
    # two triangles + two isolated vertices
    src = np.array([0, 1, 2, 3, 4, 5])
    dst = np.array([1, 2, 0, 4, 5, 3])
    g = CSRGraph.from_edges(8, src, dst)
    r = FPPSession(g).plan(num_queries=1, block_size=4).run(
        "cc", np.zeros(1, dtype=np.int64))
    assert np.array_equal(
        r.values[0], np.array([0, 0, 0, 3, 3, 3, 6, 7], np.float32))
