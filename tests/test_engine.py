"""Engine correctness: buffered execution vs sequential oracles.

The paper's central correctness claim (§5.1): yielding + priority scheduling
never change results — processing is exact.  We verify exactness across
scheduling policies, yield settings, graph families and query batches.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # degrade: unit tests run, property tests skip
    given = None

from repro.core import oracles
from repro.core.engine import FPPEngine
from repro.core.partition import partition
from repro.core.queries import prepare, run_bfs, run_ppr, run_sssp
from repro.core.yielding import NO_YIELD, YieldConfig
from repro.graphs.generators import erdos_renyi, grid2d, rmat


def _check_sssp(g, bg, perm, srcs, res, atol=1e-3):
    for qi, s in enumerate(srcs):
        d_or, _ = oracles.dijkstra(g, int(s))
        d_eng = res.values[qi][perm]
        np.testing.assert_allclose(np.nan_to_num(d_eng, posinf=1e30),
                                   np.nan_to_num(d_or, posinf=1e30),
                                   atol=atol)


@pytest.mark.parametrize("schedule", ["priority", "fifo", "random", "max_ops"])
def test_sssp_exact_all_policies(schedule):
    g = grid2d(12, 12, seed=0)
    bg, perm = partition(g, 32, method="bfs")
    srcs = np.array([0, 70, 143])
    res = run_sssp(bg, perm[srcs], schedule=schedule)
    _check_sssp(g, bg, perm, srcs, res)


@pytest.mark.parametrize("yc", [
    NO_YIELD,
    YieldConfig(delta=1.0),
    YieldConfig(delta=8.0),
    YieldConfig(mu_factor=0.25),
    YieldConfig(mu_factor=4.0),
    YieldConfig(mu_factor=1.0, delta=2.0),
    YieldConfig(max_rounds=1),
])
def test_sssp_exact_all_yield_configs(yc):
    """Yielding pauses work but never changes results (paper §5.1)."""
    g = rmat(8, 6, seed=1)
    bg, perm = partition(g, 64, method="bfs")
    srcs = np.array([3, 99])
    res = run_sssp(bg, perm[srcs], yield_config=yc)
    _check_sssp(g, bg, perm, srcs, res)


@pytest.mark.parametrize("method", ["bfs", "random", "degree", "natural"])
def test_sssp_exact_all_partition_methods(method):
    g = erdos_renyi(300, 4.0, seed=2)
    bg, perm = partition(g, 64, method=method)
    srcs = np.array([5, 250])
    res = run_sssp(bg, perm[srcs], schedule="priority")
    _check_sssp(g, bg, perm, srcs, res)


def test_bfs_levels_exact():
    g = rmat(8, 4, seed=3, weighted=False)
    bg, perm = prepare(g, 64, unit_weights=True)
    srcs = np.array([0, 17, 200])
    res = run_bfs(bg, perm[srcs])
    for qi, s in enumerate(srcs):
        d_or, _ = oracles.bfs(g, int(s))
        d_eng = res.values[qi][perm]
        d_eng = np.where(np.isfinite(d_eng), d_eng, -1).astype(np.int32)
        assert (d_or == d_eng).all()


def test_disconnected_components_stay_inf():
    # two disjoint cliques
    src = [0, 1, 2, 5, 6, 7]
    dst = [1, 2, 0, 6, 7, 5]
    from repro.core.graph import CSRGraph
    g = CSRGraph.from_edges(8, src, dst, symmetrize=True)
    bg, perm = partition(g, 4, method="natural")
    res = run_sssp(bg, perm[np.array([0])])
    d = res.values[0][perm]
    assert np.isfinite(d[:3]).all() and np.isinf(d[5:]).all()


def test_single_vertex_source_trivial():
    from repro.core.graph import CSRGraph
    g = CSRGraph.from_edges(3, [0], [1], [2.0])
    bg, perm = partition(g, 4, method="natural")
    res = run_sssp(bg, perm[np.array([2])])  # source with no out-edges
    d = res.values[0][perm]
    assert d[2] == 0 and np.isinf(d[0]) and np.isinf(d[1])


def test_ppr_invariants_and_accuracy():
    g = rmat(8, 8, seed=4)
    eps, alpha = 1e-5, 0.15
    bg, perm = partition(g, 64, method="bfs")
    deg = g.out_degree()
    srcs = np.random.default_rng(0).choice(np.flatnonzero(deg > 0), 4,
                                           replace=False)
    res = run_ppr(bg, perm[srcs], alpha=alpha, eps=eps)
    # exact PPR by dense power iteration
    A = np.zeros((g.n, g.n))
    s_, d_, _ = g.edges()
    A[s_, d_] = 1.0
    Pm = A / np.maximum(A.sum(1), 1)[:, None]
    for qi, s in enumerate(srcs):
        p_eng = res.values[qi][perm]
        r_eng = res.residual[qi][perm]
        # mass conservation (f32 accumulation tolerance)
        assert abs(p_eng.sum() + r_eng.sum() - 1.0) < 5e-3
        # ACL terminal condition: r < eps * deg everywhere
        assert (r_eng <= eps * np.maximum(deg, 1) + 1e-7).all()
        # deg-normalized error vs exact <= O(eps)
        e = np.zeros(g.n)
        e[s] = 1.0
        pi, x = np.zeros(g.n), e
        for _ in range(300):
            pi += alpha * x
            x = (1 - alpha) * (x @ Pm)
        err = np.abs(p_eng - pi) / np.maximum(deg, 1)
        assert err.max() <= eps * 2


def test_ppr_empty_when_converged():
    """After the run every partition buffer is drained (termination cond)."""
    g = grid2d(8, 8, seed=5)
    bg, perm = partition(g, 32)
    res = run_ppr(bg, perm[np.array([0, 10])], eps=1e-3)
    assert res.stats.visits > 0


def test_work_accounting_positive_and_bounded():
    g = grid2d(16, 16, seed=6)
    bg, perm = partition(g, 64)
    srcs = np.array([0, 100])
    res = run_sssp(bg, perm[srcs])
    d_or, oracle_edges = oracles.dijkstra(g, 0)
    assert (res.edges_processed > 0).all()
    # paper Appendix A: within small constant factor of sequential
    assert res.edges_processed.mean() < 40 * oracle_edges


if given is not None:
    @settings(max_examples=15, deadline=None)
    @given(st.data())
    def test_sssp_property_random_graphs(data):
        """Fixed shapes (one jit compile), random structure/weights/sources."""
        n, B = 48, 16
        nedges = data.draw(st.integers(20, 150))
        rng_seed = data.draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(rng_seed)
        src = rng.integers(0, n, nedges)
        dst = rng.integers(0, n, nedges)
        w = rng.uniform(0.5, 4.0, nedges).astype(np.float32)
        from repro.core.graph import CSRGraph
        g = CSRGraph.from_edges(n, src, dst, w)
        bg, perm = partition(g, B, method="natural")
        srcs = rng.choice(n, 2, replace=False)
        res = run_sssp(bg, perm[srcs], yield_config=YieldConfig(delta=1.0))
        _check_sssp(g, bg, perm, srcs, res)
else:
    def test_sssp_property_random_graphs():
        pytest.importorskip("hypothesis")
