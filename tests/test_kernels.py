"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles,
swept over shapes and dtypes (per-kernel allclose contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # degrade: unit tests run, property tests skip
    given = None

from repro.kernels.minplus import ops
from repro.kernels.minplus.ref import masked_matmul_ref, minplus_ref


def _rand_block(rng, b, density=0.2, dtype=np.float32):
    w = rng.uniform(0.5, 8.0, (b, b)).astype(dtype)
    mask = rng.random((b, b)) < density
    return np.where(mask, w, np.inf).astype(dtype)


def _rand_dist(rng, q, b, dtype=np.float32):
    d = rng.uniform(0.0, 50.0, (q, b)).astype(dtype)
    mask = rng.random((q, b)) < 0.5
    return np.where(mask, d, np.inf).astype(dtype)


@pytest.mark.parametrize("q", [1, 8, 128, 200])
@pytest.mark.parametrize("b", [16, 128, 256])
def test_minplus_kernel_shapes(q, b):
    rng = np.random.default_rng(q * 1000 + b)
    d = _rand_dist(rng, q, b)
    w = _rand_block(rng, b)
    got = np.asarray(ops.minplus_pallas(jnp.asarray(d), jnp.asarray(w)))
    want = np.asarray(minplus_ref(jnp.asarray(d), jnp.asarray(w)))
    np.testing.assert_allclose(np.nan_to_num(got, posinf=1e30),
                               np.nan_to_num(want, posinf=1e30), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_minplus_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    d = jnp.asarray(_rand_dist(rng, 16, 64)).astype(dtype)
    w = jnp.asarray(_rand_block(rng, 64)).astype(dtype)
    got = ops.minplus_pallas(d, w).astype(jnp.float32)
    want = minplus_ref(d, w).astype(jnp.float32)
    np.testing.assert_allclose(np.nan_to_num(np.asarray(got), posinf=1e30),
                               np.nan_to_num(np.asarray(want), posinf=1e30),
                               rtol=1e-2)


def test_minplus_brute_force_small():
    rng = np.random.default_rng(1)
    d = _rand_dist(rng, 3, 8)
    w = _rand_block(rng, 8, density=0.5)
    want = np.full((3, 8), np.inf, np.float32)
    for q in range(3):
        for v in range(8):
            for u in range(8):
                want[q, v] = min(want[q, v], d[q, u] + w[u, v])
    got = np.asarray(ops.minplus_pallas(jnp.asarray(d), jnp.asarray(w)))
    np.testing.assert_allclose(np.nan_to_num(got, posinf=1e30),
                               np.nan_to_num(want, posinf=1e30), rtol=1e-6)


@pytest.mark.parametrize("q,b", [(4, 16), (128, 128), (64, 256)])
def test_masked_matmul_kernel(q, b):
    rng = np.random.default_rng(q + b)
    x = rng.uniform(0, 1, (q, b)).astype(np.float32)
    w = _rand_block(rng, b)
    got = np.asarray(ops.masked_matmul_pallas(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(masked_matmul_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_masked_matmul_all_absent():
    w = jnp.full((32, 32), jnp.inf)
    x = jnp.ones((8, 32))
    got = ops.masked_matmul_pallas(x, w)
    assert np.asarray(got == 0).all()


def test_minplus_identity_on_empty_frontier():
    d = jnp.full((8, 32), jnp.inf)
    w = jnp.asarray(_rand_block(np.random.default_rng(2), 32))
    got = ops.minplus_pallas(d, w)
    assert np.isinf(np.asarray(got)).all()


if given is not None:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.9))
    def test_minplus_property(seed, density):
        rng = np.random.default_rng(seed)
        d = _rand_dist(rng, 8, 32)
        w = _rand_block(rng, 32, density=density)
        got = np.asarray(ops.minplus_pallas(jnp.asarray(d), jnp.asarray(w)))
        want = np.asarray(minplus_ref(jnp.asarray(d), jnp.asarray(w)))
        np.testing.assert_allclose(np.nan_to_num(got, posinf=1e30),
                                   np.nan_to_num(want, posinf=1e30),
                                   rtol=1e-6)
        # semiring properties: monotone (adding sources only lowers results)
        d2 = np.minimum(d, _rand_dist(rng, 8, 32))
        got2 = np.asarray(ops.minplus_pallas(jnp.asarray(d2), jnp.asarray(w)))
        assert (np.nan_to_num(got2, posinf=1e30)
                <= np.nan_to_num(got, posinf=1e30) + 1e-5).all()
else:
    def test_minplus_property():
        pytest.importorskip("hypothesis")


def test_engine_with_pallas_kernels_matches_ref_engine():
    """Full engine run routed through the Pallas kernels (interpret mode)."""
    from repro.core.partition import partition
    from repro.core.queries import run_sssp
    from repro.graphs.generators import grid2d
    g = grid2d(8, 8, seed=9)
    bg, perm = partition(g, 16)
    srcs = perm[np.array([0, 37])]
    ref = run_sssp(bg, srcs, use_pallas=False)
    got = run_sssp(bg, srcs, use_pallas=True)
    np.testing.assert_allclose(np.nan_to_num(got.values, posinf=1e30),
                               np.nan_to_num(ref.values, posinf=1e30),
                               atol=1e-4)
