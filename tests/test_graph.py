"""Unit + property tests for CSR / BlockGraph containers."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # degrade: unit tests run, property tests skip
    given = None

from repro.core.graph import BlockGraph, CSRGraph, vmem_block_size
from repro.graphs.generators import erdos_renyi, grid2d, rmat, watts_strogatz


def test_csr_from_edges_dedup_minweight():
    # duplicate edge keeps the min weight; self loops dropped
    g = CSRGraph.from_edges(4, [0, 0, 1, 2, 2], [1, 1, 1, 3, 3],
                            [5.0, 2.0, 9.9, 1.0, 7.0])
    assert g.m == 2
    src, dst, w = g.edges()
    assert list(src) == [0, 2] and list(dst) == [1, 3]
    assert np.allclose(w, [2.0, 1.0])


def test_csr_permute_preserves_edges():
    g = grid2d(5, 5, seed=0)
    perm = np.random.default_rng(0).permutation(g.n)
    gp = g.permute(perm)
    s0, d0, w0 = g.edges()
    s1, d1, w1 = gp.edges()
    e0 = {(int(perm[a]), int(perm[b]), round(float(c), 5))
          for a, b, c in zip(s0, d0, w0)}
    e1 = {(int(a), int(b), round(float(c), 5)) for a, b, c in zip(s1, d1, w1)}
    assert e0 == e1


@pytest.mark.parametrize("gen", [
    lambda: grid2d(7, 9, seed=1),
    lambda: rmat(7, 4, seed=2),
    lambda: erdos_renyi(100, 3.0, seed=3),
    lambda: watts_strogatz(80, 6, 0.3, seed=4),
])
@pytest.mark.parametrize("block_size", [16, 64])
def test_blockgraph_roundtrip(gen, block_size):
    """Every CSR edge appears in exactly one dense block with its weight."""
    g = gen()
    bg = BlockGraph.from_csr(g, block_size)
    B = bg.block_size
    src, dst, w = g.edges()
    recon = {}
    for k in range(bg.blocks.shape[0]):
        us, vs = np.nonzero(np.isfinite(bg.blocks[k]))
        for u, v in zip(us, vs):
            gu = int(bg.blk_src[k]) * B + int(u)
            gv = int(bg.blk_dst[k]) * B + int(v)
            recon[(gu, gv)] = float(bg.blocks[k, u, v])
    expect = {(int(a), int(b)): float(c) for a, b, c in zip(src, dst, w)}
    assert recon == pytest.approx(expect)
    # degree bookkeeping matches CSR
    assert (bg.deg.reshape(-1)[:g.n] == g.out_degree()).all()
    assert bg.vmask.sum() == g.n
    # row_nnz consistent with blocks
    assert (bg.row_nnz == np.isfinite(bg.blocks).sum(axis=2)).all()


def test_blockgraph_diagonal_always_present():
    g = CSRGraph.from_edges(10, [0], [9], [1.0])  # only a cross-block edge
    bg = BlockGraph.from_csr(g, 4)
    assert len(bg.diag_blk) == bg.num_parts
    for p in range(bg.num_parts):
        k = bg.diag_blk[p]
        assert bg.blk_src[k] == p and bg.blk_dst[k] == p


def test_vmem_block_size_monotone():
    assert vmem_block_size(16 << 20) <= vmem_block_size(128 << 20)
    b = vmem_block_size(96 << 20, num_queries=256)
    assert 2 * b * b * 4 + 2 * 256 * b * 4 <= 96 << 20


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 23), st.integers(0, 23)),
                    min_size=1, max_size=60))
    def test_blockgraph_roundtrip_property(edges):
        src = np.array([e[0] for e in edges])
        dst = np.array([e[1] for e in edges])
        g = CSRGraph.from_edges(24, src, dst)
        bg = BlockGraph.from_csr(g, 8)
        # every finite entry corresponds to a real edge and vice versa
        total = int(np.isfinite(bg.blocks).sum())
        assert total == g.m
else:
    def test_blockgraph_roundtrip_property():
        pytest.importorskip("hypothesis")
