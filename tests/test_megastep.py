"""Device-resident scheduling (ISSUE 4): the K-visit megastep.

What these tests pin:
  * megastep results match the legacy per-visit host loop for all four
    scheduler policies x both visit-algebra modes — bit-identical for
    minplus (and for push under the deterministic policies, where the
    visit sequences coincide), within the ACL eps tolerance for push under
    ``random`` (different seeded streams, same guarantee);
  * the host ``PartitionScheduler`` is the oracle: ``device_select``
    reproduces its deterministic argmin/argmax choices bit-for-bit,
    first-index ties included;
  * the on-device ``random`` policy is seeded and replayable (same seed =>
    same visit order and same values);
  * ``FPPEngine.run`` performs O(visits/K) host synchronizations;
  * a staggered streaming run through chunked megastep pumps still equals
    the one-shot run of the union (admission/harvest at chunk boundaries).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import oracles  # noqa: E402
from repro.core import visit as V  # noqa: E402
from repro.core.engine import FPPEngine  # noqa: E402
from repro.core.partition import partition  # noqa: E402
from repro.core.scheduler import POLICIES, PartitionScheduler  # noqa: E402
from repro.fpp import FPPSession  # noqa: E402
from repro.graphs.generators import grid2d, rmat  # noqa: E402


def _minplus_setup():
    g = grid2d(12, 12, seed=0)
    bg, perm = partition(g, 32, method="bfs")
    return g, bg, perm, perm[np.array([0, 70, 143])]


def _push_setup():
    g = rmat(8, 6, seed=5)
    bg, perm = partition(g, 64, method="bfs")
    deg = g.out_degree()
    srcs_o = np.random.default_rng(0).choice(np.flatnonzero(deg > 0), 3,
                                             replace=False)
    return g, bg, perm, srcs_o, perm[srcs_o]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("K", [1, 8, 64])
def test_megastep_minplus_bit_identical_to_host_loop(policy, K):
    """minplus is order-independent down to the bit (every candidate is the
    same left-associated path sum), so even the random policy — which visits
    in a different seeded order on device — must agree exactly."""
    _, bg, _, srcs = _minplus_setup()
    eng = FPPEngine(bg, mode="minplus", num_queries=len(srcs),
                    schedule=policy, k_visits=K)
    host = eng.run(srcs, host_loop=True, record_order=True)
    mega = eng.run(srcs, record_order=True)
    np.testing.assert_array_equal(
        np.nan_to_num(mega.values, posinf=1e30),
        np.nan_to_num(host.values, posinf=1e30))
    if policy != "random":
        # deterministic policies replay the exact host visit sequence
        assert mega.visit_order == host.visit_order
        np.testing.assert_array_equal(mega.edges_processed,
                                      host.edges_processed)
        assert mega.stats.visits == host.stats.visits


@pytest.mark.parametrize("policy", POLICIES)
def test_megastep_push_matches_host_loop_and_oracle(policy):
    g, bg, perm, srcs_o, srcs = _push_setup()
    eps = 1e-4
    deg = np.maximum(g.out_degree(), 1)
    eng = FPPEngine(bg, mode="push", num_queries=len(srcs),
                    schedule=policy, eps=eps, k_visits=64)
    host = eng.run(srcs, host_loop=True)
    mega = eng.run(srcs)
    if policy != "random":
        # same visit sequence => same float arithmetic, bit for bit
        np.testing.assert_array_equal(mega.values, host.values)
        np.testing.assert_array_equal(mega.residual, host.residual)
    for qi, s in enumerate(srcs_o):
        want_p, _, _ = oracles.ppr_push(g, int(s), eps=eps)
        err = np.abs(mega.values[qi][perm] - want_p) / deg
        assert err.max() <= 2 * eps, (policy, qi)
        mass = mega.values[qi].sum() + mega.residual[qi].sum()
        assert abs(mass - 1.0) < 5e-3, (policy, qi)


@pytest.mark.parametrize("mode", ["minplus", "push"])
def test_megastep_sync_count_is_o_visits_over_k(mode):
    """The acceptance bound: one host consultation per K-visit chunk (+1
    final empty chunk for termination), against visits for the host loop."""
    if mode == "minplus":
        _, bg, _, srcs = _minplus_setup()
        kw = {}
    else:
        _, bg, _, _, srcs = _push_setup()
        kw = {"eps": 1e-3}
    for K in (1, 8, 64):
        eng = FPPEngine(bg, mode=mode, num_queries=len(srcs), k_visits=K,
                        **kw)
        res = eng.run(srcs)
        assert res.stats.visits > 0
        assert res.stats.host_syncs <= -(-res.stats.visits // K) + 1, \
            (mode, K, res.stats.host_syncs, res.stats.visits)
        host = eng.run(srcs, host_loop=True)
        assert host.stats.host_syncs == host.stats.visits


def test_megastep_respects_max_visits_exactly():
    """The dynamic ``limit`` operand caps a chunk mid-K, so max_visits keeps
    per-visit granularity without recompiling."""
    _, bg, _, srcs = _minplus_setup()
    eng = FPPEngine(bg, mode="minplus", num_queries=len(srcs), k_visits=64)
    for cap in (1, 5, 7):
        res = eng.run(srcs, max_visits=cap, record_order=True)
        assert res.stats.visits == cap
        assert len(res.visit_order) == cap


def test_device_select_matches_host_scheduler_oracle():
    """Deterministic device policies reproduce the host argmin/argmax
    bit-for-bit (including first-index tie-breaks); random stays inside the
    non-empty set."""
    rng = np.random.default_rng(3)
    key = jax.random.PRNGKey(0)
    for trial in range(20):
        P = int(rng.integers(2, 17))
        prio = np.where(rng.random(P) < 0.4, np.inf,
                        rng.integers(0, 4, P)).astype(np.float32)  # many ties
        if not np.isfinite(prio).any():
            prio[int(rng.integers(P))] = 1.0
        stamp = np.where(np.isfinite(prio),
                         rng.integers(0, 3, P),
                         np.iinfo(np.int32).max - 1).astype(np.int32)
        ops = np.where(np.isfinite(prio), rng.integers(1, 4, P),
                       0).astype(np.int32)
        for policy in ("priority", "fifo", "max_ops"):
            sched = PartitionScheduler(policy, P)
            want = sched.select(prio, stamp, ops)
            got = int(V.device_select(policy, jnp.asarray(prio),
                                      jnp.asarray(stamp), jnp.asarray(ops),
                                      key))
            assert got == want, (trial, policy)
        key, sub = jax.random.split(key)
        r = int(V.device_select("random", jnp.asarray(prio),
                                jnp.asarray(stamp), jnp.asarray(ops), sub))
        assert np.isfinite(prio[r]), trial


def test_random_policy_seeded_determinism():
    """Same seed => same on-device threefry stream => identical visit order
    and bit-identical results, run-to-run and engine-to-engine."""
    _, bg, _, srcs = _minplus_setup()

    def once(seed):
        eng = FPPEngine(bg, mode="minplus", num_queries=len(srcs),
                        schedule="random", seed=seed, k_visits=8)
        res = eng.run(srcs, record_order=True)
        return res.values, res.visit_order

    v1, o1 = once(7)
    v2, o2 = once(7)
    assert o1 == o2
    np.testing.assert_array_equal(v1, v2)
    # a replayed run on the SAME engine restarts the stream too
    eng = FPPEngine(bg, mode="minplus", num_queries=len(srcs),
                    schedule="random", seed=7, k_visits=8)
    ra = eng.run(srcs, record_order=True)
    rb = eng.run(srcs, record_order=True)
    assert ra.visit_order == rb.visit_order == o1


@pytest.mark.parametrize("kind,K", [("sssp", 1), ("sssp", 8), ("ppr", 8)])
def test_streaming_staggered_chunked_matches_one_shot(kind, K):
    """Admission and harvest at K-visit chunk boundaries preserve the
    streaming exactness contract (DESIGN.md §3.3): a staggered run equals
    the one-shot union — bitwise for minplus, within eps for push."""
    g = grid2d(12, 12, seed=6)
    srcs = np.array([0, 40, 80, 120, 143, 7])
    eps = 1e-3
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=32)
    one = sess.run(kind, srcs, eps=eps)
    stream = sess.stream(kind, capacity=4, eps=eps, k_visits=K)
    qids = stream.submit(srcs[:3])
    stream.pump(3)                       # in-flight work between arrivals
    qids += stream.submit(srcs[3:])
    out = stream.run()
    assert len(out) == len(srcs)
    # chunked dispatch: at most one sync per chunk plus the empty
    # terminal/boundary chunks (one per pump round)
    assert stream.host_syncs <= -(-stream.visits // K) + 4
    if K > 1:
        assert stream.host_syncs < stream.visits
    deg = np.maximum(g.out_degree(), 1)
    for i, qid in enumerate(qids):
        if kind == "sssp":
            np.testing.assert_array_equal(out[qid], one.values[i])
        else:
            diff = np.abs(out[qid] - one.values[i]) / deg
            assert diff.max() <= 4 * eps, (i, diff.max())


def test_streaming_step_path_matches_chunked_pump():
    """The legacy per-visit ``step()`` path (host scheduler + harvest_every
    cadence) stays pinned against the chunked megastep pump — the two
    streaming drivers must not drift apart."""
    g = grid2d(10, 10, seed=2)
    srcs = np.array([0, 25, 50, 75, 99])
    sess = FPPSession(g).plan(num_queries=len(srcs), block_size=16)
    chunked = sess.stream("sssp", capacity=3)
    chunked.submit(srcs)
    out_pump = chunked.run()
    stepped = sess.stream("sssp", capacity=3, harvest_every=2)
    stepped.submit(srcs)
    while stepped.step():
        pass
    stepped._harvest()
    out_step = {qid: q.values for qid, q in stepped.queries.items()
                if q.done}
    assert set(out_pump) == set(out_step) == set(range(len(srcs)))
    for qid in out_pump:
        np.testing.assert_array_equal(out_pump[qid], out_step[qid])
    # the whole point of the chunked path: far fewer host consultations
    assert chunked.host_syncs < stepped.visits
