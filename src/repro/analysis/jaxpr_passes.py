"""jaxpr device-loop hygiene pass (DESIGN.md §7).

Traces every program in the canonical inventory (``analysis/programs.py``)
and walks the jaxpr — structurally, before XLA sees it:

  host-callback-in-loop   ``pure_callback``/``io_callback``/
                          ``debug_callback``/``device_put`` inside a
                          ``while_loop``/``scan`` body.  One of these turns
                          the O(visits/K) host-sync story into O(visits) —
                          the exact regression the megastep exists to
                          prevent, caught as a trace property.
  host-callback           the same primitives anywhere else in the program
                          (warning: suspicious in a hot program, fatal in
                          a loop).
  x64-promotion           any intermediate or I/O aval in f64/s64/u64/c128
                          — the engine's dtype story is f32 values + exact
                          int32 (hi, lo) edge counters; a silent upcast
                          doubles every HBM tile.
  weak-output             a weakly-typed program output — a literal leaked
                          past the declared dtypes and will re-promote at
                          the next op.
  counter-dtype           the program's exact-edge counters are not int32.
  donation-unsafe         a donation-candidate state output whose avals no
                          longer match its input (shape/dtype drift breaks
                          buffer reuse even before ``donate_argnums`` is
                          requested).

``check_program`` is the per-program unit so tests can feed seeded-violation
programs straight in.
"""
from __future__ import annotations

from typing import List

from repro.analysis import Finding, PassContext

CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback")
TRANSFER_PRIMS = ("device_put",)
LOOP_PRIMS = ("while", "scan")
BAD_DTYPES = ("float64", "int64", "uint64", "complex128")


def _subjaxprs(value):
    """Yield every Jaxpr hiding in an eqn param value."""
    import jax
    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def _walk(jaxpr, in_loop: bool, visit):
    for eqn in jaxpr.eqns:
        visit(eqn, in_loop)
        child_in_loop = in_loop or eqn.primitive.name in LOOP_PRIMS
        for value in eqn.params.values():
            for sub in _subjaxprs(value):
                _walk(sub, child_in_loop, visit)


def check_program(program) -> List[Finding]:
    import jax

    findings: List[Finding] = []
    key = program.key

    def finding(code, severity, message):
        findings.append(Finding(pass_name="jaxpr.hygiene", code=code,
                                severity=severity, location=key,
                                message=message))

    closed = jax.make_jaxpr(program.fn)(*program.args)

    callbacks_in_loop: List[str] = []
    callbacks_outside: List[str] = []
    bad_dtype_prims: List[str] = []

    def visit(eqn, in_loop):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMS + TRANSFER_PRIMS:
            (callbacks_in_loop if in_loop else callbacks_outside).append(name)
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and str(dtype) in BAD_DTYPES:
                bad_dtype_prims.append(f"{name}->{dtype}")

    _walk(closed.jaxpr, False, visit)

    if callbacks_in_loop:
        finding("host-callback-in-loop", "error",
                f"{len(callbacks_in_loop)} host callback/transfer op(s) "
                f"inside a device loop body ({sorted(set(callbacks_in_loop))})"
                f" — every loop iteration would sync the host")
    if callbacks_outside:
        finding("host-callback", "warning",
                f"{len(callbacks_outside)} host callback/transfer op(s) in "
                f"the program ({sorted(set(callbacks_outside))})")
    if bad_dtype_prims:
        finding("x64-promotion", "error",
                f"{len(bad_dtype_prims)} 64-bit intermediate(s): "
                f"{sorted(set(bad_dtype_prims))[:4]} — the engine dtype "
                f"contract is f32 values + int32 counters")

    out_shape = jax.eval_shape(program.fn, *program.args)
    leaves = jax.tree_util.tree_leaves(out_shape)
    for i, leaf in enumerate(leaves):
        if str(getattr(leaf, "dtype", "")) in BAD_DTYPES:
            finding("x64-promotion", "error",
                    f"program output {i} is {leaf.dtype}")
        if getattr(leaf, "weak_type", False):
            finding("weak-output", "error",
                    f"program output {i} ({leaf.dtype}) is weakly typed — "
                    f"a literal leaked past the declared dtypes")

    for name, sds in program.counters(out_shape).items():
        if str(sds.dtype) != "int32":
            finding("counter-dtype", "error",
                    f"exact-edge counter {name} is {sds.dtype}, not the "
                    f"int32 (hi, lo) contract")

    for name, in_tree, out_tree in program.donation(program.args, out_shape):
        in_leaves = jax.tree_util.tree_leaves(in_tree)
        out_leaves = jax.tree_util.tree_leaves(out_tree)
        in_avals = [(tuple(l.shape), str(l.dtype)) for l in in_leaves]
        out_avals = [(tuple(l.shape), str(l.dtype)) for l in out_leaves]
        if in_avals != out_avals:
            finding("donation-unsafe", "error",
                    f"state {name!r} comes back with different avals than "
                    f"it went in ({in_avals} -> {out_avals}) — the state "
                    f"planes must stay donation-compatible")
    return findings


def run_pass(ctx: PassContext) -> List[Finding]:
    from repro.analysis.programs import build_programs

    findings: List[Finding] = []
    for program in build_programs(only=ctx.only_programs):
        findings.extend(check_program(program))
    return findings
