"""The canonical hot-program inventory the jaxpr/HLO passes run over.

One small graph (grid2d 16x16, seed 0 — the tier-1 test workhorse), one
query batch (Q=8), the planner's block size for it (B=64, P=4), and the
full BACKENDS × KINDS matrix of jitted programs:

  engine/<kind>        FPPEngine's K-visit megastep (core/visit
                       .make_megastep; the per-dispatch hot program)
  engine-fused/<kind>  the same megastep with fused=True — every visit
                       body is one pallas_call (kernels/fused_visit), so
                       the XLA program shrinks to the scheduling loop
                       around an opaque kernel; budgeted separately
  streaming/<kind>     StreamingExecutor's pump megastep — same skeleton
                       with the [Q] pending-lane harvest mask folded in
  engine-serve/<kind>  the serving pools' warm-cache megastep
                       (serve/compile_cache.py AOT-compiles exactly this
                       program): pow2-bucketed capacity, visit body picked
                       per kind from the committed dispatch yardsticks
                       (planner.auto_fused) — fused for minplus, XLA
                       megastep for ppr
  distributed/<kind>@d{ndev}
                       the jit(shard_map(while(superstep))) mesh program
                       (core/distributed.make_distributed_program), keyed
                       by device count since XLA specializes on it
  baselines/<kind>     the synchronous global round programs
                       (core/baselines.make_minplus_round / make_push_round)

Each :class:`Program` carries its jitted fn plus trace-ready args
(concrete arrays or ShapeDtypeStructs — both trace and lower), and small
accessors telling the hygiene pass where the exact-edge counters and the
donation-candidate state live in the output pytree.

Programs are traced/compiled, never *run* — the sources only pin shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

CANONICAL_ROWS = 16
CANONICAL_COLS = 16
CANONICAL_SEED = 0
CANONICAL_Q = 8
CANONICAL_K = 8
CANONICAL_WALK_LEN = 16


@dataclasses.dataclass
class Program:
    key: str                  # "engine/sssp", "distributed/bfs@d8", ...
    backend: str
    kind: str
    fn: Callable              # jitted
    args: tuple               # concrete arrays or ShapeDtypeStructs
    # out pytree -> {name: ShapeDtypeStruct} of the exact-edge counters
    counters: Callable = lambda out: {}
    # out pytree -> [(name, in_subtree, out_subtree)] donation candidates
    donation: Callable = lambda args, out: []


def _megastep_args(engine, key):
    import jax.numpy as jnp
    state = engine.init_state(np.arange(CANONICAL_Q, dtype=np.int64))
    return (state, jnp.int32(0), jnp.int32(CANONICAL_K), key)


def _megastep_counters(out):
    ms = out[1]
    return {"eq_hi": ms.eq_hi, "eq_lo": ms.eq_lo}


def _megastep_donation(args, out):
    return [("state", args[0], out[0])]


def build_programs(only: Optional[str] = None) -> List[Program]:
    """The full matrix; ``only`` substring-filters the program keys."""
    import jax

    from repro.core.baselines import (make_minplus_round, make_push_round,
                                      make_walk_round)
    from repro.core.distributed import make_distributed_program
    from repro.core.engine import DeviceGraph, FPPEngine
    from repro.core.queries import WEIGHT_VARIANTS
    from repro.core.randomwalk import init_walk_state, make_walk_visit
    from repro.core.yielding import NO_YIELD
    from repro.fpp.backends import _ENGINE_MODE, KINDS, default_mesh
    from repro.fpp.planner import default_yield_config, pow2_bucket
    from repro.fpp.session import FPPSession
    from repro.fpp.streaming import StreamingExecutor
    from repro.graphs.generators import grid2d

    import jax.numpy as jnp

    g = grid2d(CANONICAL_ROWS, CANONICAL_COLS, seed=CANONICAL_SEED)
    sess = FPPSession(g)
    sess.plan(num_queries=CANONICAL_Q)
    mesh = default_mesh()
    ndev = int(mesh.shape["model"])
    key = jax.random.PRNGKey(0)
    programs: List[Program] = []

    for kind in KINDS:
        bg, _ = sess.prepared(weights=WEIGHT_VARIANTS.get(kind, "natural"))
        yc = default_yield_config(kind, bg)

        if kind == "rw":
            # rw has no megastep: its hot program at every backend is the
            # buffered walk visit (engine/streaming/serving lanes) or the
            # bulk walk round/mesh program (baselines/distributed) — the
            # exact-edge counter analogue is the int32 ``steps`` plane
            wlen = CANONICAL_WALK_LEN
            wcount = lambda out: {"steps": out[1]}
            wdon = lambda args, out: [("occ", args[5], out[4])]

            def _walk_program(keyname, backend, capacity):
                dgw = DeviceGraph.build(bg, NO_YIELD, capacity)
                st = init_walk_state(
                    dgw, np.arange(capacity, dtype=np.int64) % bg.n)
                return Program(
                    key=keyname, backend=backend, kind="rw",
                    fn=make_walk_visit(dgw, wlen, CANONICAL_SEED),
                    args=st + (jnp.int32(0),),
                    counters=wcount, donation=wdon)

            programs.append(_walk_program("engine/rw", "engine",
                                          CANONICAL_Q))
            programs.append(_walk_program("streaming/rw", "streaming",
                                          CANONICAL_Q))
            programs.append(_walk_program("engine-serve/rw", "engine",
                                          pow2_bucket(CANONICAL_Q)))

            fn, args = make_distributed_program(
                bg, CANONICAL_Q, mesh, kind="rw", yield_config=yc,
                length=wlen, seed=CANONICAL_SEED)
            programs.append(Program(
                key=f"distributed/rw@d{ndev}", backend="distributed",
                kind="rw", fn=fn, args=args,
                counters=lambda out: {"steps": out[1]},
                donation=lambda args, out: [("occ", args[9], out[4])]))

            dgw = DeviceGraph.build(bg, NO_YIELD, CANONICAL_Q)
            programs.append(Program(
                key="baselines/rw", backend="baselines", kind="rw",
                fn=make_walk_round(dgw, wlen, CANONICAL_SEED),
                args=init_walk_state(
                    dgw, np.arange(CANONICAL_Q, dtype=np.int64) % bg.n),
                counters=wcount, donation=wdon))
            continue

        mode = _ENGINE_MODE[kind]

        # -- engine megastep ------------------------------------------------
        eng = FPPEngine(bg, mode=mode, num_queries=CANONICAL_Q,
                        yield_config=yc, k_visits=CANONICAL_K)
        programs.append(Program(
            key=f"engine/{kind}", backend="engine", kind=kind,
            fn=eng._megastep, args=_megastep_args(eng, key),
            counters=_megastep_counters, donation=_megastep_donation))

        # -- engine fused megastep (visit bodies inside one pallas_call) ----
        feng = FPPEngine(bg, mode=mode, num_queries=CANONICAL_Q,
                         yield_config=yc, k_visits=CANONICAL_K, fused=True)
        programs.append(Program(
            key=f"engine-fused/{kind}", backend="engine", kind=kind,
            fn=feng._megastep, args=_megastep_args(feng, key),
            counters=_megastep_counters, donation=_megastep_donation))

        # -- streaming pump megastep (harvest_mask=True) --------------------
        ex = StreamingExecutor(sess, kind, capacity=CANONICAL_Q,
                               k_visits=CANONICAL_K)
        programs.append(Program(
            key=f"streaming/{kind}", backend="streaming", kind=kind,
            fn=ex._megastep,
            args=(ex.state, jnp.int32(0), jnp.int32(CANONICAL_K), ex._key),
            counters=_megastep_counters, donation=_megastep_donation))

        # -- serving warm-cache megastep (GraphServer lane pools) -----------
        from repro.core import visit as _visit
        from repro.fpp.planner import auto_fused, pow2_bucket
        from repro.fpp.streaming import (build_stream_engine,
                                         build_stream_megastep)
        cap = pow2_bucket(CANONICAL_Q)
        seng = build_stream_engine(
            sess, kind, cap, schedule=sess.current_plan.schedule,
            k_visits=CANONICAL_K,
            fused=auto_fused(kind, CANONICAL_K,
                             dmax=bg.nbr_part.shape[1]))[0]
        sstate = _visit.init_engine_state(
            seng.algebra, seng.dg, np.empty(0, dtype=np.int64),
            num_queries=cap)
        programs.append(Program(
            key=f"engine-serve/{kind}", backend="engine", kind=kind,
            fn=build_stream_megastep(seng, sess.current_plan.schedule),
            args=(sstate, jnp.int32(0), jnp.int32(CANONICAL_K), key),
            counters=_megastep_counters, donation=_megastep_donation))

        # -- distributed superstep program ----------------------------------
        fn, args = make_distributed_program(bg, CANONICAL_Q, mesh, kind=kind,
                                            yield_config=yc)
        programs.append(Program(
            key=f"distributed/{kind}@d{ndev}", backend="distributed",
            kind=kind, fn=fn, args=args,
            counters=lambda out: {"eq_hi": out[2], "eq_lo": out[3]},
            donation=lambda args, out: [("vals", args[5], out[0]),
                                        ("buf", args[6], out[1])]))

        # -- baselines round ------------------------------------------------
        dg = DeviceGraph.build(bg, NO_YIELD, CANONICAL_Q)
        P, B = dg.num_parts, dg.block_size
        blk_src = jnp.asarray(bg.blk_src.astype(np.int32))
        blk_dst = jnp.asarray(bg.blk_dst.astype(np.int32))
        f32 = jnp.float32
        state_sds = jax.ShapeDtypeStruct((P, CANONICAL_Q, B), f32)
        if kind == "ppr":
            rfn = make_push_round(dg, blk_src, blk_dst, alpha=0.15, eps=1e-4)
            rargs = (state_sds, state_sds)
            counters = lambda out: {"eq": out[3]}
            donation = lambda args, out: [("p", args[0], out[0]),
                                          ("r", args[1], out[1])]
        else:
            rfn = make_minplus_round(dg, blk_src, blk_dst)
            rargs = (state_sds,
                     jax.ShapeDtypeStruct((P, CANONICAL_Q, B), jnp.bool_))
            counters = lambda out: {"eq": out[2]}
            donation = lambda args, out: [("dist", args[0], out[0])]
        programs.append(Program(
            key=f"baselines/{kind}", backend="baselines", kind=kind,
            fn=rfn, args=rargs, counters=counters, donation=donation))

    if only:
        programs = [p for p in programs if only in p.key]
    return programs
