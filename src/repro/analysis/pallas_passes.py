"""Pallas kernel contract passes (DESIGN.md §7).

``pallas.contracts`` validates every declared :class:`KernelContract`
statically — no tracing, no pallas_call:

  * tile divisibility: each full dim divides into whole blocks;
  * grid coverage: the grid writes each output element exactly once
    (``num_blocks == grid_size`` per output tile);
  * VMEM bound: the per-grid-step footprint fits the raw VMEM budget for
    every kernel, and additionally fits the planner memory model's
    working set (`MemoryModel.covers`) for *wired* graph kernels — a
    wired kernel whose tiles outgrow the model would thrash the cache
    the planner sized.

``pallas.reachability`` cross-checks each contract's ``wired`` claim
against the actual import graph of ``src/repro`` (AST-level, so a
refactor that orphans a kernel is caught even if tests still import it
directly).  Dead kernels are allowlisted *warnings with a reason* —
``wired=False`` requires a ``note`` naming the plan.  ``core/randomwalk``
gets an explicit ruling too: it must stay dispatched (via
``core/queries.run_rw`` / ``fpp.session.random_walks``), not drift dead.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Set

from repro.analysis import Finding, PassContext


def _imported_names(tree) -> Set[str]:
    """All dotted module names a module imports (Import + ImportFrom)."""
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                names.add(a.name)
        elif isinstance(node, ast.ImportFrom) and node.module:
            names.add(node.module)
            for a in node.names:
                names.add(f"{node.module}.{a.name}")
    return names


def _import_graph(root: pathlib.Path) -> Dict[str, Set[str]]:
    """relative file path -> set of imported dotted names, over src/repro."""
    graph = {}
    base = root / "src" / "repro"
    for path in sorted(base.rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        graph[str(path.relative_to(root))] = _imported_names(tree)
    return graph


def _importers_of(graph: Dict[str, Set[str]], prefix: str,
                  own_dir: str) -> List[str]:
    """Files outside ``own_dir`` importing anything under ``prefix``."""
    hits = []
    for rel, names in graph.items():
        if rel.startswith(own_dir):
            continue
        if any(n == prefix or n.startswith(prefix + ".") for n in names):
            hits.append(rel)
    return hits


def check_contract(c, mem) -> List[Finding]:
    """Validate one KernelContract against one MemoryModel."""
    findings = []
    loc = f"{c.module} ({c.name})"
    for t in c.tiles:
        if not t.divisible():
            findings.append(Finding(
                pass_name="pallas.contracts", code="tile-divisibility",
                severity="error", location=loc,
                message=f"tile {t.name}: block {t.block} does not "
                        f"divide full shape {t.full}"))
    for t in c.out_tiles:
        if not t.divisible():
            continue
        if t.update == "once" and t.num_blocks() != c.grid_size():
            findings.append(Finding(
                pass_name="pallas.contracts", code="grid-coverage",
                severity="error", location=loc,
                message=f"output {t.name}: grid {c.grid} schedules "
                        f"{c.grid_size()} programs but the tiling "
                        f"yields {t.num_blocks()} blocks — each output "
                        f"element must be written exactly once"))
        elif t.update == "accum" and t.num_blocks() != 1:
            findings.append(Finding(
                pass_name="pallas.contracts", code="grid-coverage",
                severity="error", location=loc,
                message=f"output {t.name}: update='accum' promises one "
                        f"shared block but the tiling yields "
                        f"{t.num_blocks()} — accumulation across programs "
                        f"requires a single aliased block"))
        # "rmw": scalar-prefetch scatter — coverage is the index map's
        # job (checked dynamically by the parity harness), not the grid's
    fp = c.footprint_bytes()
    if fp > mem.vmem_bytes:
        findings.append(Finding(
            pass_name="pallas.contracts", code="vmem-overflow",
            severity="error", location=loc,
            message=f"per-grid-step footprint {fp} B exceeds the "
                    f"{mem.vmem_bytes} B VMEM budget"))
    elif c.wired and c.block_size is not None:
        if c.fused_model:
            # fused-visit contracts: the whole-visit residency budget.
            # dmax and P are implied by the declared tiling — the grid is
            # (1 + dmax,) and the state rows are P + 1 (trash row).
            dmax = c.grid_size() - 1
            ok = mem.fused_covers(fp, c.block_size, c.num_queries,
                                  c.num_planes, dmax)
            ws = mem.fused_working_set(c.block_size, c.num_queries,
                                       c.num_planes, dmax)
            model = (f"fused working set {ws} B (B={c.block_size}, "
                     f"Q={c.num_queries}, np={c.num_planes}, dmax={dmax})")
        else:
            ok = mem.covers(fp, c.block_size, c.num_queries)
            ws = mem.working_set(c.block_size, c.num_queries)
            model = (f"model working set {ws} B (B={c.block_size}, "
                     f"Q={c.num_queries})")
        if not ok:
            findings.append(Finding(
                pass_name="pallas.contracts", code="model-overflow",
                severity="error", location=loc,
                message=f"footprint {fp} B exceeds the planner's {model}"
                        f" — the kernel would thrash the cache the "
                        f"planner sized"))
        else:
            findings.append(Finding(
                pass_name="pallas.contracts", code="footprint",
                severity="info", location=loc,
                message=f"footprint {fp} B within {model}"))
    return findings


def check_contracts(ctx: PassContext) -> List[Finding]:
    from repro.fpp.planner import MemoryModel
    from repro.kernels.contract import all_contracts

    mem = MemoryModel()
    findings: List[Finding] = []
    for c in all_contracts():
        findings.extend(check_contract(c, mem))
    return findings


def check_reachability(ctx: PassContext) -> List[Finding]:
    from repro.kernels.contract import KERNEL_PACKAGES, all_contracts

    graph = _import_graph(ctx.root)
    findings = []

    wired_claim = {pkg: False for pkg in KERNEL_PACKAGES}
    notes = {}
    for c in all_contracts():
        pkg = c.module.split(".")[2]          # repro.kernels.<pkg>.<mod>
        wired_claim[pkg] = wired_claim[pkg] or c.wired
        if not c.wired:
            notes[pkg] = c.note

    for pkg in KERNEL_PACKAGES:
        importers = _importers_of(graph, f"repro.kernels.{pkg}",
                                  own_dir="src/repro/kernels")
        loc = f"src/repro/kernels/{pkg}"
        if wired_claim[pkg] and not importers:
            findings.append(Finding(
                pass_name="pallas.reachability", code="stale-wired-claim",
                severity="error", location=loc,
                message="contract claims wired=True but no module outside "
                        "kernels/ imports this package — fix the dispatch "
                        "table or declare the kernel dead with a note"))
        elif not wired_claim[pkg] and importers:
            findings.append(Finding(
                pass_name="pallas.reachability", code="stale-dead-claim",
                severity="error", location=loc,
                message=f"contract claims wired=False but "
                        f"{sorted(importers)} import it — flip the claim"))
        elif not wired_claim[pkg]:
            if not notes.get(pkg):
                findings.append(Finding(
                    pass_name="pallas.reachability", code="dead-no-reason",
                    severity="error", location=loc,
                    message="dead kernel with no ruling — wired=False "
                            "requires a contract note naming the plan"))
            else:
                findings.append(Finding(
                    pass_name="pallas.reachability", code="dead-kernel",
                    severity="allowlisted", location=loc,
                    message=f"unreachable from any dispatch table "
                            f"(allowlisted: {notes[pkg]})"))
        else:
            findings.append(Finding(
                pass_name="pallas.reachability", code="wired",
                severity="info", location=loc,
                message=f"dispatched by {sorted(importers)}"))

    # core/randomwalk ruling: it must stay wired through the query facade
    rw_importers = _importers_of(graph, "repro.core.randomwalk",
                                 own_dir="src/repro/core/randomwalk")
    rw_importers = [r for r in rw_importers
                    if r != "src/repro/core/randomwalk.py"]
    if rw_importers:
        findings.append(Finding(
            pass_name="pallas.reachability", code="wired",
            severity="info", location="src/repro/core/randomwalk.py",
            message=f"dispatched by {sorted(rw_importers)}"))
    else:
        findings.append(Finding(
            pass_name="pallas.reachability", code="dead-module",
            severity="error", location="src/repro/core/randomwalk.py",
            message="core/randomwalk lost its dispatch-table entry "
                    "(core/queries.run_rw, fpp.session.random_walks) — "
                    "rewire it or add an explicit dead ruling here"))
    return findings
