"""fppcheck — the static-analysis layer (DESIGN.md §7).

ForkGraph's whole thesis is a *static* resource contract: partitions sized
to the cache, atomic-free intra-partition execution, bounded inter-partition
work.  This package checks those contracts without running a benchmark, as
four pass families over four different program representations:

  jaxpr   device-loop hygiene of the traced hot programs (no host callbacks
          or transfers inside the ``while_loop`` body, no f64/weak-type
          promotion, int32 ``(hi, lo)`` edge counters, donation-safe state)
  hlo     per-program op budgets over the *compiled* HLO text, checked
          against the committed ``analysis/budgets.json`` baseline — an
          extra HBM round-trip in the megastep fails CI without timing
          anything
  pallas  static VMEM footprints of every kernel's BlockSpecs/grid against
          the §3.1 memory model, tile divisibility, grid coverage, and
          dispatch-table reachability (dead kernels are allowlisted with a
          reason, never silent)
  ast     source lints: bare ``assert`` on user-reachable paths, ``jnp.``
          work inside host Python loops in ``core/``, and the doc-consistency
          sweep (``scripts/check_docs.py`` is now a shim over ``docs``)

``scripts/fppcheck.py`` is the one CLI; CI runs it under forced host device
counts {1, 8} and fails on any error-severity finding (budget drift, a
reintroduced bare assert, a callback in a device loop, ...).

This module is importable without jax (the registry resolves pass modules
lazily), so the docs/ast families run before heavyweight deps install.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import pathlib
from typing import Callable, Dict, List, Optional, Tuple

#: severity ladder: only "error" fails the build.  "allowlisted" is a
#: warning with an explicit standing excuse (e.g. the dead-kernel list).
SEVERITIES = ("error", "warning", "allowlisted", "info")


def repo_root() -> pathlib.Path:
    """The repo checkout this package sits in (…/src/repro/analysis)."""
    return pathlib.Path(__file__).resolve().parents[3]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One fact a pass established about the codebase."""
    pass_name: str     # registry key, e.g. "jaxpr.hygiene"
    code: str          # stable machine tag, e.g. "host-callback-in-loop"
    severity: str      # one of SEVERITIES
    location: str      # "path:line" or a program key like "engine/sssp"
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"one of {SEVERITIES}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"[{self.severity:>11}] {self.pass_name} {self.code} "
                f"@ {self.location}: {self.message}")


@dataclasses.dataclass
class PassContext:
    """Everything a pass may need; passes take (ctx) and return findings."""
    root: pathlib.Path
    update_budgets: bool = False
    budgets_path: Optional[pathlib.Path] = None
    only_programs: Optional[str] = None   # substring filter over program keys

    def __post_init__(self):
        self.root = pathlib.Path(self.root)
        if self.budgets_path is None:
            self.budgets_path = pathlib.Path(__file__).with_name(
                "budgets.json")


@dataclasses.dataclass
class Report:
    """The result of one fppcheck invocation."""
    findings: List[Finding]
    passes_run: List[str]
    env: dict = dataclasses.field(default_factory=dict)

    def count(self, severity: str) -> int:
        return sum(1 for f in self.findings if f.severity == severity)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def as_dict(self) -> dict:
        return {
            "passes_run": list(self.passes_run),
            "env": dict(self.env),
            "counts": {s: self.count(s) for s in SEVERITIES},
            "findings": [f.as_dict() for f in self.findings],
            "ok": self.ok,
        }

    def write(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.as_dict(), indent=2,
                                   sort_keys=True) + "\n")

    def render(self) -> str:
        lines = [f"fppcheck: ran {len(self.passes_run)} pass(es): "
                 f"{', '.join(self.passes_run)}"]
        for sev in SEVERITIES:
            for f in self.findings:
                if f.severity == sev:
                    lines.append("  " + f.render())
        counts = ", ".join(f"{self.count(s)} {s}" for s in SEVERITIES
                           if self.count(s))
        lines.append(f"fppcheck: {'FAIL' if self.errors else 'OK'}"
                     f"{' — ' + counts if counts else ' — no findings'}")
        return "\n".join(lines)


#: registry: pass name -> (module, function).  Modules import lazily so the
#: jax-free families (ast, docs) run without jax installed.
PASSES: Dict[str, Tuple[str, str]] = {
    "ast.asserts": ("repro.analysis.ast_passes", "check_asserts"),
    "ast.host-jnp": ("repro.analysis.ast_passes", "check_host_jnp_loops"),
    "docs.refs": ("repro.analysis.docs", "run_pass"),
    "pallas.contracts": ("repro.analysis.pallas_passes", "check_contracts"),
    "pallas.reachability": ("repro.analysis.pallas_passes",
                            "check_reachability"),
    "jaxpr.hygiene": ("repro.analysis.jaxpr_passes", "run_pass"),
    "hlo.budgets": ("repro.analysis.hlo_passes", "run_pass"),
}

#: pass families as the CLI exposes them (scripts/fppcheck.py --<family>)
FAMILIES: Dict[str, Tuple[str, ...]] = {
    "ast": ("ast.asserts", "ast.host-jnp"),
    "docs": ("docs.refs",),
    "pallas": ("pallas.contracts", "pallas.reachability"),
    "jaxpr": ("jaxpr.hygiene",),
    "hlo": ("hlo.budgets",),
}


def resolve_pass(name: str) -> Callable[[PassContext], List[Finding]]:
    mod_name, fn_name = PASSES[name]
    return getattr(importlib.import_module(mod_name), fn_name)


def run_passes(names, ctx: Optional[PassContext] = None) -> Report:
    """Run the named passes in order and collect one Report."""
    ctx = ctx or PassContext(root=repo_root())
    findings: List[Finding] = []
    ran = []
    for name in names:
        if name not in PASSES:
            raise ValueError(f"unknown pass {name!r}; one of "
                             f"{sorted(PASSES)}")
        findings.extend(resolve_pass(name)(ctx))
        ran.append(name)
    return Report(findings=findings, passes_run=ran)
