"""AST lint passes over ``src/repro`` (DESIGN.md §7).

Two lints, both plain ``ast`` walks — no jax import:

  ast.asserts    bare ``assert`` on user-reachable paths.  Asserts vanish
                 under ``python -O`` and give the caller a context-free
                 AssertionError; library code raises ValueError/RuntimeError
                 with a message instead.  Tests (``tests/``, ``scripts/``)
                 and reference implementations keep their asserts; a
                 deliberate invariant can stay with an inline
                 ``# fppcheck: allow-assert`` excuse.

  ast.host-jnp   ``jnp.``/``jax.numpy`` calls inside host Python ``for``/
                 ``while`` loops in ``core/`` and ``serve/``.  A jnp call
                 per host iteration is a dispatch (and often a transfer)
                 per iteration — the exact pattern the K-visit megastep
                 exists to remove, and in the serving lanes a stall every
                 tenant shares.  Loops inside nested ``def``/``lambda`` are
                 skipped (those are traced bodies, where jnp is the point),
                 as are scalar constructors like ``jnp.int32(0)`` and lines
                 carrying ``# fppcheck: allow-host-jnp``.
"""
from __future__ import annotations

import ast
import pathlib
from typing import List

from repro.analysis import Finding, PassContext

#: file/dir names whose asserts are exempt wholesale: test code asserts by
#: design, and kernels' ``ref.py`` oracles are internal to the test suite —
#: with the one exception (minplus/ref.py shape check) now a ValueError.
ASSERT_EXEMPT_DIRS = {"tests", "__pycache__"}

ALLOW_ASSERT = "fppcheck: allow-assert"
ALLOW_HOST_JNP = "fppcheck: allow-host-jnp"

#: scalar constructors / dtype casts — cheap, no device dispatch worth
#: flagging when they appear in a host loop
SCALAR_CTORS = {"int32", "int64", "float32", "float64", "bool_", "uint32",
                "uint64", "asarray", "dtype"}


def _py_files(root: pathlib.Path, sub: str = "src/repro"):
    base = root / sub
    for path in sorted(base.rglob("*.py")):
        parts = set(p.name for p in path.relative_to(base).parents)
        if not parts & ASSERT_EXEMPT_DIRS:
            yield path


def _line_has(source_lines, lineno: int, marker: str) -> bool:
    if 1 <= lineno <= len(source_lines):
        return marker in source_lines[lineno - 1]
    return False


def check_asserts(ctx: PassContext) -> List[Finding]:
    findings = []
    for path in _py_files(ctx.root):
        text = path.read_text()
        lines = text.splitlines()
        tree = ast.parse(text, filename=str(path))
        rel = path.relative_to(ctx.root)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assert):
                continue
            if _line_has(lines, node.lineno, ALLOW_ASSERT):
                continue
            findings.append(Finding(
                pass_name="ast.asserts", code="bare-assert",
                severity="error", location=f"{rel}:{node.lineno}",
                message="bare assert on a library path — raise ValueError/"
                        "RuntimeError with a message (or annotate "
                        f"'# {ALLOW_ASSERT}')"))
    return findings


class _HostLoopJnp(ast.NodeVisitor):
    """Find jnp attribute-calls lexically inside host for/while loops.

    Nested function/lambda bodies are *not* host code at the loop's
    nesting level — they are typically traced (round_fn closures, vmapped
    operators), so descent stops there.
    """

    def __init__(self, jnp_aliases, lines):
        self.jnp_aliases = jnp_aliases
        self.lines = lines
        self.loop_depth = 0
        self.hits = []   # (lineno, rendered call)

    # -- barriers: a new def/lambda resets "host loop" context ------------
    def visit_FunctionDef(self, node):
        saved, self.loop_depth = self.loop_depth, 0
        self.generic_visit(node)
        self.loop_depth = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        saved, self.loop_depth = self.loop_depth, 0
        self.generic_visit(node)
        self.loop_depth = saved

    # -- loops ------------------------------------------------------------
    def visit_For(self, node):
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_AsyncFor = visit_For
    visit_While = visit_For

    # -- the actual check -------------------------------------------------
    def visit_Call(self, node):
        if self.loop_depth > 0:
            fn = node.func
            if (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id in self.jnp_aliases
                    and fn.attr not in SCALAR_CTORS
                    and not _line_has(self.lines, node.lineno,
                                      ALLOW_HOST_JNP)):
                self.hits.append((node.lineno,
                                  f"{fn.value.id}.{fn.attr}(...)"))
        self.generic_visit(node)


def _jnp_aliases(tree) -> set:
    """Names bound to jax.numpy in this module (usually just {'jnp'})."""
    aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.numpy":
                    aliases.add(a.asname or "jax.numpy")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax" :
                for a in node.names:
                    if a.name == "numpy":
                        aliases.add(a.asname or "numpy")
    return aliases


#: Subtrees the host-jnp lint polices: the kernel/dataflow core plus the
#: serving layer, whose admission/pump/delivery threads are exactly where
#: a stray per-iteration dispatch would stall every tenant at once.
HOST_JNP_SUBDIRS = ("src/repro/core", "src/repro/serve")


def check_host_jnp_loops(ctx: PassContext) -> List[Finding]:
    findings = []
    for sub in HOST_JNP_SUBDIRS:
        findings.extend(_host_jnp_in(ctx, sub))
    return findings


def _host_jnp_in(ctx: PassContext, sub: str) -> List[Finding]:
    findings = []
    for path in _py_files(ctx.root, sub):
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
        aliases = _jnp_aliases(tree)
        if not aliases:
            continue
        visitor = _HostLoopJnp(aliases, text.splitlines())
        visitor.visit(tree)
        rel = path.relative_to(ctx.root)
        for lineno, call in visitor.hits:
            findings.append(Finding(
                pass_name="ast.host-jnp", code="jnp-in-host-loop",
                severity="error", location=f"{rel}:{lineno}",
                message=f"{call} inside a host Python loop — one dispatch "
                        "per iteration; hoist into the traced program or "
                        f"annotate '# {ALLOW_HOST_JNP}'"))
    return findings
