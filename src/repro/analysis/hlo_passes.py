"""HLO op-budget pass (DESIGN.md §7).

Compiles every program in the canonical inventory and reduces its
optimized HLO text (``launch/hlo.py: op_census`` + ``collective_stats``)
to one flat metric row per program:

  ops_total, op_<opcode>        whole-module opcode counts for the
                                opcodes that track memory traffic and
                                layout churn (copy, convert, transpose,
                                fusion, dynamic-slice, ...)
  while_body_total, wb_<opcode> the same census restricted to while-loop
                                bodies — the per-iteration cost, where an
                                extra copy means an extra HBM round-trip
                                *every* visit
  collective_bytes[_<kind>]     operand bytes of collectives by kind

Rows are checked against the committed ``analysis/budgets.json``
baselines as **ceilings**: only ``measured > budget`` fails, so compiler
noise that shrinks a count never blocks a PR.  Distributed programs are
keyed ``@d{ndev}`` because XLA specializes on device count.

``--update-budgets`` (PassContext.update_budgets) rewrites the measured
rows in place — the explicit act a perf PR commits when a budget
legitimately moves (DESIGN.md §7 workflow).
"""
from __future__ import annotations

import json
from typing import Dict, List

from repro.analysis import Finding, PassContext

#: opcodes budgeted individually (everything else rides in ops_total)
INTERESTING_OPS = ("copy", "convert", "transpose", "fusion", "while",
                   "dynamic-slice", "dynamic-update-slice", "scatter",
                   "gather", "dot", "custom-call", "all-to-all",
                   "all-reduce", "all-gather", "reduce-scatter",
                   "collective-permute")


def measure_program(program) -> Dict[str, int]:
    """Compile one program and reduce its HLO text to a flat metric row."""
    from repro.launch.hlo import collective_stats, op_census

    hlo = program.fn.lower(*program.args).compile().as_text()
    census = op_census(hlo)
    coll = collective_stats(hlo)
    row = {"ops_total": census.total,
           "while_body_total": census.while_body_total,
           "collective_bytes": coll.total_bytes}
    for op in INTERESTING_OPS:
        row[f"op_{op}"] = census.counts.get(op, 0)
        row[f"wb_{op}"] = census.while_body_counts.get(op, 0)
    for kind, nb in sorted(coll.bytes_by_kind.items()):
        row[f"collective_bytes_{kind}"] = nb
    return row


def load_budgets(ctx: PassContext) -> Dict[str, Dict[str, int]]:
    if ctx.budgets_path.exists():
        return json.loads(ctx.budgets_path.read_text())
    return {}


def check_row(key: str, row: Dict[str, int],
              baseline: Dict[str, int]) -> List[Finding]:
    """Compare one measured metric row against its committed ceiling."""
    findings: List[Finding] = []
    drift = []
    for metric, value in row.items():
        limit = baseline.get(metric)
        if limit is None:
            findings.append(Finding(
                pass_name="hlo.budgets", code="unbudgeted-metric",
                severity="warning", location=key,
                message=f"metric {metric} ({value}) has no budget — "
                        f"refresh the baseline row"))
        elif value > limit:
            drift.append(f"{metric}: {value} > {limit}")
    if drift:
        findings.append(Finding(
            pass_name="hlo.budgets", code="budget-exceeded",
            severity="error", location=key,
            message="; ".join(drift) + " — the program grew past its "
                    "committed ceiling (if intentional, regenerate "
                    "with --update-budgets and commit the diff)"))
    else:
        findings.append(Finding(
            pass_name="hlo.budgets", code="within-budget",
            severity="info", location=key,
            message=f"ops_total {row['ops_total']} <= "
                    f"{baseline.get('ops_total')}, while-body "
                    f"{row['while_body_total']} <= "
                    f"{baseline.get('while_body_total')}"))
    return findings


def run_pass(ctx: PassContext) -> List[Finding]:
    from repro.analysis.programs import build_programs

    budgets = load_budgets(ctx)
    findings: List[Finding] = []
    measured_all: Dict[str, Dict[str, int]] = {}

    for program in build_programs(only=ctx.only_programs):
        row = measure_program(program)
        measured_all[program.key] = row
        baseline = budgets.get(program.key)
        if baseline is None:
            if not ctx.update_budgets:
                findings.append(Finding(
                    pass_name="hlo.budgets", code="no-baseline",
                    severity="error", location=program.key,
                    message="program has no committed budget row — run "
                            "scripts/fppcheck.py --hlo --update-budgets "
                            "and commit analysis/budgets.json"))
            continue
        findings.extend(check_row(program.key, row, baseline))

    if ctx.update_budgets:
        merged = dict(budgets)
        merged.update(measured_all)
        ctx.budgets_path.write_text(
            json.dumps(merged, indent=2, sort_keys=True) + "\n")
        findings.append(Finding(
            pass_name="hlo.budgets", code="budgets-updated",
            severity="info", location=str(ctx.budgets_path),
            message=f"rewrote {len(measured_all)} budget row(s); commit "
                    f"the diff"))
    return findings
