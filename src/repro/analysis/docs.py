"""Doc-consistency pass: no dangling DESIGN.md § refs, no stale repo map.

The code cites the architecture doc as ``DESIGN.md §N.M`` in docstrings, and
DESIGN.md renumbers sections as the system grows — so every citation is
checked against the headings that actually exist:

  (a) every ``DESIGN.md §N[.M]`` reference in the repo's ``*.py`` files,
      README.md, and CHANGES.md resolves to a real DESIGN.md heading;
  (b) every internal ``§N[.M]`` cross-reference inside DESIGN.md itself
      resolves (references to the *paper's* sections are written
      "paper §N" and are exempt);
  (c) every path named in README's "Repo map" table exists (relative to
      the repo root, or to src/repro/ for bare package entries).

This used to live in ``scripts/check_docs.py``; that script is now a thin
shim over this module so existing CI invocations keep working, and the same
checks run as the registered ``docs.refs`` fppcheck pass (DESIGN.md §7).
Stdlib-only on purpose — CI runs it before the jax install finishes cooking.
"""
from __future__ import annotations

import pathlib
import re
from typing import List, Tuple

from repro.analysis import Finding, PassContext

#: a section citation: §N, §N.M (used both with and without the
#: "DESIGN.md " prefix depending on the file being scanned)
SECTION = r"§(\d+(?:\.\d+)*)"
#: directories never scanned for citations
SKIP_DIRS = {".git", "__pycache__", ".github", "results"}


def design_headings(root: pathlib.Path) -> set:
    """Section numbers with a real heading in DESIGN.md (## §2, ### §2.1)."""
    text = (root / "DESIGN.md").read_text()
    return set(re.findall(rf"^#{{2,}}\s+{SECTION}", text, re.M))


def iter_source_files(root: pathlib.Path):
    for path in sorted(root.rglob("*.py")):
        if not SKIP_DIRS & set(p.name for p in path.parents):
            yield path
    for name in ("README.md", "CHANGES.md"):
        if (root / name).exists():
            yield root / name


def check_design_refs(root: pathlib.Path, headings: set
                      ) -> List[Tuple[str, str]]:
    """Returns (location, message) pairs for every dangling reference."""
    errors = []
    # (a) prefixed references anywhere in the tree
    pat = re.compile(rf"DESIGN\.md\s+{SECTION}")
    for path in iter_source_files(root):
        text = path.read_text(errors="replace")
        for lineno, line in enumerate(text.splitlines(), 1):
            for ref in pat.findall(line):
                if ref not in headings:
                    errors.append((f"{path.relative_to(root)}:{lineno}",
                                   f"dangling reference DESIGN.md §{ref}"))
    # (b) bare internal cross-references inside DESIGN.md; "paper §N"
    # cites the source paper, not this document (checked over the full
    # text so a citation wrapped across a line break still counts)
    text = (root / "DESIGN.md").read_text()
    for m in re.finditer(SECTION, text):
        pre = text[max(0, m.start() - 10):m.start()]
        if re.search(r"[Pp]aper(?:'s)?[\s-]+$", pre):
            continue
        if m.group(1) not in headings:
            lineno = text.count("\n", 0, m.start()) + 1
            errors.append((f"DESIGN.md:{lineno}",
                           f"dangling internal cross-reference "
                           f"§{m.group(1)}"))
    return errors


def check_repo_map(root: pathlib.Path) -> List[Tuple[str, str]]:
    """Every `path` in README's Repo map table must exist on disk."""
    errors = []
    text = (root / "README.md").read_text()
    m = re.search(r"^## Repo map\n(.*?)(?=^## )", text, re.M | re.S)
    if not m:
        return [("README.md", "no '## Repo map' section found")]
    for row in m.group(1).splitlines():
        if not row.startswith("|") or set(row) <= {"|", "-", " "}:
            continue
        first_cell = row.split("|")[1]
        for span in re.findall(r"`([^`]+)`", first_cell):
            if "/" not in span and "." not in span:
                continue
            candidates = (root / span, root / "src" / "repro" / span)
            if not any(p.exists() for p in candidates):
                errors.append(("README.md repo map",
                               f"`{span}` does not exist"))
    return errors


def run_checks(root: pathlib.Path) -> List[Tuple[str, str]]:
    """All (location, message) problems; empty list = docs are consistent."""
    headings = design_headings(root)
    if not headings:
        return [("DESIGN.md", "no § headings found — parser broken?")]
    return check_design_refs(root, headings) + check_repo_map(root)


def run_pass(ctx: PassContext) -> List[Finding]:
    """The registered fppcheck pass (docs.refs)."""
    return [Finding(pass_name="docs.refs", code="dangling-ref",
                    severity="error", location=loc, message=msg)
            for loc, msg in run_checks(ctx.root)]


def main(root: pathlib.Path) -> int:
    """Legacy scripts/check_docs.py CLI behavior (same output contract)."""
    headings = design_headings(root)
    if not headings:
        print("check_docs: DESIGN.md has no § headings — parser broken?")
        return 1
    errors = run_checks(root)
    if errors:
        print(f"check_docs: {len(errors)} dangling reference(s):")
        for loc, msg in errors:
            print(f"  {loc}: {msg}")
        return 1
    print(f"check_docs: OK ({len(headings)} DESIGN.md sections, "
          f"all references resolve, repo map clean)")
    return 0
