"""StarCoder2-7B [dense]: GQA (kv=4), RoPE, non-gated GELU FFN.
[arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab=49152, act="gelu", gated_mlp=False, norm="layernorm",
    qkv_bias=True,
    microbatches=4,
    source="arXiv:2402.19173; hf",
))
