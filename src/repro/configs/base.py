"""Architecture config system.

One ``ArchConfig`` describes any of the assigned architectures; family-specific
fields are optional.  ``reduced()`` produces the CPU-smoke-test variant of the
same family (small layers/width/experts/vocab), per the deliverable contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int          # per-expert FFN width
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default d_model // 16


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    window: int = 2048         # local attention window
    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    lru_width: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"                   # or "layernorm"
    act: str = "silu"                       # or "gelu"
    gated_mlp: bool = True                  # SwiGLU-style (False: plain MLP)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # enc-dec (whisper): encoder depth/width mirror decoder unless set
    n_enc_layers: Optional[int] = None
    cross_attention: bool = False
    # vlm: number of image-patch positions provided by the (stub) frontend
    num_image_tokens: int = 0
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # gradient-accumulation microbatches for the train_4k shape (memory fit)
    microbatches: int = 1
    # long-context capability: full attention is quadratic; SSM/hybrid are not
    subquadratic: bool = False
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline N."""
        d, v = self.d_model, self.vocab
        hd = self.head_dim_
        n = v * d                       # embedding
        if not self.tie_embeddings:
            n += v * d                  # unembed
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.family == "ssm":
            s = self.ssm or SSMConfig()
            din = s.expand * d
            dtr = s.dt_rank or d // 16
            per = (d * 2 * din + s.conv_width * din
                   + din * (dtr + 2 * s.state_dim) + dtr * din
                   + din * s.state_dim + din + din * d)
            return n + self.n_layers * (per + 2 * d)
        if self.family == "moe":
            m = self.moe
            ff = (3 if self.gated_mlp else 2) * d * m.expert_d_ff
            per = att + d * m.num_experts + m.num_experts * ff + 2 * d
            return n + self.n_layers * per
        ff = (3 if self.gated_mlp else 2) * d * self.d_ff
        per = att + ff + 2 * d
        if self.family == "hybrid":
            # roughly: attention layers ~1/3, recurrent ~2/3 w/ similar size
            return n + self.n_layers * (per + d * d // 2)
        total = n + self.n_layers * per
        if self.family == "encdec":
            enc = (self.n_enc_layers or self.n_layers) * (att + ff + 2 * d)
            total += enc + self.n_layers * att  # cross attention
        return total

    def active_params(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6*N_active*D)."""
        if self.family != "moe":
            return self.num_params()
        d = self.d_model
        hd = self.head_dim_
        m = self.moe
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        ff = (3 if self.gated_mlp else 2) * d * m.expert_d_ff
        per = att + d * m.num_experts + m.top_k * ff + 2 * d
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        return n + self.n_layers * per

    def reduced(self) -> "ArchConfig":
        """Same family, tiny dims — the CPU smoke-test configuration."""
        kw = dict(
            # hybrid: one full (rec, rec, attn) group + one tail rec layer
            n_layers=4 if self.family == "hybrid" else min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            d_ff=128,
            vocab=256,
            head_dim=16,
        )
        if self.moe:
            kw["moe"] = MoEConfig(num_experts=min(self.moe.num_experts, 4),
                                  top_k=min(self.moe.top_k, 2),
                                  expert_d_ff=64)
        if self.ssm:
            kw["ssm"] = SSMConfig(state_dim=4, conv_width=4, expand=2,
                                  dt_rank=8)
        if self.hybrid:
            kw["hybrid"] = HybridConfig(window=16, pattern=self.hybrid.pattern)
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
        if self.num_image_tokens:
            kw["num_image_tokens"] = 8
        return dataclasses.replace(self, **kw)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # importing the modules registers the configs
    from repro.configs import (  # noqa: F401
        falcon_mamba_7b, mistral_large_123b, paligemma_3b, phi35_moe,
        qwen2_72b, qwen3_moe_30b, recurrentgemma_2b, stablelm_12b,
        starcoder2_7b, whisper_base)
