"""Mistral-Large-123B [dense]: GQA (kv=8), SwiGLU.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
    vocab=32768,
    microbatches=16,
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
))
