"""PaliGemma-3B [vlm]: SigLIP vision frontend (STUB per spec — input_specs
provides precomputed patch embeddings) + Gemma-2B decoder backbone.
[arXiv:2407.07726; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=257216, head_dim=256, act="gelu", gated_mlp=True,
    tie_embeddings=True, num_image_tokens=256,
    microbatches=2,
    source="arXiv:2407.07726; hf",
))
