"""Whisper-base [audio]: encoder-decoder; conv audio frontend is a STUB per
spec (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, norm="layernorm", act="gelu", gated_mlp=False,
    cross_attention=True, tie_embeddings=True,
    microbatches=2,
    source="arXiv:2212.04356; unverified",
))
