"""Falcon-Mamba-7B [ssm]: attention-free Mamba-1, ssm_state=16; subquadratic
(runs the long_500k shape). [arXiv:2410.05355; unverified]"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=65024,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    subquadratic=True,
    microbatches=4,
    source="arXiv:2410.05355; unverified",
))
