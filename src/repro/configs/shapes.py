"""Assigned input-shape set (LM-family: seq_len x global_batch).

decode_* / long_* lower ``serve_step`` (one new token against a seq_len KV
cache), not ``train_step``.  long_500k requires sub-quadratic attention —
it runs only for archs with ``subquadratic=True`` (falcon-mamba,
recurrentgemma); full-attention archs record a SKIP (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def list_shapes():
    return list(SHAPES)


def applicable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic context handling."""
    if shape == "long_500k":
        return bool(cfg.subquadratic)
    return True


def skip_reason(cfg: ArchConfig, shape: str) -> str:
    if not applicable(cfg, shape):
        return (f"{cfg.name} is a full-attention arch; long_500k targets "
                "the sub-quadratic regime (SSM/hybrid). Recorded per "
                "DESIGN.md §5.")
    return ""


def reduced_shape(shape: ShapeConfig) -> ShapeConfig:
    """CPU smoke-test variant."""
    return ShapeConfig(shape.name, shape.kind,
                       seq_len=min(shape.seq_len, 32),
                       global_batch=min(shape.global_batch, 2))
