"""StableLM-2-12B [dense]: GQA (kv=8), SwiGLU.
[hf:stabilityai/stablelm-2-1_6b family; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100352, norm="layernorm",
    microbatches=4,
    source="hf:stabilityai/stablelm-2-1_6b; hf",
))
