"""RecurrentGemma-2B [hybrid]: RG-LRU + local attention 1:2 pattern
(recurrent, recurrent, attention), window 2048, MQA (kv=1); subquadratic.
[arXiv:2402.19427; hf]"""
from repro.configs.base import ArchConfig, HybridConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256, act="gelu",
    hybrid=HybridConfig(window=2048,
                        pattern=("recurrent", "recurrent", "attention")),
    tie_embeddings=True, subquadratic=True,
    microbatches=2,
    source="arXiv:2402.19427; hf",
))
