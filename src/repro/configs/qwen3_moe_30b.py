"""Qwen3-MoE-30B (3B active) [moe]: 128 experts, top-8, GQA (kv=4),
head_dim=128 explicit. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab=151936, head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768),
    microbatches=4,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
