"""Unified fork-processing front door: plan → execute → stream.

    from repro.fpp import FPPSession
    res = FPPSession(g).plan(num_queries=64).run("sssp", sources)

See DESIGN.md §3.  planner.py picks the partition size against a device
memory model, backends.py dispatches engine / distributed / baselines behind
one result contract, session.py owns the vertex reordering, streaming.py
folds asynchronously-arriving query batches into in-flight execution.
"""
from repro.fpp.backends import BACKENDS, KINDS, BackendResult, run_query
from repro.fpp.planner import (MemoryModel, Plan, autoscale_capacity,
                               autotune_block_size, make_plan,
                               model_block_size)
from repro.fpp.session import FPPSession, SessionResult
from repro.fpp.streaming import StreamingExecutor, StreamQuery

__all__ = [
    "BACKENDS", "KINDS", "BackendResult", "run_query",
    "MemoryModel", "Plan", "autoscale_capacity", "autotune_block_size",
    "make_plan", "model_block_size", "FPPSession", "SessionResult",
    "StreamingExecutor", "StreamQuery",
]
