"""FPPSession — the front door: plan → execute → stream (DESIGN.md §3).

One object owns the whole life of a fork-processing pattern:

    sess = FPPSession(g)                       # host CSR, original vertex ids
    sess.plan(num_queries=64)                  # memory-model block-size plan
    res = sess.run("sssp", sources)            # original ids in AND out
    res = sess.run("sssp", sources, backend="baselines")   # same contract
    res = sess.run("ppr", seeds, backend="distributed")    # pod-scale push
    bc  = sess.bc(sources)                     # applications ride the same path
    stream = sess.stream("sssp", capacity=8)   # queries arriving over time

Above the session sits the serving layer: ``serve/graph_server.py``
(DESIGN.md §4.2) registers one session per graph and multiplexes
multi-tenant request streams onto per-(graph, kind) ``stream()`` executors.

Everything downstream of here (engine, distributed runtime, baselines) speaks
the *reordered* id space and partition-major state; the session is the only
layer that owns ``perm`` and hides it.  All three backends serve every query
kind — both visit-algebra families (minplus and push, core/visit.py) run on
the single-device engine AND the shard_map pod runtime — and return identical
dtypes/shapes (see backends.py), so swapping ``backend=`` is a one-word
experiment, not a rewrite.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.graph import BlockGraph, CSRGraph
from repro.core.partition import partition
from repro.core.yielding import YieldConfig
from repro.fpp import backends as _backends
from repro.fpp import planner as _planner
from repro.fpp.planner import MemoryModel, Plan


@dataclasses.dataclass
class SessionResult:
    """Backend-independent result, in the ORIGINAL vertex id space."""
    kind: str
    backend: str
    values: np.ndarray                # [Q, n] float32
    residual: Optional[np.ndarray]    # [Q, n] float32 (ppr) or None
    edges_processed: np.ndarray       # [Q] float64
    stats: dict
    sources: np.ndarray               # [Q] original ids as submitted


class FPPSession:
    """Plan → execute → stream for fork-processing patterns on one graph."""

    def __init__(self, g: CSRGraph, *, mem: Optional[MemoryModel] = None):
        self.graph = g
        self.mem = mem or MemoryModel()
        self._plan: Optional[Plan] = None
        # (block_size, method, weight_variant) -> (BlockGraph, perm)
        self._prepared: Dict[tuple, Tuple[BlockGraph, np.ndarray]] = {}
        self._kreach_stride: Optional[float] = None
        # the serving compile cache warms megasteps on background threads
        # (serve/compile_cache.py); partitioning must not race itself
        self._prepare_lock = threading.Lock()

    @property
    def kreach_stride(self) -> float:
        """The hop-shift S for this graph's kreach packing (a per-graph
        constant: ``oracles.kreach_stride`` of n and the max weight), shared
        by the "shift" weight variant and the result decode so they can
        never disagree."""
        if self._kreach_stride is None:
            from repro.core.oracles import kreach_stride
            g = self.graph
            self._kreach_stride = kreach_stride(
                g.n, float(g.weights.max()) if g.m else 1.0)
        return self._kreach_stride

    # ------------------------------------------------------------------ plan

    def plan(self, num_queries: int = 64, *,
             block_size: Optional[int] = None,
             method: Optional[str] = None,
             schedule: str = "priority",
             backend: str = "engine",
             yield_config: Optional[YieldConfig] = None,
             fused: object = False,
             tune: bool = False,
             tune_sources: Optional[np.ndarray] = None,
             tune_kind: str = "sssp") -> "FPPSession":
        """Resolve the execution plan; chainable.

        ``tune=True`` measures every memory-feasible block size on a query
        sample (``tune_sources``, default: first min(8, Q) vertices with
        out-edges) and keeps the one with the least modeled traffic —
        feeding benchmarks/fig16's sweep back into the system.

        ``fused`` may be True/False (a blanket visit-body choice) or
        ``"auto"``: each run/stream then picks the body per kind from the
        committed dispatch yardsticks (``planner.auto_fused`` — fused
        wins for minplus kinds, the XLA megastep for ppr).
        """
        p = _planner.make_plan(self.graph, num_queries, mem=self.mem,
                               block_size=block_size, method=method,
                               schedule=schedule, backend=backend,
                               yield_config=yield_config, fused=fused)
        self._plan = p
        if tune and block_size is None:
            if tune_sources is None:
                deg = self.graph.out_degree()
                cand = np.flatnonzero(deg > 0)
                tune_sources = cand[:min(8, cand.size)]
            best, rows = _planner.autotune_block_size(
                self, tune_kind, np.asarray(tune_sources), self.mem,
                num_queries=num_queries)
            self._plan = dataclasses.replace(
                p, block_size=best, tuned=True,
                tuning_rows=tuple(tuple(sorted(r.items())) for r in rows))
        return self

    @property
    def current_plan(self) -> Plan:
        if self._plan is None:
            self.plan()
        return self._plan

    # -------------------------------------------------------------- prepare

    def prepared(self, *, block_size: Optional[int] = None,
                 method: Optional[str] = None,
                 unit_weights: bool = False,
                 weights: Optional[str] = None):
        """(BlockGraph, perm) for the plan (or overrides), cached per
        weight variant.

        ``weights`` names a ``core/queries.reweight`` variant (natural /
        unit / zero / shift); ``unit_weights=True`` is the legacy spelling
        of ``weights="unit"``.  Reweighting never touches the structure, so
        every variant of one (block_size, method) shares the same perm —
        each just carries its own block values.
        """
        from repro.core.queries import reweight
        p = self.current_plan
        bs = int(block_size or p.block_size)
        meth = method or p.method
        variant = weights or ("unit" if unit_weights else "natural")
        key = (bs, meth, variant)
        with self._prepare_lock:
            if key not in self._prepared:
                stride = self.kreach_stride if variant == "shift" else None
                g = reweight(self.graph, variant, stride=stride)
                self._prepared[key] = partition(g, bs, method=meth)
            return self._prepared[key]

    # ------------------------------------------------------------------ run

    def run(self, kind: str, sources: np.ndarray, *,
            backend: Optional[str] = None,
            schedule: Optional[str] = None,
            yield_config: Optional[YieldConfig] = None,
            block_size: Optional[int] = None,
            method: Optional[str] = None,
            alpha: float = 0.15, eps: float = 1e-4,
            use_pallas: bool = False, mesh=None,
            max_visits: Optional[int] = None,
            fused: Optional[bool] = None,
            frontier_mode: str = "dense",
            k: int = 8, length: int = 32,
            seed: int = 0) -> SessionResult:
        """Execute one query batch.  Sources and values use original ids.

        ``fused`` defaults to the plan's setting (``plan(fused=True)``);
        pass it explicitly to override per run.  ``frontier_mode="sparse"``
        selects the fused kernel's chunk-skipping late-frontier relaxation
        (minplus kinds only).

        The session resolves each kind's weight variant and decode: ``cc``
        values come back as canonical min-original-id component labels
        (identical across every lane and backend), ``kreach`` takes the
        hop budget ``k`` (values = dist of the hop-minimal path within the
        budget; residual = hop counts), ``rw`` takes ``length``/``seed``
        (values = occupancy counts; fused is not applicable and is
        ignored — the walker loop has no megastep to fuse).
        """
        from repro.core.queries import WEIGHT_VARIANTS
        sources = np.asarray(sources)
        p = self.current_plan
        bg, perm = self.prepared(block_size=block_size, method=method,
                                 weights=WEIGHT_VARIANTS.get(kind, "natural"))
        yc = (yield_config if yield_config is not None else
              (p.yield_config or _planner.default_yield_config(kind, bg)))
        bk = backend or p.backend
        if fused is None:
            # the plan's default applies only where it can: other backends
            # run their own visit bodies (explicit fused=True still raises).
            # plan(fused="auto") resolves per kind from committed yardsticks,
            # falling back to the XLA megastep when this partitioning is
            # denser than the fused-kernel dmax budget.
            fused = bk == "engine" and kind != "rw" and p.resolve_fused(
                kind, dmax=bg.nbr_part.shape[1])
        out = _backends.run_query(
            bk, kind, bg, perm[sources],
            schedule=schedule or p.schedule, yield_config=yc,
            alpha=alpha, eps=eps, use_pallas=use_pallas, mesh=mesh,
            max_visits=max_visits,
            fused=bool(fused) and kind != "rw", frontier_mode=frontier_mode,
            k=k, hop_stride=(self.kreach_stride if kind == "kreach" else 1.0),
            length=length, seed=seed)
        values = out.values[:, perm]          # back to original vertex ids
        if kind == "cc":
            values = _backends.canonicalize_cc(values)
        residual = None if out.residual is None else out.residual[:, perm]
        return SessionResult(kind=kind, backend=backend or p.backend,
                             values=values, residual=residual,
                             edges_processed=out.edges_processed,
                             stats=out.stats, sources=sources)

    # --------------------------------------------------------------- stream

    def stream(self, kind: str = "sssp", capacity: int = 16, *,
               schedule: Optional[str] = None,
               yield_config: Optional[YieldConfig] = None,
               alpha: float = 0.15, eps: float = 1e-4,
               harvest_every: int = 1, k_visits: int = 64,
               fused: Optional[bool] = None, megastep=None,
               k: int = 8, length: int = 32, seed: int = 0):
        """A streaming executor: submit query batches as they arrive
        (fpp/streaming.py); answers match the one-shot run of the union.
        ``k_visits`` sets the device-resident chunk size — admission and
        harvest happen at chunk boundaries (DESIGN.md §3.3), so it is also
        the lane-recycling latency knob: lower K = fresher harvests, more
        host syncs.  ``harvest_every`` only affects the legacy per-visit
        ``step()`` cadence; the default ``pump()``/``run()`` path harvests
        once per chunk regardless.  ``fused`` defaults to the plan's
        (per-kind under ``fused="auto"``); ``megastep`` injects a warm
        pre-compiled executable (serve/compile_cache.py) so the executor
        never traces.

        ``kind="rw"`` returns a :class:`~repro.fpp.streaming.WalkExecutor`
        (same submit/pump/take_finished surface) whose walks are bitwise
        the tape walks of ``run("rw", ...)`` at the executor's ``length``
        and ``seed``; ``kind="kreach"`` streams at hop budget ``k``.
        """
        from repro.fpp.streaming import StreamingExecutor, WalkExecutor
        from repro.core.queries import WEIGHT_VARIANTS
        if kind == "rw":
            # ``megastep`` doubles as the warm compiled walk visit here —
            # one injection surface for every lane kind
            return WalkExecutor(self, capacity=capacity, length=length,
                                seed=seed, k_visits=k_visits, visit=megastep)
        if fused is None:
            bg, _ = self.prepared(
                weights=WEIGHT_VARIANTS.get(kind, "natural"))
            fused = self.current_plan.resolve_fused(
                kind, k_visits, dmax=bg.nbr_part.shape[1])
        return StreamingExecutor(
            self, kind=kind, capacity=capacity,
            schedule=schedule or self.current_plan.schedule,
            yield_config=yield_config, alpha=alpha, eps=eps,
            harvest_every=harvest_every, k_visits=k_visits,
            fused=bool(fused), megastep=megastep, k=k)

    # --------------------------------------------------- paper applications

    def bc(self, sources: np.ndarray, **run_kw):
        """Approximate betweenness centrality from sampled BFS roots."""
        from repro.core.applications import bc_accumulate
        res = self.run("bfs", sources, **run_kw)
        return bc_accumulate(self.graph, np.asarray(sources),
                             res.values), res

    def landmarks(self, landmarks: np.ndarray, **run_kw):
        """Landmark labeling: one SSSP per landmark, labels in original ids."""
        from repro.core.applications import LandmarkLabels
        res = self.run("sssp", landmarks, **run_kw)
        return LandmarkLabels(np.asarray(landmarks), res.values), res

    def ncp(self, seeds: np.ndarray, *, alpha: float = 0.15,
            eps: float = 1e-4, max_size: Optional[int] = None, **run_kw):
        """Network community profile from a fleet of PPRs."""
        from repro.core.applications import ncp_profile
        res = self.run("ppr", seeds, alpha=alpha, eps=eps, **run_kw)
        return ncp_profile(self.graph, res.values,
                           max_size=max_size), res

    def random_walks(self, sources: np.ndarray, length: int = 32, *,
                     seed: int = 0, block_size: Optional[int] = None,
                     method: Optional[str] = None):
        """Buffered random walks (core/randomwalk.py), original ids in/out.

        Walkers are FPP queries under the same plan as everything else:
        the session hands reordered sources to ``core/queries.run_rw`` and
        maps the final ``positions`` back through the inverse permutation,
        so callers never see the partition-major id space.  ``steps`` and
        ``trajectory_hash`` are id-space-independent and pass through.
        """
        import dataclasses as _dc

        from repro.core.queries import run_rw
        sources = np.asarray(sources)
        bg, perm = self.prepared(block_size=block_size, method=method)
        res = run_rw(bg, perm[sources], length, seed=seed)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(len(perm))
        return _dc.replace(res, positions=inv[res.positions])
