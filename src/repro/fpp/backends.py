"""Backend dispatch: three execution paths behind one result contract.

  engine       single-device buffered FPP engine (core/engine.py, Alg. 2)
  distributed  shard_map pod runtime (core/distributed.py) — partitions over
               the "model" mesh axis, queries over "data"
  baselines    global-frontier GPS engines (core/baselines.py), kept callable
               so every speedup claim stays one flag away from its baseline

Every (backend, kind) pair in ``BACKENDS × KINDS`` dispatches — the engine
and the distributed runtime instantiate the same ``core/visit.py`` algebra
for both the minplus (sssp/bfs) and push (ppr) families, so no combination
raises.  Whatever the backend, the caller gets the same contract back:
``values`` is float32 ``[Q, n]`` in the *reordered* id space (the session
maps back to original ids), ``edges_processed`` is float64 ``[Q]`` holding
exact integral counts.  That uniformity is what lets tests assert all three
paths against core/oracles.py bit-for-bit on dtype/shape (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.baselines import (global_minplus, global_push,
                                  global_random_walks)
from repro.core.engine import FPPEngine
from repro.core.graph import BlockGraph
from repro.core.oracles import decode_kreach
from repro.core.visit import cc_label_plane
from repro.core.yielding import YieldConfig

BACKENDS = ("engine", "distributed", "baselines")
KINDS = ("sssp", "bfs", "ppr", "cc", "kreach", "rw")

#: engine mode per kind; rw bypasses the visit-algebra engine entirely
#: (core/randomwalk.py is its own buffered loop over the same substrate)
_ENGINE_MODE = {"sssp": "minplus", "bfs": "minplus", "ppr": "push",
                "cc": "cc", "kreach": "kreach"}


@dataclasses.dataclass
class BackendResult:
    values: np.ndarray                 # [Q, n] float32, reordered id space
    residual: Optional[np.ndarray]     # [Q, n] float32 (push kinds) or None
    edges_processed: np.ndarray        # [Q] float64
    stats: dict                        # visits / rounds / supersteps / bytes


def _normalize(values, residual, edges, stats) -> BackendResult:
    return BackendResult(
        values=np.ascontiguousarray(np.asarray(values, dtype=np.float32)),
        residual=(None if residual is None
                  else np.asarray(residual, dtype=np.float32)),
        edges_processed=np.asarray(edges, dtype=np.float64),
        stats=stats)


def default_mesh():
    """(data=1, model=ndev) mesh over whatever devices this process has."""
    import jax
    return jax.make_mesh((1, len(jax.devices())), ("data", "model"))


def canonicalize_cc(values: np.ndarray) -> np.ndarray:
    """Rewrite raw cc label rows (reordered-rep ids, any id space) into the
    canonical min-original-id-per-component labels.

    ``values``: [Q, n] rows in the ORIGINAL vertex order whose cells hold
    the backend's reordered representative ids.  Two vertices share a
    component iff they share a cell value, so grouping by value and taking
    the min row index (= min original id) yields labels independent of the
    partitioning permutation — the form union-find (oracles.connected_
    components) produces directly.
    """
    values = np.asarray(values)
    n = values.shape[1]
    out = np.empty_like(values, dtype=np.float32)
    done: dict = {}
    for q in range(values.shape[0]):
        key = values[q].tobytes()       # cc lanes are identical; decode once
        if key not in done:
            reps = values[q].astype(np.int64)
            min_orig = np.full(n, n, dtype=np.int64)
            np.minimum.at(min_orig, reps, np.arange(n))
            done[key] = min_orig[reps].astype(np.float32)
        out[q] = done[key]
    return out


def _rw_result(res, stats: dict) -> BackendResult:
    """WalkResult -> the uniform backend contract: values = occupancy
    counts [Q, n] (start + each step's position), edges = steps taken."""
    return _normalize(res.occupancy, None,
                      np.asarray(res.steps, dtype=np.float64), stats)


def run_query(backend: str, kind: str, bg: BlockGraph, sources: np.ndarray,
              *, schedule: str = "priority",
              yield_config: Optional[YieldConfig] = None,
              alpha: float = 0.15, eps: float = 1e-4,
              use_pallas: bool = False, mesh=None,
              max_visits: Optional[int] = None,
              fused: bool = False,
              frontier_mode: str = "dense",
              k: int = 8, hop_stride: float = 1.0,
              length: int = 32, seed: int = 0) -> BackendResult:
    """Run one query batch (sources in reordered ids) on one backend.

    ``fused=True`` (engine backend only) swaps each visit body for the
    fused Pallas kernel (kernels/fused_visit): the whole visit — apply,
    relax rounds, emission, scheduler refresh — runs inside one
    pallas_call, bit-identical to the XLA megastep for the deterministic
    algebras.  ``frontier_mode="sparse"`` selects the chunk-skipping
    relaxation for late sparse frontiers (minplus kinds only).

    The transformed-weight kinds expect ``bg`` already built from the
    matching weight variant (session.prepared handles this): ``cc`` a
    zero-weight graph, ``kreach`` the hop-shifted weights with
    ``hop_stride`` = the shift S (``oracles.kreach_stride``) and ``k`` the
    hop budget.  ``rw`` takes the natural graph plus ``length``/``seed``;
    its values are occupancy counts and its trajectories are identical
    across all three backends (see core/randomwalk.py's tape contract).
    Raw ``cc`` values are reordered-rep labels — callers canonicalize with
    :func:`canonicalize_cc` after mapping back to original ids.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if kind not in KINDS:
        raise ValueError(f"unknown query kind {kind!r}; one of {KINDS}")
    if fused and backend != "engine":
        raise ValueError(
            f"fused=True is an engine-backend flag; backend={backend!r} "
            f"runs its own visit bodies")
    sources = np.asarray(sources)

    if kind == "rw":
        if backend == "engine":
            from repro.core.randomwalk import run_random_walks
            res = run_random_walks(bg, sources, length, seed=seed)
            return _rw_result(res, {"visits": res.visits})
        if backend == "baselines":
            res = global_random_walks(bg, sources, length, seed=seed)
            return _rw_result(res, {"rounds": res.visits})
        from repro.core.distributed import run_distributed_walks
        res = run_distributed_walks(bg, sources, mesh or default_mesh(),
                                    length, seed=seed)
        return _rw_result(res, {"supersteps": res.visits})

    if backend == "engine":
        eng = FPPEngine(bg, mode=_ENGINE_MODE[kind],
                        num_queries=len(sources),
                        yield_config=yield_config or YieldConfig(),
                        schedule=schedule, alpha=alpha, eps=eps,
                        use_pallas=use_pallas, fused=fused,
                        frontier_mode=frontier_mode,
                        hop_budget=k, hop_stride=hop_stride)
        res = eng.run(sources, max_visits=max_visits)
        return _normalize(res.values, res.residual, res.edges_processed, {
            "visits": res.stats.visits, "rounds": res.stats.rounds,
            "blocks_loaded": res.stats.blocks_loaded,
            "modeled_bytes": res.stats.modeled_bytes,
            "host_syncs": res.stats.host_syncs})

    if backend == "baselines":
        if kind == "ppr":
            res = global_push(bg, sources, alpha=alpha, eps=eps)
            residual = np.zeros_like(res.values)  # Jacobi push drains below eps
        elif kind == "cc":
            res = global_minplus(bg, sources,
                                 init_plane=cc_label_plane(bg))
            residual = None
        else:
            res = global_minplus(bg, sources)
            residual = None
        values = res.values
        if kind == "kreach":
            values, residual = decode_kreach(values, hop_stride, k)
        return _normalize(values, residual, res.edges_processed, {
            "rounds": res.rounds, "modeled_bytes": res.modeled_bytes,
            "modeled_bytes_shared": res.modeled_bytes_shared})

    # distributed: the same visit algebra at pod scale (DESIGN.md §2.2)
    from repro.core.distributed import (run_distributed_cc,
                                        run_distributed_ppr,
                                        run_distributed_sssp)
    mesh = mesh or default_mesh()
    if kind == "ppr":
        res = run_distributed_ppr(bg, sources, mesh, alpha=alpha, eps=eps,
                                  yield_config=yield_config)
    elif kind == "cc":
        res = run_distributed_cc(bg, len(sources), mesh,
                                 yield_config=yield_config)
    else:
        res = run_distributed_sssp(bg, sources, mesh,
                                   yield_config=yield_config)
    values, residual = res.values, res.residual
    if kind == "kreach":
        values, residual = decode_kreach(values, hop_stride, k)
    return _normalize(values, residual, res.edges_processed, {
        "supersteps": res.supersteps})
