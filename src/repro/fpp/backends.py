"""Backend dispatch: three execution paths behind one result contract.

  engine       single-device buffered FPP engine (core/engine.py, Alg. 2)
  distributed  shard_map pod runtime (core/distributed.py) — partitions over
               the "model" mesh axis, queries over "data"
  baselines    global-frontier GPS engines (core/baselines.py), kept callable
               so every speedup claim stays one flag away from its baseline

Every (backend, kind) pair in ``BACKENDS × KINDS`` dispatches — the engine
and the distributed runtime instantiate the same ``core/visit.py`` algebra
for both the minplus (sssp/bfs) and push (ppr) families, so no combination
raises.  Whatever the backend, the caller gets the same contract back:
``values`` is float32 ``[Q, n]`` in the *reordered* id space (the session
maps back to original ids), ``edges_processed`` is float64 ``[Q]`` holding
exact integral counts.  That uniformity is what lets tests assert all three
paths against core/oracles.py bit-for-bit on dtype/shape (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.baselines import global_minplus, global_push
from repro.core.engine import FPPEngine
from repro.core.graph import BlockGraph
from repro.core.yielding import YieldConfig

BACKENDS = ("engine", "distributed", "baselines")
KINDS = ("sssp", "bfs", "ppr")


@dataclasses.dataclass
class BackendResult:
    values: np.ndarray                 # [Q, n] float32, reordered id space
    residual: Optional[np.ndarray]     # [Q, n] float32 (push kinds) or None
    edges_processed: np.ndarray        # [Q] float64
    stats: dict                        # visits / rounds / supersteps / bytes


def _normalize(values, residual, edges, stats) -> BackendResult:
    return BackendResult(
        values=np.ascontiguousarray(np.asarray(values, dtype=np.float32)),
        residual=(None if residual is None
                  else np.asarray(residual, dtype=np.float32)),
        edges_processed=np.asarray(edges, dtype=np.float64),
        stats=stats)


def default_mesh():
    """(data=1, model=ndev) mesh over whatever devices this process has."""
    import jax
    return jax.make_mesh((1, len(jax.devices())), ("data", "model"))


def run_query(backend: str, kind: str, bg: BlockGraph, sources: np.ndarray,
              *, schedule: str = "priority",
              yield_config: Optional[YieldConfig] = None,
              alpha: float = 0.15, eps: float = 1e-4,
              use_pallas: bool = False, mesh=None,
              max_visits: Optional[int] = None,
              fused: bool = False,
              frontier_mode: str = "dense") -> BackendResult:
    """Run one query batch (sources in reordered ids) on one backend.

    ``fused=True`` (engine backend only) swaps each visit body for the
    fused Pallas kernel (kernels/fused_visit): the whole visit — apply,
    relax rounds, emission, scheduler refresh — runs inside one
    pallas_call, bit-identical to the XLA megastep for the deterministic
    algebras.  ``frontier_mode="sparse"`` selects the chunk-skipping
    relaxation for late sparse frontiers (minplus kinds only).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if kind not in KINDS:
        raise ValueError(f"unknown query kind {kind!r}; one of {KINDS}")
    if fused and backend != "engine":
        raise ValueError(
            f"fused=True is an engine-backend flag; backend={backend!r} "
            f"runs its own visit bodies")
    sources = np.asarray(sources)

    if backend == "engine":
        mode = "push" if kind == "ppr" else "minplus"
        eng = FPPEngine(bg, mode=mode, num_queries=len(sources),
                        yield_config=yield_config or YieldConfig(),
                        schedule=schedule, alpha=alpha, eps=eps,
                        use_pallas=use_pallas, fused=fused,
                        frontier_mode=frontier_mode)
        res = eng.run(sources, max_visits=max_visits)
        return _normalize(res.values, res.residual, res.edges_processed, {
            "visits": res.stats.visits, "rounds": res.stats.rounds,
            "blocks_loaded": res.stats.blocks_loaded,
            "modeled_bytes": res.stats.modeled_bytes,
            "host_syncs": res.stats.host_syncs})

    if backend == "baselines":
        if kind == "ppr":
            res = global_push(bg, sources, alpha=alpha, eps=eps)
            residual = np.zeros_like(res.values)  # Jacobi push drains below eps
        else:
            res = global_minplus(bg, sources)
            residual = None
        return _normalize(res.values, residual, res.edges_processed, {
            "rounds": res.rounds, "modeled_bytes": res.modeled_bytes,
            "modeled_bytes_shared": res.modeled_bytes_shared})

    # distributed: the same visit algebra at pod scale (DESIGN.md §2.2)
    from repro.core.distributed import (run_distributed_ppr,
                                        run_distributed_sssp)
    mesh = mesh or default_mesh()
    if kind == "ppr":
        res = run_distributed_ppr(bg, sources, mesh, alpha=alpha, eps=eps,
                                  yield_config=yield_config)
    else:
        res = run_distributed_sssp(bg, sources, mesh,
                                   yield_config=yield_config)
    return _normalize(res.values, res.residual, res.edges_processed, {
        "supersteps": res.supersteps})
