"""Partition-size planning: the paper's "partition fits in LLC" rule as code.

The deciding performance knob of the whole design is how much graph becomes
resident per visit (paper §7.3 / Fig. 16; GPOP and CSR-segmenting reach the
same conclusion: partition-size-to-cache fit decides everything).  On TPU the
LLC is VMEM, so the planner solves

    argmax B  s.t.  working_set(B, Q) <= vmem_bytes

against an explicit :class:`MemoryModel`, and can optionally *measure* the
candidates on a query sample (``tune=True``) — the sweep previously buried in
``benchmarks/fig16_partition_size.py`` / ``benchmarks/table4_tuning.py``, now
reusable (those benchmarks call :func:`measure_run` today).

DESIGN.md §3 documents how the plan feeds the session front door.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.graph import CSRGraph
from repro.core.yielding import YieldConfig, default_delta

#: block-size candidates, smallest to largest (TPU lane-friendly powers of 2)
CANDIDATE_BLOCK_SIZES = (64, 128, 256, 512, 1024, 2048, 4096)

#: neighbor-slot budget assumed when sizing a *fused* plan before the graph
#: is partitioned (dmax is a property of the partitioning, not the plan);
#: real dmax beyond this only grows the parking scratch linearly, so the
#: budget is a planning guard, not a correctness bound
FUSED_DMAX_BUDGET = 8

#: committed dispatch yardsticks (visits/s) from BENCH_engine.json's
#: ``bench_dispatch`` section — the measured trajectory a perf PR commits.
#: Keyed (kind, dispatch, K).  ``auto_fused`` reads these to pick the visit
#: body per kind instead of a blanket ``fused=`` flag: the fused Pallas
#: visit wins for the minplus family (sssp K=64: 6809 vs 6185 visits/s)
#: but *loses* for push (ppr K=64: 2500 vs 3540 — the in-kernel push
#: round's lane-mask traffic outweighs the residency win on small
#: partitions; the regression is recorded in BENCH_engine.json's
#: ``bench_notes`` and stands until a fused-push PR beats the yardstick).
DISPATCH_YARDSTICKS = {
    ("sssp", "megastep", 8): 4597.4,
    ("sssp", "megastep", 64): 6185.4,
    ("sssp", "fused", 8): 5407.5,
    ("sssp", "fused", 64): 6809.3,
    ("ppr", "megastep", 8): 3088.4,
    ("ppr", "megastep", 64): 3539.8,
    ("ppr", "fused", 8): 2535.4,
    ("ppr", "fused", 64): 2500.3,
}

#: bfs runs the same minplus megastep/fused kernels as sssp (unit weights
#: only change the block values), so it shares sssp's yardstick row; cc and
#: kreach are minplus instantiations over transformed weights (zero /
#: hop-shifted), so they share it too.  rw has no yardstick row yet — its
#: walker loop never dispatches a megastep, so auto_fused's conservative
#: False is exactly right.
_YARDSTICK_KIND = {"bfs": "sssp", "cc": "sssp", "kreach": "sssp"}


def auto_fused(kind: str, k_visits: int = 64,
               dmax: Optional[int] = None) -> bool:
    """Pick the visit body for ``kind`` from the committed yardsticks.

    True iff the fused Pallas visit measured faster than the XLA megastep
    at the nearest committed chunk size.  Unknown kinds (no committed rows
    either way) conservatively stay on the XLA megastep — a new kind must
    land a ``bench_dispatch`` row before auto-select will fuse it.

    ``dmax`` (the partitioning's neighbor-slot count, ``bg.nbr_part
    .shape[1]``) guards the auto-select against block graphs denser than
    the :data:`FUSED_DMAX_BUDGET` the yardsticks were measured under: the
    fused kernel's pre-gathered ``[P, 1+dmax, B+1, B]`` adjacency and its
    ``(1+dmax,)`` grid both grow linearly in dmax, so past the budget the
    residency win inverts and auto-select stays on the XLA megastep.  An
    *explicit* ``fused=True`` is never overridden — callers who measured
    their own graph keep their choice.
    """
    if dmax is not None and int(dmax) > FUSED_DMAX_BUDGET:
        return False
    yk = _YARDSTICK_KIND.get(kind, kind)
    ks = sorted({k for (kk, _, k) in DISPATCH_YARDSTICKS if kk == yk})
    if not ks:
        return False
    k = min(ks, key=lambda c: abs(c - int(k_visits)))
    fused = DISPATCH_YARDSTICKS.get((yk, "fused", k))
    plain = DISPATCH_YARDSTICKS.get((yk, "megastep", k))
    return fused is not None and plain is not None and fused > plain


def pow2_bucket(demand: int, min_capacity: int = 1,
                max_capacity: int = 1024) -> int:
    """Snap a lane-count demand to its power-of-two bucket.

    Every capacity the serving layer ever instantiates comes through here
    (initial pool size, autoscale hints), so the set of compiled megastep
    shapes stays logarithmic in demand and a resize lands on a warm
    executable in the serving compile cache (keyed by this bucket) instead
    of a retrace (DESIGN.md §4.2).
    """
    demand = max(int(demand), int(min_capacity), 1)
    cap = 1
    while cap < demand:
        cap *= 2
    return max(int(min_capacity), min(int(max_capacity), cap))


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Device memory budget the plan must fit (two-level hierarchy, §2).

    Working set of one partition visit (what must be VMEM-resident):
      adjacency block   B*B*dtype   (x2 when double-buffering the next block)
      dist/value tile   Q*B*dtype
      buffer tile       Q*B*dtype
    HBM holds the full block-sparse graph plus the [P, Q, B] state planes;
    ``hbm_bytes`` caps the state so Q and B cannot silently overflow a chip.
    """
    vmem_bytes: int = 96 * 1024 * 1024
    hbm_bytes: int = 16 * 1024 ** 3
    dtype_bytes: int = 4
    double_buffer: bool = True

    def working_set(self, block_size: int, num_queries: int) -> int:
        mult = 2 if self.double_buffer else 1
        return (mult * block_size * block_size * self.dtype_bytes
                + 2 * num_queries * block_size * self.dtype_bytes)

    def fused_working_set(self, block_size: int, num_queries: int,
                          num_planes: int, dmax: int) -> int:
        """VMEM bytes one *fused* visit holds resident (DESIGN.md §2.4).

        The fused kernel keeps every state channel (``num_planes`` value
        planes + the buffer) for the visited partition in VMEM across the
        whole visit, both the in and the aliased out block, plus the
        partition's pre-gathered adjacency row (diagonal + ``dmax``
        boundary blocks, each [B+1, B] with the nnz row folded in), the
        degree row, the request vector, and the emission parking scratch
        (two [Q, B] planes and a degree row per slot, slot 0 being the
        resident row).  This is deliberately larger than ``working_set``:
        residency across rounds is the fusion's point, so the planner
        must budget the whole visit, not one relaxation.
        """
        b, q, d = block_size, num_queries, self.dtype_bytes
        chans = num_planes + 1
        slots = 1 + dmax
        state = 2 * chans * q * b * d            # in + aliased out block
        adj = slots * (b + 1) * b * d            # w_vis row, nnz folded in
        scratch = slots * (2 * q * b + b) * d    # cand/plane/deg parking
        return state + adj + scratch + b * d + (1 + q) * d

    def state_bytes(self, n_vertices: int, num_queries: int,
                    block_size: int) -> int:
        """HBM-resident state planes (dist + buf + one spare), padded."""
        n_pad = -(-n_vertices // block_size) * block_size
        return 3 * n_pad * num_queries * self.dtype_bytes

    def covers(self, footprint_bytes: int, block_size: int,
               num_queries: int) -> bool:
        """True if a kernel's *static* VMEM footprint is within budget.

        The fppcheck Pallas contract pass (DESIGN.md §7) computes each
        wired kernel's per-grid-step footprint from its BlockSpecs and
        asks this model — the same one that planned the block size —
        whether that footprint stays inside the working set budgeted for
        one ``(block_size, num_queries)`` partition visit.  A kernel
        whose tiles outgrow the model would thrash exactly the cache the
        planner sized for.
        """
        return (footprint_bytes <= self.working_set(block_size, num_queries)
                and footprint_bytes <= self.vmem_bytes)

    def fused_covers(self, footprint_bytes: int, block_size: int,
                     num_queries: int, num_planes: int, dmax: int) -> bool:
        """``covers`` for fused-visit kernels (``fused_model=True``
        contracts): the footprint is judged against the whole-visit
        residency budget instead of the single-relaxation working set."""
        return (footprint_bytes <= self.fused_working_set(
                    block_size, num_queries, num_planes, dmax)
                and footprint_bytes <= self.vmem_bytes)

    def fits(self, block_size: int, num_queries: int,
             n_vertices: Optional[int] = None, *,
             fused: bool = False, num_planes: int = 2,
             dmax: int = FUSED_DMAX_BUDGET) -> bool:
        if self.working_set(block_size, num_queries) > self.vmem_bytes:
            return False
        if fused and self.fused_working_set(
                block_size, num_queries, num_planes, dmax) > self.vmem_bytes:
            return False
        if n_vertices is not None and self.state_bytes(
                n_vertices, num_queries, block_size) > self.hbm_bytes:
            return False
        return True


@dataclasses.dataclass(frozen=True)
class Plan:
    """A resolved execution plan for one fork-processing pattern."""
    block_size: int
    method: str                 # partition/reorder method (partition.py)
    schedule: str               # inter-partition policy (scheduler.py)
    backend: str                # "engine" | "distributed" | "baselines"
    num_queries: int
    mem: MemoryModel
    yield_config: Optional[YieldConfig] = None   # None => per-kind default
    tuned: bool = False
    tuning_rows: tuple = ()
    #: visit-body dispatch: False = XLA megastep, True = fused Pallas
    #: kernel, "auto" = per-kind from the committed yardsticks
    #: (:func:`auto_fused`) at execution time
    fused: object = False

    def resolve_fused(self, kind: str, k_visits: int = 64,
                      dmax: Optional[int] = None) -> bool:
        """The concrete visit body for one kind under this plan."""
        if self.fused == "auto":
            return auto_fused(kind, k_visits, dmax=dmax)
        return bool(self.fused)

    def working_set_bytes(self) -> int:
        if self.fused:
            return self.mem.fused_working_set(
                self.block_size, self.num_queries, num_planes=2,
                dmax=FUSED_DMAX_BUDGET)
        return self.mem.working_set(self.block_size, self.num_queries)


def default_method(g: CSRGraph) -> str:
    """Paper §7.1: METIS-like clustering for road/web graphs, random for
    power-law social graphs (where clustering quality collapses)."""
    deg = g.out_degree()
    mean = max(1.0, float(deg.mean()))
    if float(deg.max()) > 64.0 * mean:      # heavy-tailed hub structure
        return "random"
    return "bfs"


def est_dmax(g: CSRGraph, block_size: int) -> int:
    """Pessimistic neighbor-slot estimate for one partition of size B.

    Skewed (real SNAP-style) graphs concentrate edges on a few hubs; if
    the ``B`` heaviest vertices land in one partition, their combined
    out-edges reach at best ``ceil(sum(top-B degrees) / B)`` distinct
    partitions — the floor on that partition's boundary-block count.
    Clamped to ``P - 1`` (a partition cannot neighbor more partitions than
    exist).  This is a planning estimate from the degree sequence alone,
    usable before any partitioning has run.
    """
    if g.n == 0:
        return 0
    deg = np.sort(g.out_degree())[::-1]
    top = float(deg[: int(block_size)].sum())
    num_parts = -(-g.n // int(block_size))
    return int(min(max(num_parts - 1, 0),
                   np.ceil(top / max(float(block_size), 1.0))))


def model_block_size(g: CSRGraph, num_queries: int, mem: MemoryModel,
                     candidates: Sequence[int] = CANDIDATE_BLOCK_SIZES,
                     min_parts: int = 8, fused: bool = False,
                     degree_aware: bool = True) -> int:
    """Largest candidate whose visit working set fits the memory model.

    Also keeps at least ``min_parts`` partitions alive (clamped to what the
    graph can support): with too few partitions there is nothing for the
    scheduler to choose between and buffered consolidation degenerates —
    the "smaller multiplies scheduling overhead, larger thrashes" U-shape
    of Fig. 16 has a scheduling wall on the right, not just a cache wall.

    ``degree_aware=True`` adds the skew guard for real ingested graphs:
    each candidate must also keep one visit's *neighborhood* — the diagonal
    block plus :func:`est_dmax` boundary blocks streamed against it —
    inside the VMEM budget.  On uniform-degree graphs the estimate is tiny
    and the guard never binds; on hub-heavy graphs it pushes the plan to a
    smaller B so heavy vertices split across more, smaller boundary blocks
    instead of dragging a mega-neighborhood through the cache every visit.
    """
    best = None
    for b in candidates:
        if -(-g.n // b) < max(2, min(min_parts, g.n // candidates[0])):
            break
        if degree_aware:
            hood = (1 + est_dmax(g, b)) * b * b * mem.dtype_bytes
            if hood > mem.vmem_bytes:
                continue   # hub neighborhoods outgrow VMEM at this B
        if mem.fits(b, num_queries, g.n, fused=fused):
            best = b
    if best is None:
        raise ValueError(
            f"no candidate block size fits the memory model for "
            f"Q={num_queries} (smallest candidate {candidates[0]} needs "
            f"{mem.working_set(candidates[0], num_queries)} B of "
            f"{mem.vmem_bytes} B VMEM); shrink the query batch or raise "
            f"the budget")
    return best


def measure_run(session, kind: str, sources: np.ndarray,
                **overrides) -> dict:
    """Run one configuration through the session and report the sweep row.

    The reusable measurement unit behind ``autotune_block_size`` and the
    benchmark sweeps (table4 policies/thresholds, fig16 block sizes).
    Partitioning is warmed outside the timed window — it is a one-time
    per-graph cost, not part of the execution being compared.  The engine
    backend runs its K-visit megastep loop here like everywhere else, so
    the measured candidates see the real O(visits/K) dispatch cost
    (``host_syncs`` is recorded per row; benchmarks/bench_dispatch.py
    sweeps K itself).
    """
    from repro.core.queries import WEIGHT_VARIANTS
    session.prepared(block_size=overrides.get("block_size"),
                     method=overrides.get("method"),
                     weights=WEIGHT_VARIANTS.get(kind, "natural"))
    t0 = time.perf_counter()
    res = session.run(kind, sources, **overrides)
    secs = time.perf_counter() - t0
    return {
        "runtime_s": secs,
        "visits": res.stats.get("visits", 0),
        "host_syncs": res.stats.get("host_syncs", 0),
        "traffic_bytes": res.stats.get("modeled_bytes", 0.0),
        "edges_per_q": float(np.mean(res.edges_processed)),
    }


def autotune_block_size(session, kind: str, sources: np.ndarray,
                        mem: MemoryModel,
                        candidates: Sequence[int] = CANDIDATE_BLOCK_SIZES,
                        objective: str = "traffic_bytes",
                        num_queries: Optional[int] = None):
    """Measure each memory-feasible candidate; return (best_B, rows).

    Objective defaults to modeled HBM->VMEM traffic — deterministic across
    machines, and the paper's Fig. 16 shows it tracks the runtime U-shape
    (visits x bytes-per-visit).  Ties break toward measured runtime.

    Feasibility is judged at ``num_queries`` (the plan's real batch width),
    while measurement runs on the (smaller) ``sources`` sample.
    """
    g = session.graph
    nq = num_queries if num_queries is not None else len(sources)
    feasible = [b for b in candidates
                if b < max(2, g.n) and mem.fits(b, nq, g.n)]
    if not feasible:
        raise ValueError(
            f"no candidate block size fits the memory model for Q={nq}; "
            f"shrink the query batch or raise the budget")
    rows = []
    for b in feasible:
        row = measure_run(session, kind, sources, block_size=b)
        row["block_size"] = b
        rows.append(row)
    best = min(rows, key=lambda r: (r[objective], r["runtime_s"]))
    return int(best["block_size"]), rows


#: default serving result-cache budget, in units of one single-lane HBM
#: plane set (``MemoryModel.state_bytes`` at Q=1).  One cached entry costs
#: roughly a third of a plane set (values [n] f32; ppr adds a residual
#: plane), so 16 plane sets hold on the order of 25-50 hot answers — wide
#: enough to cover a Zipf head, small next to the executor state itself.
RESULT_CACHE_PLANE_SETS = 16


def result_cache_budget(mem: MemoryModel, n_vertices: int, block_size: int,
                        plane_sets: int = RESULT_CACHE_PLANE_SETS) -> int:
    """Byte budget for the serving result cache (DESIGN.md §4.2).

    Priced by the same §3.1 memory model that sizes everything else: a
    small multiple (:data:`RESULT_CACHE_PLANE_SETS`) of one query lane's
    padded HBM plane set for this graph.  ``GraphServer`` takes the max
    over its registered graphs, so the cache scales with the largest
    graph being served rather than a hardcoded byte count; an explicit
    ``GraphServer(cache_bytes=...)`` replaces this default entirely.
    """
    return int(plane_sets) * mem.state_bytes(int(n_vertices), 1,
                                             int(block_size))


def autoscale_capacity(queue_depth: int, active: int, *,
                       mem: MemoryModel, n_vertices: int, block_size: int,
                       min_capacity: int = 1,
                       max_capacity: int = 1024) -> int:
    """Suggest a lane-pool ``capacity`` from observed queue pressure.

    The serving autoscaling hint (DESIGN.md §4.2): demand is what is
    in flight plus what is waiting; the suggestion is the next power of two
    covering it (powers of two keep the set of jitted engine shapes
    logarithmic in demand), clamped to ``[min_capacity, max_capacity]`` and
    then shrunk until the §3.1 memory model accepts the visit working set
    and the HBM state planes at the pool's block size.  Pure function of
    its inputs — GraphServer calls it between chunks and applies a changed
    suggestion only when the pool is idle, so resizing never moves an
    in-flight lane.
    """
    cap = pow2_bucket(int(queue_depth) + int(active),
                      min_capacity=min_capacity, max_capacity=max_capacity)
    while cap > min_capacity and not mem.fits(block_size, cap, n_vertices):
        cap //= 2
    return int(cap)


def make_plan(g: CSRGraph, num_queries: int, *,
              mem: Optional[MemoryModel] = None,
              block_size: Optional[int] = None,
              method: Optional[str] = None,
              schedule: str = "priority",
              backend: str = "engine",
              yield_config: Optional[YieldConfig] = None,
              fused: object = False,
              degree_aware: bool = True) -> Plan:
    """Resolve a plan without measuring (the model-only path).

    ``FPPSession.plan(tune=True)`` upgrades the block size by measurement.
    ``fused="auto"`` defers the visit-body choice to the per-kind
    yardsticks (:func:`auto_fused`); block sizing then budgets the fused
    working set, the conservative bound, since some kinds may fuse.
    ``degree_aware=False`` disables the hub-skew VMEM guard in
    :func:`model_block_size` (ignored when ``block_size`` is explicit).
    """
    mem = mem or MemoryModel()
    if fused not in (True, False, "auto"):
        raise ValueError(f"fused must be True, False, or 'auto', "
                         f"got {fused!r}")
    if block_size is None:
        block_size = model_block_size(g, num_queries, mem, fused=bool(fused),
                                      degree_aware=degree_aware)
    method = method or default_method(g)
    return Plan(block_size=int(block_size), method=method, schedule=schedule,
                backend=backend, num_queries=int(num_queries), mem=mem,
                yield_config=yield_config,
                fused=(fused if fused == "auto" else bool(fused)))


def default_yield_config(kind: str, bg) -> YieldConfig:
    """Per-query-kind yield defaults (paper Table 4 settings)."""
    if kind == "bfs":
        return YieldConfig(delta=1.0)          # Δ=1 == level-synchronous
    if kind == "ppr":
        return YieldConfig(mu_factor=100.0)    # paper's NCP setting
    if kind in ("cc", "kreach", "rw"):
        # these kinds run transformed weights (zero / hop-shifted) or no
        # weights at all, so a Δ-window derived from the block values would
        # be the wrong scale (0 for cc, the hop stride for kreach) — run
        # the full-window fixpoint instead
        return YieldConfig()
    wmax = float(np.nanmax(np.where(np.isfinite(bg.blocks), bg.blocks,
                                    np.nan)))
    return YieldConfig(delta=default_delta(wmax))
