"""Streaming FPP execution: queries that arrive over time (DESIGN.md §3.3).

``examples/serve_batched.py``'s ContinuousBatcher keeps an LM decode batch
full by refilling finished slots between decode steps.  This module is the
same idea for graph queries: the engine state carries ``capacity`` query
lanes, and between partition visits the executor

  * **admits** queued queries into free lanes by injecting their source op
    into the partition buffer (exactly how a one-shot run initializes, so
    late arrivals are indistinguishable from early ones),
  * **harvests** lanes whose queries have no pending buffered op anywhere
    (queries are independent, so per-lane completion is exact), records
    their values, and recycles the lane.

The visits between those boundaries run as device-resident K-visit
*megasteps* (``core/visit.make_megastep``): partition selection happens on
device and the host is consulted once per chunk, not once per visit.
Admission and harvest move to chunk boundaries — which the DESIGN.md §3.3
exactness argument already permits: admission only adds ops a one-shot run
would have started with, and harvesting later never changes a finished
lane's values, so chunking delays *when* lanes recycle, never *what* a
query answers.

Everything mode-specific — what a buffered op means, when a lane is pending,
what a partition's priority is — comes from the engine's ``core/visit.py``
algebra, so minplus (sssp/bfs) and push (ppr) lanes stream through the same
loop.  Because yielding/scheduling never change results (paper §5.1) and
admission only adds ops a one-shot run would have started with, a staggered
streaming run returns bit-identical minplus answers to the one-shot run of
the union, and push answers within the same eps tolerance the one-shot run
carries — ``tests/test_fpp_session.py`` pins both properties.

Concurrency contract (DESIGN.md §4.2): every public entry point —
``submit``, ``step``, ``pump``, ``run``, ``take_finished`` — serializes on
one executor lock, and ``pump`` holds it for whole chunks, so a submitter
on another thread joins exactly at a megastep chunk boundary: the only
point where touching lanes was ever legal.  Thread safety here is the same
rule as exactness, enforced by a lock instead of an argument.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import visit as _visit
from repro.core.engine import FPPEngine
from repro.core.scheduler import PartitionScheduler
from repro.core.yielding import YieldConfig
from repro.fpp import planner as _planner


def build_stream_engine(session, kind: str, capacity: int, *,
                        schedule: str = "priority",
                        yield_config: Optional[YieldConfig] = None,
                        alpha: float = 0.15, eps: float = 1e-4,
                        seed: int = 0, k_visits: int = 64,
                        fused: bool = False,
                        k: int = 8) -> Tuple[FPPEngine, object,
                                             np.ndarray]:
    """(engine, bg, perm) exactly as a :class:`StreamingExecutor` for the
    same arguments would build them.

    The one construction path shared by the executor and the serving
    compile cache (``serve/compile_cache.py``): a megastep AOT-compiled
    from this engine is interchangeable with the one the executor would
    trace itself, because the graph staging (``session.prepared`` is
    cached per session), yield config, algebra parameters, and chunk size
    all come from here.  ``k`` is the kreach hop budget (ignored by other
    kinds); the stride comes from the session so the shift variant and the
    decode can never disagree.
    """
    from repro.core.queries import WEIGHT_VARIANTS
    from repro.fpp.backends import _ENGINE_MODE
    bg, perm = session.prepared(weights=WEIGHT_VARIANTS.get(kind, "natural"))
    yc = (yield_config if yield_config is not None
          else _planner.default_yield_config(kind, bg))
    engine = FPPEngine(bg, mode=_ENGINE_MODE[kind],
                       num_queries=int(capacity),
                       yield_config=yc, schedule=schedule, alpha=alpha,
                       eps=eps, seed=seed, k_visits=int(k_visits),
                       fused=bool(fused), hop_budget=int(k),
                       hop_stride=(session.kreach_stride
                                   if kind == "kreach" else 1.0))
    return engine, bg, perm


def build_stream_megastep(engine: FPPEngine, schedule: str) -> Callable:
    """The streaming pump's megastep for ``engine``: the §2.3 K-visit chunk
    with the [Q] pending-lane harvest mask folded into the same dispatch
    (``harvest_mask=True``) — what ``pump`` runs and what the serving
    compile cache warms ahead of time."""
    return _visit.make_megastep(
        engine.dg, engine.algebra, engine.max_rounds, policy=schedule,
        K=engine.k_visits, harvest_mask=True, fused=engine.fused,
        frontier_mode=engine.frontier_mode)


@dataclasses.dataclass
class StreamQuery:
    """One admitted-or-queued query and, eventually, its answer.

    The ``*_visit`` fields are snapshots of the executor-global visit
    counter (queue wait = admitted - submitted, in-flight latency =
    finished - admitted, both in visits the whole executor ran); the
    ``*_sync`` fields snapshot ``host_syncs`` the same way, so a serving
    layer can bill exact per-request host round trips (DESIGN.md §4.2)."""
    qid: int
    source: int                 # original vertex id
    slot: int = -1
    submitted_visit: int = -1
    admitted_visit: int = -1
    finished_visit: int = -1
    admitted_sync: int = -1
    finished_sync: int = -1
    values: Optional[np.ndarray] = None      # [n] original ids, on completion
    residual: Optional[np.ndarray] = None    # push kinds
    edges: float = 0.0
    done: bool = False


class StreamingExecutor:
    """Admission queue + slot-recycling loop over the buffered engine.

    Mirrors serve/engine.py's ContinuousBatcher (DESIGN.md §4.1): ``submit``
    enqueues work, ``step`` runs one partition visit (admitting and
    harvesting around it), ``run`` drains everything submitted so far.
    ``pump(n)`` advances a bounded number of visits so callers can
    interleave arrivals.  ``serve/graph_server.py`` (DESIGN.md §4.2) stacks
    multi-tenant admission on top of this loop.

    ``k_visits`` is the device-resident chunk size: ``pump``/``run``
    dispatch megasteps of up to that many visits, and admission/harvest
    only happen at those chunk boundaries, so K is simultaneously the
    host-sync amortization factor and the lane-recycling latency.  The
    executor builds its megastep with ``harvest_mask=True`` so the [Q]
    pending-lane mask rides back in the same host sync as the chunk stats —
    harvesting costs no extra dispatch (core/visit.make_megastep).
    """

    def __init__(self, session, kind: str = "sssp", capacity: int = 16, *,
                 schedule: str = "priority",
                 yield_config: Optional[YieldConfig] = None,
                 alpha: float = 0.15, eps: float = 1e-4,
                 harvest_every: int = 1, seed: int = 0,
                 k_visits: int = 64, fused: bool = False,
                 megastep: Optional[Callable] = None, k: int = 8):
        if kind not in ("sssp", "bfs", "ppr", "cc", "kreach"):
            raise ValueError(f"streaming supports sssp/bfs/ppr/cc/kreach "
                             f"(rw streams via WalkExecutor), got {kind!r}")
        self.session = session
        self.kind = kind
        self.capacity = int(capacity)
        self.alpha, self.eps = alpha, eps
        self.k = int(k)
        # per-visit cadence of the legacy step() path; pump()/run() harvest
        # at megastep chunk boundaries instead
        self.harvest_every = max(1, int(harvest_every))
        self.engine, bg, perm = build_stream_engine(
            session, kind, self.capacity, schedule=schedule,
            yield_config=yield_config, alpha=alpha, eps=eps, seed=seed,
            k_visits=k_visits, fused=fused, k=k)
        self.bg, self.perm = bg, perm
        self.mode = self.engine.mode
        # own megastep with the pending-lane harvest mask folded into the
        # chunk dispatch (the engine's plain-run megastep skips it).  A
        # caller may inject a warm one (``megastep=``) — the serving
        # compile cache hands over programs AOT-compiled from an engine
        # built by the same :func:`build_stream_engine` call, so the
        # injected executable is the one this executor would have traced.
        self._megastep = (megastep if megastep is not None
                          else build_stream_megastep(self.engine, schedule))
        self.algebra = self.engine.algebra
        # serializes submit/step/pump/run/take_finished: a foreign-thread
        # submit lands exactly at a chunk boundary (module docstring)
        self._lock = threading.RLock()
        self.finished: collections.deque = collections.deque()
        self.scheduler = PartitionScheduler(schedule, bg.num_parts, seed)
        self.state = self._empty_state()
        self.queue: collections.deque = collections.deque()
        self.queries: Dict[int, StreamQuery] = {}
        self.free_slots: List[int] = list(range(self.capacity))
        self.slot_qid = np.full(self.capacity, -1, dtype=np.int64)
        self.visits = 0
        self.modeled_bytes = 0.0
        self.host_syncs = 0
        self._key = jax.random.PRNGKey(seed)
        self._lane_pending: Optional[np.ndarray] = None  # set by _chunk
        self._drained = False                            # set by _chunk
        self._next_qid = 0
        # per-lane edge counts: exact int32 per visit, float64 on host
        self._edges = np.zeros(self.capacity, dtype=np.float64)
        alg, deg = self.algebra, self.engine.dg.deg
        self._pending_q = jax.jit(lambda planes, buf: jnp.any(
            alg.pending(buf[:-1], planes, deg), axis=(0, 2)))
        self._prio_row = jax.jit(alg.prio_of)
        if self.mode == "cc":
            # cc admission buffers the whole label plane (every partition),
            # so the priority refresh runs vmapped over all rows at once
            self._cc_plane = jnp.asarray(_visit.cc_label_plane(bg))
            self._prio_all = jax.jit(jax.vmap(alg.prio_of))

    # ----------------------------------------------------------- lifecycle

    def _empty_state(self) -> _visit.VisitState:
        return _visit.init_engine_state(
            self.algebra, self.engine.dg,
            np.empty(0, dtype=np.int64), num_queries=self.capacity)

    def submit(self, sources: np.ndarray) -> List[int]:
        """Enqueue a batch of sources (original ids); returns their qids.

        Thread-safe: a submit racing a ``pump`` on another thread blocks
        until the in-flight chunk's boundary and is admitted there —
        indistinguishable from having arrived between chunks."""
        with self._lock:
            qids = []
            for s in np.atleast_1d(np.asarray(sources)):
                q = StreamQuery(qid=self._next_qid, source=int(s),
                                submitted_visit=self.visits)
                self._next_qid += 1
                self.queries[q.qid] = q
                self.queue.append(q.qid)
                qids.append(q.qid)
            self._admit()
            return qids

    # ----------------------------------------------------------- admission

    def _inject_plane(self, q: StreamQuery, slot: int):
        """cc admission: a cc lane's init is the whole label plane, not one
        source op — buffer it across every partition exactly as the
        one-shot run's ``init_ops`` does (the source only names the lane),
        then refresh every partition's priority row in one vmapped
        dispatch.  Late cc arrivals therefore converge to the identical
        labels a one-shot lane computes: same initial buffer, same
        fixpoint."""
        st = self.state
        P = self.bg.num_parts
        buf = st.buf.at[:P, slot, :].set(self.algebra.combine(
            st.buf[:P, slot, :], self._cc_plane))
        newprio, newops = self._prio_all(buf[:P], st.planes,
                                         self.engine.dg.deg)
        came_alive = (~np.isfinite(np.asarray(st.prio))
                      & np.isfinite(np.asarray(newprio)))
        stamp = jnp.where(jnp.asarray(came_alive), jnp.int32(self.visits),
                          st.stamp)
        self.state = st._replace(buf=buf, prio=jnp.asarray(newprio),
                                 ops_count=jnp.asarray(newops), stamp=stamp)
        q.slot = slot
        q.admitted_visit = self.visits
        q.admitted_sync = self.host_syncs
        self.slot_qid[slot] = q.qid

    def _inject(self, q: StreamQuery, slot: int):
        """Buffer the query's source op — identical to one-shot init, so the
        scheduler sees a late arrival as just another pending partition."""
        if self.mode == "cc":
            self._inject_plane(q, slot)
            return
        B = self.engine.dg.block_size
        src = int(self.perm[q.source])
        pv, lv = divmod(src, B)
        st = self.state
        was_empty = not np.isfinite(float(np.asarray(st.prio[pv])))
        buf = st.buf.at[pv, slot, lv].set(self.algebra.combine(
            st.buf[pv, slot, lv], jnp.float32(self.algebra.source_value)))
        planes_row = tuple(x[pv] for x in st.planes)
        newprio, newops = self._prio_row(buf[pv], planes_row,
                                         self.engine.dg.deg[pv])
        prio = st.prio.at[pv].set(newprio)
        ops = st.ops_count.at[pv].set(newops)
        stamp = st.stamp
        if was_empty and np.isfinite(float(np.asarray(newprio))):
            stamp = stamp.at[pv].set(jnp.int32(self.visits))
        self.state = st._replace(buf=buf, prio=prio, ops_count=ops,
                                 stamp=stamp)
        q.slot = slot
        q.admitted_visit = self.visits
        q.admitted_sync = self.host_syncs
        self.slot_qid[slot] = q.qid

    def _admit(self):
        while self.free_slots and self.queue:
            qid = self.queue.popleft()
            self._inject(self.queries[qid], self.free_slots.pop(0))

    # ------------------------------------------------------------- harvest

    def _reset_slot(self, slot: int):
        st = self.state
        planes = tuple(x.at[:, slot, :].set(v)
                       for x, v in zip(st.planes, self.algebra.plane_init))
        buf = st.buf.at[:, slot, :].set(self.algebra.identity)
        self.state = st._replace(planes=planes, buf=buf)
        self._edges[slot] = 0.0

    def _harvest(self, pending: Optional[np.ndarray] = None):
        """Finish every active lane with no pending op anywhere.

        ``pending`` is the [capacity] bool lane mask when the caller already
        has one (the megastep harvests it in the same dispatch as the chunk
        stats); without it a dedicated ``_pending_q`` dispatch runs — the
        legacy ``step()`` cadence."""
        active = self.slot_qid >= 0
        if not active.any():
            return
        st = self.state
        if pending is None:
            self.host_syncs += 1
            pending = np.asarray(self._pending_q(st.planes, st.buf))
        n = self.bg.n
        for slot in np.flatnonzero(active & ~pending):
            q = self.queries[int(self.slot_qid[slot])]
            vals = np.asarray(st.planes[0][:, slot, :]).reshape(-1)[:n]
            if self.mode == "push":
                rfull = (np.asarray(st.planes[1][:, slot, :])
                         + np.asarray(st.buf[:-1, slot, :])).reshape(-1)[:n]
                q.residual = rfull[self.perm].astype(np.float32)
            if self.mode == "kreach":
                # unpack the lexicographic (hops, dist) fixpoint with the
                # engine's stride/budget — elementwise, so decode-then-perm
                # equals perm-then-decode
                from repro.core.oracles import decode_kreach
                dv, dh = decode_kreach(vals[None, :], self.engine.hop_stride,
                                       self.engine.hop_budget)
                q.values = dv[0][self.perm].astype(np.float32)
                q.residual = dh[0][self.perm].astype(np.float32)
            elif self.mode == "cc":
                # raw reordered-rep labels -> canonical min-original-id
                # labels, after the perm mapping (same order as session.run)
                from repro.fpp.backends import canonicalize_cc
                q.values = canonicalize_cc(
                    vals[self.perm][None, :])[0]
            else:
                q.values = vals[self.perm].astype(np.float32)
            q.edges = float(self._edges[slot])
            q.finished_visit = self.visits
            q.finished_sync = self.host_syncs
            q.done = True
            self.finished.append(q.qid)
            self.slot_qid[slot] = -1
            self._reset_slot(int(slot))
            self.free_slots.append(int(slot))

    # ---------------------------------------------------------------- loop

    @property
    def active(self) -> int:
        return int((self.slot_qid >= 0).sum())

    @property
    def queue_depth(self) -> int:
        """Submitted-but-not-yet-admitted queries (free-lane starvation
        signal; GraphServer's autoscaling hint reads it)."""
        return len(self.queue)

    def take_finished(self) -> List[int]:
        """Drain the finished-lane queue: qids harvested since the last
        call, in completion order.  The serving delivery lane consumes
        this instead of scanning every query for ``done`` — and because
        ``_harvest`` appends under the executor lock while delivery pops
        here, a response is never observed half-built."""
        with self._lock:
            out = list(self.finished)
            self.finished.clear()
            return out

    def step(self) -> bool:
        """One partition visit (admit before, harvest after).  False when
        nothing is pending anywhere — all admitted queries are complete."""
        with self._lock:
            return self._step_locked()

    def _step_locked(self) -> bool:
        self._admit()
        st = self.state
        p = self.scheduler.select(np.asarray(st.prio), np.asarray(st.stamp),
                                  np.asarray(st.ops_count))
        if p is None:
            self._harvest()
            self._admit()
            return bool(self.queue) or self.active > 0
        self.state, (_, eq) = self.engine._visit(self.state, jnp.int32(p),
                                                 jnp.int32(self.visits))
        self._edges += np.asarray(eq, dtype=np.float64)
        self.visits += 1
        self.modeled_bytes += float(self.engine._visit_bytes[p])
        if self.visits % self.harvest_every == 0:
            self._harvest()
        return True

    def _chunk(self, limit: int) -> int:
        """One megastep dispatch of up to ``min(limit, K)`` visits; chunk
        stats AND the pending-lane harvest mask come back in that single
        host sync.  Returns visits executed."""
        limit = min(int(limit), self.engine.k_visits)
        if limit <= 0:
            self._lane_pending = None   # a stale mask must never be harvested
            return 0
        st, ms = self._megastep(self.state, jnp.int32(self.visits),
                                jnp.int32(limit), self._key)
        self.host_syncs += 1
        v = int(ms.visits)
        # the mask reflects the chunk-end state even when v == 0 (megastep
        # recomputes it from the unchanged input state); a chunk that stops
        # below its limit proves the device is drained — no confirmation
        # dispatch needed
        self._lane_pending = np.asarray(ms.lane_pending)
        self._drained = v < limit
        if v == 0:
            return 0
        self.state = st
        self._key = ms.key
        self._edges += _visit.harvest_edges(ms.eq_hi, ms.eq_lo)
        counts = np.asarray(ms.visit_counts, dtype=np.int64)
        self.modeled_bytes += float(counts @ self.engine._visit_bytes)
        self.visits += v
        return v

    def pump(self, max_visits: int) -> int:
        """Advance up to ``max_visits`` visits in device-resident chunks of
        up to the engine's K; admission and harvest happen at the chunk
        boundaries (DESIGN.md §3.3).  Returns visits executed.

        Holds the executor lock per chunk, releasing it at every chunk
        boundary — exactly where foreign-thread submits are allowed in."""
        start = self.visits
        while True:
            with self._lock:
                if self.visits - start >= max_visits:
                    break
                self._admit()
                did = self._chunk(max_visits - (self.visits - start))
                self._harvest(pending=self._lane_pending)
                if did == 0 or self._drained:
                    # nothing left pending on device: every unfinished lane
                    # was just harvested; refill from the queue or stop
                    self._admit()
                    if not self.queue and self.active == 0:
                        break
        return self.visits - start

    def run(self, max_visits: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drain queue + lanes; returns {qid: values} (original ids)."""
        budget = max_visits or 2000 * self.bg.num_parts
        while (self.queue or self.active) and self.visits < budget:
            if self.pump(budget - self.visits) == 0:
                break
        with self._lock:
            self._harvest()
            return {qid: q.values
                    for qid, q in self.queries.items() if q.done}

    def result(self, qid: int) -> StreamQuery:
        return self.queries[qid]


class WalkExecutor:
    """Slot-recycling random-walk lanes: the :class:`StreamingExecutor`
    surface (submit / pump / run / take_finished / result) over the
    buffered walker loop (core/randomwalk.py).

    A lane holds one walker; free lanes park with ``steps = length`` so the
    jitted visit's liveness mask skips them.  Because the rw tape is keyed
    by (source, step) — never by lane, batch, or visit order — a walker
    admitted into a recycled slot mid-stream draws exactly the trajectory
    the one-shot ``session.run("rw", ...)`` would, so served occupancy rows
    are bitwise the session's.  ``length`` and ``seed`` are executor-wide
    (like ``alpha``/``eps`` on the push lanes): they parameterize the
    compiled visit, so requests wanting different values belong on a
    different executor.

    Completion is per-lane exact (``steps >= length``), values are
    occupancy counts [n] in original ids (start + each step), and
    ``edges`` bills the steps actually taken — the same contract
    ``backends.run_query("rw")`` returns.  Thread-safety matches
    StreamingExecutor: one lock, foreign submits join at visit boundaries.
    """

    def __init__(self, session, capacity: int = 16, *, length: int = 32,
                 seed: int = 0, k_visits: int = 64, visit=None):
        from repro.core.engine import DeviceGraph
        from repro.core.randomwalk import make_walk_visit
        from repro.core.yielding import NO_YIELD
        self.session = session
        self.kind = "rw"
        self.capacity = int(capacity)
        self.length, self.seed = int(length), int(seed)
        self.k_visits = int(k_visits)
        bg, perm = session.prepared()
        self.bg, self.perm = bg, perm
        self.dg = DeviceGraph.build(bg, NO_YIELD, self.capacity)
        # ``visit`` injects a warm AOT-compiled walk visit
        # (serve/compile_cache.build_warm_megastep kind="rw") — same
        # function of the same graph constants, so injection never changes
        # a trajectory
        self._visit = (visit if visit is not None
                       else make_walk_visit(self.dg, self.length, self.seed))
        B = self.dg.block_size
        # one visit streams the resident diagonal block plus every boundary
        # block against it — the same neighborhood the planner budgets
        self._visit_bytes = float(
            (1 + self.dg.nbr_blk.shape[1]) * B * B * 4)
        Q, n_pad = self.capacity, self.dg.num_parts * B
        self._pos = jnp.zeros(Q, jnp.int32)
        self._steps = jnp.full(Q, self.length, jnp.int32)   # parked
        self._part = jnp.zeros(Q, jnp.int32)
        self._src = jnp.zeros(Q, jnp.int32)
        self._thash = jnp.zeros(Q, jnp.uint32)
        self._occ = jnp.zeros((Q, n_pad), jnp.float32)
        self._lock = threading.RLock()
        self.finished: collections.deque = collections.deque()
        self.queue: collections.deque = collections.deque()
        self.queries: Dict[int, StreamQuery] = {}
        self.free_slots: List[int] = list(range(self.capacity))
        self.slot_qid = np.full(self.capacity, -1, dtype=np.int64)
        self.visits = 0
        self.modeled_bytes = 0.0
        self.host_syncs = 0
        self._next_qid = 0

    # ----------------------------------------------------------- admission

    def submit(self, sources: np.ndarray) -> List[int]:
        """Enqueue walk sources (original ids); returns their qids."""
        with self._lock:
            qids = []
            for s in np.atleast_1d(np.asarray(sources)):
                q = StreamQuery(qid=self._next_qid, source=int(s),
                                submitted_visit=self.visits)
                self._next_qid += 1
                self.queries[q.qid] = q
                self.queue.append(q.qid)
                qids.append(q.qid)
            self._admit()
            return qids

    def _admit(self):
        B = self.dg.block_size
        while self.free_slots and self.queue:
            qid = self.queue.popleft()
            slot = self.free_slots.pop(0)
            q = self.queries[qid]
            src = int(self.perm[q.source])
            # identical to randomwalk.init_walk_state, per lane
            self._pos = self._pos.at[slot].set(src)
            self._steps = self._steps.at[slot].set(0)
            self._part = self._part.at[slot].set(src // B)
            self._src = self._src.at[slot].set(src)
            self._thash = self._thash.at[slot].set(jnp.uint32(src))
            self._occ = self._occ.at[slot].set(0.0).at[slot, src].set(1.0)
            q.slot = slot
            q.admitted_visit = self.visits
            q.admitted_sync = self.host_syncs
            self.slot_qid[slot] = q.qid

    # ------------------------------------------------------------- harvest

    def _harvest(self):
        active = self.slot_qid >= 0
        if not active.any():
            return
        self.host_syncs += 1
        steps = np.asarray(self._steps)
        done = active & (steps >= self.length)
        if not done.any():
            return
        occ = np.asarray(self._occ)
        n = self.bg.n
        for slot in np.flatnonzero(done):
            q = self.queries[int(self.slot_qid[slot])]
            q.values = occ[slot, :n][self.perm].astype(np.float32)
            q.edges = float(steps[slot])
            q.finished_visit = self.visits
            q.finished_sync = self.host_syncs
            q.done = True
            self.finished.append(q.qid)
            self.slot_qid[slot] = -1
            self.free_slots.append(int(slot))
            # park the lane; its occupancy row resets at the next admit
            self._steps = self._steps.at[int(slot)].set(self.length)

    # ---------------------------------------------------------------- loop

    @property
    def active(self) -> int:
        return int((self.slot_qid >= 0).sum())

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def take_finished(self) -> List[int]:
        with self._lock:
            out = list(self.finished)
            self.finished.clear()
            return out

    def pump(self, max_visits: int) -> int:
        """Advance up to ``max_visits`` buffered walk visits, admitting and
        harvesting around each (walk scheduling reads walker residency from
        the host every visit, so per-visit boundaries cost no extra sync).
        Returns visits executed."""
        start = self.visits
        while True:
            with self._lock:
                if self.visits - start >= int(max_visits):
                    break
                self._admit()
                self.host_syncs += 1
                steps = np.asarray(self._steps)
                part = np.asarray(self._part)
                live = (self.slot_qid >= 0) & (steps < self.length)
                if not live.any():
                    self._harvest()
                    self._admit()
                    if not self.queue and self.active == 0:
                        break
                    continue    # freshly admitted (or length-0) lanes
                # max-ops scheduling: the partition with most live walkers
                counts = np.bincount(part[live],
                                     minlength=self.dg.num_parts)
                p = int(np.argmax(counts))
                (self._pos, self._steps, self._part, self._thash,
                 self._occ) = self._visit(self._pos, self._steps,
                                          self._part, self._src,
                                          self._thash, self._occ,
                                          jnp.int32(p))
                self.visits += 1
                self.modeled_bytes += self._visit_bytes
                self._harvest()
        return self.visits - start

    def run(self, max_visits: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drain queue + lanes; returns {qid: occupancy} (original ids)."""
        budget = max_visits or 2000 * self.bg.num_parts
        while (self.queue or self.active) and self.visits < budget:
            if self.pump(budget - self.visits) == 0:
                break
        with self._lock:
            self._harvest()
            return {qid: q.values
                    for qid, q in self.queries.items() if q.done}

    def result(self, qid: int) -> StreamQuery:
        return self.queries[qid]
