"""Production meshes.

A function, not a module-level constant: importing this module never
touches jax device state (contract requirement — device count is locked at
first jax init, and only launch/dryrun.py sets the 512-device flag).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake or real) devices exist —
    used by tests and CPU examples."""
    n = len(jax.devices())
    if data * model > n:
        data, model = 1, 1
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def chips(mesh) -> int:
    return int(mesh.devices.size)
