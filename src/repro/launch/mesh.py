"""Production meshes (+ the jax-version compat shims every caller shares).

A function, not a module-level constant: importing this module never
touches jax device state (contract requirement — device count is locked at
first jax init, and only launch/dryrun.py sets the 512-device flag).

Compat: jax >= 0.5/0.6 grew ``jax.sharding.AxisType`` / the ``axis_types=``
kwarg and ``jax.set_mesh``; on 0.4.x the equivalents are the default
(auto) axis behaviour and the ``with mesh:`` resource-env context.
``compat_make_mesh`` / ``set_mesh`` paper over the difference so drivers
and test scripts run on both.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:                     # jax 0.4.x: Auto is the only mode
    AxisType = None
    _AXIS_KW = lambda n: {}             # noqa: E731


def compat_make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the kwarg exists."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_AXIS_KW(len(shape)))


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient for bare-PartitionSpec use:
    ``jax.set_mesh`` on new jax, the mesh resource-env context on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh                         # Mesh is itself a context manager


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake or real) devices exist —
    used by tests and CPU examples."""
    n = len(jax.devices())
    if data * model > n:
        data, model = 1, 1
    return compat_make_mesh((data, model), ("data", "model"))


def chips(mesh) -> int:
    return int(mesh.devices.size)
