"""End-to-end training driver.

On a real pod this runs under the production mesh; on this container it
drives the same code path on the host devices with a reduced config:

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-7b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Restores from the newest checkpoint automatically (kill it and rerun to
see fault tolerance; tests do this programmatically).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.base import get_config
from repro.configs.shapes import ShapeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import rules_for
from repro.models.factory import build_model
from repro.train.data import batch_for_step
from repro.train.loop import LoopConfig, run_loop
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", action="store_true",
                    help="int8 gradient compression with error feedback")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, microbatches=args.microbatches)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    rules = rules_for(cfg, mesh) if mesh.devices.size > 1 else None

    model = build_model(cfg)
    opt = AdamW()
    lr = warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps)
    state = init_train_state(model, jax.random.PRNGKey(0), opt,
                             compression=args.compression)
    step_fn = jax.jit(make_train_step(
        model, opt, lr, rules=rules, microbatches=args.microbatches,
        compression=args.compression), donate_argnums=0)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}) "
          f"{n_params / 1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")

    lc = LoopConfig(n_steps=args.steps, ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir, log_every=10)
    state, stats = run_loop(step_fn, state,
                            lambda s: batch_for_step(cfg, shape, s), lc)
    first = stats.history[0]["loss"] if stats.history else float("nan")
    last = stats.history[-1]["loss"] if stats.history else float("nan")
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({stats.steps_run} steps, {stats.straggler_events} straggler "
          f"events)")
    return state, stats


if __name__ == "__main__":
    main()
