"""Full-program step builders shared by dryrun.py and the drivers.

Each returns (fn, arg_specs, in_shardings, donate_argnums): everything
jax.jit needs, with all array arguments as ShapeDtypeStructs (no device
allocation — the dry-run contract).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models import factory as factory_lib
from repro.models.factory import Model, build_model, input_specs
from repro.models.sharding import AxisRules, default_rules
from repro.train.optimizer import AdamState, AdamW, warmup_cosine
from repro.train.train_step import (TrainState, batch_shardings,
                                    make_train_step, state_shardings)

SEQ_POLICY_ARCHS = {"starcoder2-7b", "paligemma-3b", "whisper-base",
                    "recurrentgemma-2b"}


def rules_for(cfg: ArchConfig, mesh, overrides: dict = None, *,
              optimized: bool = True) -> AxisRules:
    """Arch-appropriate logical-axis rules (DESIGN.md §6).

    optimized=True enables the §Perf hillclimb winners (manual-TP layer
    blocks where eligible); optimized=False is the measured GSPMD-auto
    baseline A (results/dryrun_baselineA).
    """
    tp = mesh.devices.shape[mesh.axis_names.index("model")] \
        if "model" in mesh.axis_names else 1
    seq_attn = (cfg.n_heads % max(tp, 1) != 0)
    r = default_rules(mesh, seq_shard_attn=seq_attn)
    if optimized and cfg.d_model >= 8192:
        # measured crossover (EXPERIMENTS.md §Perf item 8): manual-TP's
        # dW locality wins big for the giant dense models (mistral
        # 270->153s, qwen2 156->96s dominant term) but its f32 boundary
        # gathers regress smaller-d archs (stablelm 23->41s)
        r.rules["manual_tp"] = True
    if overrides:
        r.rules.update(overrides)
    return r


def effective_microbatches(cfg: ArchConfig, shape: ShapeConfig,
                           mesh) -> int:
    """Largest mb <= cfg.microbatches with (B/mb) divisible by the batch
    shards of this mesh (a multi-pod mesh shards the batch 2x wider, so
    per-arch mb settings are sized for single-pod and clamped here)."""
    shards = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            shards *= mesh.devices.shape[mesh.axis_names.index(ax)]
    mb = max(1, cfg.microbatches)
    B = shape.global_batch
    while mb > 1 and (B % mb or (B // mb) % shards):
        mb //= 2
    return mb


def abstract_params(model: Model):
    """(param ShapeDtypeStructs, logical axes) without allocating."""
    box = {}

    def f(k):
        p, a = model.init(k)
        box["axes"] = a
        return p
    specs = jax.eval_shape(f, jax.random.PRNGKey(0))
    return specs, box["axes"]


def param_shardings(pspecs, axes, rules: AxisRules):
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                        rules.tree_specs(axes, pspecs),
                        is_leaf=lambda x: isinstance(x, P))


def build_train_setup(cfg: ArchConfig, shape: ShapeConfig, mesh,
                      rules: AxisRules = None, *, compression=False):
    rules = rules or rules_for(cfg, mesh)
    model = build_model(cfg)
    opt = AdamW()
    mb = effective_microbatches(cfg, shape, mesh)
    step_fn = make_train_step(model, opt, warmup_cosine(3e-4, 2000, 10**5),
                              rules=rules, microbatches=mb,
                              compression=compression)
    pspecs, axes = abstract_params(model)
    f32s = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pspecs)
    ef = f32s if compression else None
    needs_master = any(s.dtype != jnp.float32
                       for s in jax.tree.leaves(pspecs))
    state_specs = TrainState(
        params=pspecs,
        opt=AdamState(mu=f32s, nu=f32s,
                      count=jax.ShapeDtypeStruct((), jnp.int32),
                      master=f32s if needs_master else None),
        step=jax.ShapeDtypeStruct((), jnp.int32), ef=ef)
    st_sh = state_shardings(state_specs, axes, rules)
    bspecs = input_specs(cfg, shape)
    b_sh = batch_shardings(bspecs, rules)
    return step_fn, (state_specs, bspecs), (st_sh, b_sh), (0,)


def build_prefill_setup(cfg: ArchConfig, shape: ShapeConfig, mesh,
                        rules: AxisRules = None):
    rules = rules or rules_for(cfg, mesh)
    model = build_model(cfg)
    pspecs, axes = abstract_params(model)
    p_sh = param_shardings(pspecs, axes, rules)
    bspecs = input_specs(cfg, shape)
    b_sh = batch_shardings(bspecs, rules)

    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch,
                                      max_len=shape.seq_len, rules=rules)
        return jnp.argmax(logits, -1).astype(jnp.int32), state
    return prefill_step, (pspecs, bspecs), (p_sh, b_sh), ()


def build_decode_setup(cfg: ArchConfig, shape: ShapeConfig, mesh,
                       rules: AxisRules = None):
    rules = rules or rules_for(cfg, mesh)
    model = build_model(cfg)
    pspecs, axes = abstract_params(model)
    p_sh = param_shardings(pspecs, axes, rules)
    B = shape.global_batch
    st_specs = model.decode_state_specs(B, shape.seq_len)
    st_axes = factory_lib.state_logical_axes(model, st_specs)
    st_sh = jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                         rules.tree_specs(st_axes, st_specs),
                         is_leaf=lambda x: isinstance(x, P))
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_sh = NamedSharding(rules.mesh,
                         rules.spec(("batch", None), tok.shape))

    def decode_step(params, tokens, state):
        logits, state = model.decode(params, tokens, state, mesh=mesh,
                                     rules=rules)
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None], state
    return decode_step, (pspecs, tok, st_specs), (p_sh, t_sh, st_sh), (2,)


def build_setup(cfg: ArchConfig, shape: ShapeConfig, mesh,
                rules: AxisRules = None):
    if shape.kind == "train":
        return build_train_setup(cfg, shape, mesh, rules)
    if shape.kind == "prefill":
        return build_prefill_setup(cfg, shape, mesh, rules)
    return build_decode_setup(cfg, shape, mesh, rules)
