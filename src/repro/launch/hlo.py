"""Post-optimization HLO analysis: collective bytes + schedule.

``collective_stats`` parses ``compiled.as_text()`` and sums *operand* bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, keyed by op kind (cost_analysis does not report
collective traffic — contract §Roofline).

Caveat handled by the caller (launch/roofline.py): ops inside ``while``
bodies appear once in the HLO text regardless of trip count, exactly like
their FLOPs.  The roofline composes per-layer probe programs (no outer
scan) x layer counts, so collective bytes from probes are trip-count-exact;
full-program stats are reported as the *schedule* (which collectives, what
sizes, how many code sites), not multiplied.
"""
from __future__ import annotations

import collections
import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[2,512,1024]{2,1,0} all-gather(%param.1), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]
    ops: List[dict]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def as_dict(self):
        return {"bytes_by_kind": dict(self.bytes_by_kind),
                "count_by_kind": dict(self.count_by_kind),
                "total_bytes": self.total_bytes}


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by = collections.Counter()
    count_by = collections.Counter()
    ops = []
    seen_done = set()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.search(line)
        kind = None
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            nb = _nbytes(dtype, dims)
        else:
            mt = _TUPLE_RE.search(line)
            if mt:
                kind = mt.group(2)
                nb = sum(_nbytes(d, s)
                         for d, s in _SHAPE_RE.findall(mt.group(1)))
        if kind is None:
            continue
        # async pairs: count -start once, skip matching -done
        if "-done(" in line or f"{kind}-done" in line.split(" = ")[0]:
            continue
        bytes_by[kind] += nb
        count_by[kind] += 1
        ops.append({"kind": kind, "bytes": nb})
    return CollectiveStats(dict(bytes_by), dict(count_by), ops)


# ---------------------------------------------------------------------------
# op census: the budget substrate of the fppcheck HLO passes (DESIGN.md §7)

#: computation header:  %region_3.34 (arg: f32[]) -> f32[] {   /  ENTRY %main (
#: the param list may nest parens (tuple-typed params), so match lazily up
#: to the -> and require the opening brace
_COMPUTATION_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")

#: one instruction:  %name = <shape> opcode(...)   (shape may be a tuple)
_INSTR_RE = re.compile(
    r"=\s*(?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-zA-Z][\w\-]*)\(")

#: computations an instruction calls into
_CALLEE_RE = re.compile(
    r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


@dataclasses.dataclass
class OpCensus:
    """Opcode counts over one optimized-HLO module.

    ``counts`` covers every computation; ``while_body_counts`` covers only
    instructions reachable from a ``while`` op's body computation
    (transitively through fusions/calls) — the per-iteration cost the
    budget gates care most about, since text counts outside loops are
    trip-count-blind but an op *inside* the body runs every iteration.
    """
    counts: Dict[str, int]
    while_body_counts: Dict[str, int]
    num_computations: int

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    @property
    def while_body_total(self) -> int:
        return sum(self.while_body_counts.values())

    def as_dict(self):
        return {"counts": dict(self.counts),
                "while_body_counts": dict(self.while_body_counts),
                "total": self.total,
                "while_body_total": self.while_body_total}


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    current = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _COMPUTATION_RE.match(line)
        if m and raw and not raw[0].isspace():
            current = m.group(2)
            comps[current] = []
        elif current is not None and " = " in line:
            comps[current].append(line)
    return comps


def _callees(line: str) -> List[str]:
    out = _CALLEE_RE.findall(line)
    mb = _BRANCHES_RE.search(line)
    if mb:
        out.extend(n.strip().lstrip("%") for n in mb.group(1).split(",")
                   if n.strip())
    return out


def op_census(hlo_text: str) -> OpCensus:
    comps = _split_computations(hlo_text)
    counts: collections.Counter = collections.Counter()
    body_roots: List[str] = []
    for lines in comps.values():
        for line in lines:
            m = _INSTR_RE.search(line)
            if not m:
                continue
            op = m.group(1)
            counts[op] += 1
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", line)
                if mb:
                    body_roots.append(mb.group(1))
    # transitive closure of computations reachable from while bodies
    reach: set = set()
    stack = [b for b in body_roots if b in comps]
    while stack:
        name = stack.pop()
        if name in reach:
            continue
        reach.add(name)
        for line in comps.get(name, ()):
            for callee in _callees(line):
                if callee in comps and callee not in reach:
                    stack.append(callee)
    body_counts: collections.Counter = collections.Counter()
    for name in reach:
        for line in comps[name]:
            m = _INSTR_RE.search(line)
            if m:
                body_counts[m.group(1)] += 1
    return OpCensus(dict(counts), dict(body_counts), len(comps))


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis()
    m = compiled.memory_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "argument_bytes": int(m.argument_size_in_bytes),
        "output_bytes": int(m.output_size_in_bytes),
        "temp_bytes": int(m.temp_size_in_bytes),
        "alias_bytes": int(m.alias_size_in_bytes),
        "peak_bytes_est": int(m.argument_size_in_bytes
                              + m.temp_size_in_bytes
                              + m.output_size_in_bytes
                              - m.alias_size_in_bytes),
    }
