import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell this lowers + compiles the real
step program — train_step (train_4k), prefill_step (prefill_32k),
serve/decode_step (decode_32k, long_500k) — against the production meshes:

    single-pod  (16, 16)       ("data", "model")        256 chips
    multi-pod   (2, 16, 16)    ("pod", "data", "model") 512 chips

and records per cell: memory_analysis (fits?), cost_analysis
(per-device FLOPs/bytes), the collective schedule parsed from the
post-optimization HLO, and the probe-composed roofline inputs
(launch/probes.py).  Results go to results/dryrun/<arch>__<shape>__<mesh>.json
and EXPERIMENTS.md §Dry-run reads from them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh multi
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import base as cfg_base
from repro.configs.shapes import SHAPES, applicable, skip_reason
from repro.launch import hlo as hlo_lib
from repro.launch import probes as probes_lib
from repro.launch import mesh as mesh_lib
from repro.launch.mesh import chips, make_production_mesh
from repro.launch.steps import build_setup, rules_for

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def out_dir():
    d = os.environ.get("DRYRUN_OUT", os.path.abspath(RESULTS))
    os.makedirs(d, exist_ok=True)
    return d


def run_probes(cfg, shape, rules, mesh):
    """Compile each per-unit probe; returns composed totals + breakdown."""
    if shape.kind == "train":
        probes = probes_lib.train_probes(cfg, shape, rules)
    elif shape.kind == "prefill":
        probes = probes_lib.prefill_probes(cfg, shape, rules)
    else:
        probes = probes_lib.decode_probes(cfg, shape, rules, mesh)
    total = {"flops": 0.0, "bytes_accessed": 0.0, "collective_bytes": 0.0}
    coll_by_kind = {}
    breakdown = []
    for p in probes:
        with mesh_lib.set_mesh(rules.mesh), probes_lib.probe_tracing():
            compiled = jax.jit(p.fn, in_shardings=p.in_shardings).lower(
                *p.arg_specs).compile()
        cs = hlo_lib.cost_summary(compiled)
        col = hlo_lib.collective_stats(compiled.as_text())
        item = {"name": p.name, "count": p.count, "flops": cs["flops"],
                "bytes_accessed": cs["bytes_accessed"],
                "collective": col.as_dict()}
        breakdown.append(item)
        total["flops"] += p.count * cs["flops"]
        total["bytes_accessed"] += p.count * cs["bytes_accessed"]
        total["collective_bytes"] += p.count * col.total_bytes
        for k, v in col.bytes_by_kind.items():
            coll_by_kind[k] = coll_by_kind.get(k, 0.0) + p.count * v
    if shape.kind == "train":
        opt = probes_lib.optimizer_analytic(
            cfg_count_params(cfg), chips(rules.mesh))
        total["flops"] += opt["flops"]
        total["bytes_accessed"] += opt["bytes_accessed"]
        breakdown.append({"name": "optimizer(analytic)", "count": 1,
                          **opt})
    total["collective_by_kind"] = coll_by_kind
    return total, breakdown


def cfg_count_params(cfg):
    return cfg.num_params()


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, skip_probes=False, force=False) -> dict:
    path = os.path.join(out_dir(),
                        f"{arch}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = cfg_base.get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "n_params": cfg.num_params(),
           "n_active_params": cfg.active_params()}
    if not applicable(cfg, shape_name):
        rec["status"] = "SKIP"
        rec["reason"] = skip_reason(cfg, shape_name)
        _write(path, rec)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rules_for(cfg, mesh)
    rec["chips"] = chips(mesh)
    try:
        t0 = time.time()
        fn, arg_specs, in_sh, donate = build_setup(cfg, shape, mesh, rules)
        with mesh_lib.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*arg_specs)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        rec["lower_s"] = round(t_lower, 2)
        rec["compile_s"] = round(t_compile, 2)
        rec["cost"] = hlo_lib.cost_summary(compiled)
        rec["collective_schedule"] = hlo_lib.collective_stats(
            compiled.as_text()).as_dict()
        hbm = 16 * 2 ** 30   # v5e
        peak = rec["cost"]["peak_bytes_est"]
        rec["fits_hbm"] = bool(peak <= hbm)
        rec["peak_gb"] = round(peak / 2 ** 30, 2)
        if not skip_probes and mesh_kind == "single":
            # roofline terms are single-pod (contract); multi-pod proves
            # the pod axis shards.
            totals, breakdown = run_probes(cfg, shape, rules, mesh)
            rec["roofline_inputs"] = totals
            rec["probe_breakdown"] = breakdown
        rec["status"] = "OK" if rec["fits_hbm"] else "OK_OVER_HBM"
    except Exception as e:                      # noqa: BLE001
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    _write(path, rec)
    return rec


def _write(path, rec):
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    args = ap.parse_args()

    archs = cfg_base.list_configs() if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    rows = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, mk, force=args.force,
                               skip_probes=args.skip_probes)
                status = rec["status"]
                extra = ""
                if status.startswith("OK"):
                    extra = (f"peak {rec.get('peak_gb', '?'):>6} GB  "
                             f"compile {rec.get('compile_s', 0):6.1f}s")
                elif status == "SKIP":
                    extra = rec["reason"][:60]
                else:
                    extra = rec.get("error", "")[:90]
                print(f"{arch:25s} {shape:12s} {mk:6s} {status:12s} "
                      f"{extra}  [{time.time() - t0:5.1f}s]", flush=True)
                rows.append(rec)
    n_ok = sum(r["status"].startswith("OK") for r in rows)
    n_skip = sum(r["status"] == "SKIP" for r in rows)
    n_fail = sum(r["status"] == "FAIL" for r in rows)
    print(f"\n== dry-run: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL "
          f"of {len(rows)} cells ==")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
