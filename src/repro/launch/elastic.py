"""Elastic re-mesh: restore a checkpoint onto a different mesh.

The 1000+-node posture (DESIGN.md §6) requires surviving topology changes:
a job checkpointed on mesh M must resume on mesh M' after nodes are lost
or added.  Checkpoints store host-side full arrays (train/checkpoint.py),
so resharding is a pure device_put against the new mesh's shardings —
this module packages that as a driver:

    state', mesh' = reshard_restore(ckpt_dir, cfg, new_mesh)

and `tests/test_elastic.py` proves a (2,4) -> (4,2) -> (1,1) round trip is
loss-curve-identical.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ArchConfig
from repro.launch.steps import abstract_params, rules_for
from repro.models.factory import build_model
from repro.train import checkpoint as ck
from repro.train.optimizer import AdamState, AdamW
from repro.train.train_step import TrainState, state_shardings


def reshard_restore(ckpt_dir: str, cfg: ArchConfig, mesh, *,
                    step: Optional[int] = None, optimized: bool = True):
    """Restore the newest (or given) checkpoint onto ``mesh``.

    Returns (TrainState on the new mesh's shardings, rules, step).
    """
    model = build_model(cfg)
    opt = AdamW()
    pspecs, axes = abstract_params(model)
    import jax.numpy as jnp
    f32s = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pspecs)
    needs_master = any(s.dtype != jnp.float32
                       for s in jax.tree.leaves(pspecs))
    target = TrainState(
        params=pspecs,
        opt=AdamState(mu=f32s, nu=f32s,
                      count=jax.ShapeDtypeStruct((), jnp.int32),
                      master=f32s if needs_master else None),
        step=jax.ShapeDtypeStruct((), jnp.int32), ef=None)
    shardings = None
    rules = None
    if mesh is not None and mesh.devices.size > 1:
        rules = rules_for(cfg, mesh, optimized=optimized)
        shardings = state_shardings(target, axes, rules)
    state, got_step, _ = ck.restore(ckpt_dir, step, target=target,
                                    shardings=shardings)
    return state, rules, got_step
