"""Roofline analysis (deliverable g).

Reads the dry-run JSONs (launch/dryrun.py) and derives, per
(arch x shape) cell on the single-pod mesh:

    compute term    = FLOPs_per_chip / peak_FLOPs            [s]
    memory term     = bytes_per_chip / HBM_bw                [s]
    collective term = collective_bytes_per_chip / link_bw    [s]

FLOPs/bytes come from the probe composition (launch/probes.py) — exact in
loop trip counts, per-device.  Collective bytes are operand bytes of every
all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute in the
probes' post-optimization HLO (per-device shapes).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (contract values).

Also reported per cell:
    MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) [+ attention term],
    useful-compute ratio = MODEL_FLOPS / HLO_FLOPs (catches remat and
    redundancy waste), the dominant term, and a one-line "what would move
    the dominant term" note.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--results results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import base as cfg_base
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / ICI link


def model_flops(cfg, shape, per_chip_chips=256) -> float:
    """Analytic MODEL_FLOPS for the whole step, per chip.

    train: 6*N*D  (D = tokens; fwd 2ND + bwd 4ND)
    prefill: 2*N*D
    decode: 2*N*1 token per sequence + attention KV read term is memory,
            not FLOPs-dominant; we report 2*N_active*B.
    """
    n = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens / per_chip_chips
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens / per_chip_chips
    return 2.0 * n * shape.global_batch / per_chip_chips


def analyze_record(rec: dict) -> dict:
    cfg = cfg_base.get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    ri = rec.get("roofline_inputs")
    if not ri:
        return {}
    chips = rec.get("chips", 256)
    t_comp = ri["flops"] / PEAK_FLOPS
    t_mem = ri["bytes_accessed"] / HBM_BW
    t_coll = ri["collective_bytes"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, chips)
    bound = max(terms.values())
    out = {
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / max(ri["flops"], 1.0),
        # roofline fraction: useful compute time / modeled step time
        # (step time = max of the three terms, the balance assumption)
        "roofline_fraction": (mf / PEAK_FLOPS) / max(bound, 1e-12),
        "note": _note(dom, cfg, shape),
    }
    return out


def _note(dom: str, cfg, shape) -> str:
    if dom == "compute":
        return ("compute-bound: raise useful ratio (less remat/redundant "
                "FLOPs) or grow per-chip batch")
    if dom == "memory":
        if shape.kind == "decode":
            return ("HBM-bound on KV/state streaming: shrink cache bytes "
                    "(bf16->int8 KV, window) or batch more queries per "
                    "load (the paper's move)")
        return ("HBM-bound: increase arithmetic intensity (fuse, bigger "
                "microbatch, bf16 master-free optimizer)")
    return ("collective-bound: reshard to cut cross-chip bytes (wider "
            "model axis hurts; try FSDP-only or 2D overlap), or overlap "
            "with compute")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.results,
                                              "*__single.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "SKIP":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "SKIP", "reason": rec["reason"]})
            continue
        if "roofline_inputs" not in rec:
            continue
        a = analyze_record(rec)
        rows.append({"arch": rec["arch"], "shape": rec["shape"],
                     "status": rec["status"], **a})
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    hdr = (f"{'arch':25s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'dom':>9s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("status") == "SKIP":
            print(f"{r['arch']:25s} {r['shape']:12s} {'SKIP':>9s}")
            continue
        print(f"{r['arch']:25s} {r['shape']:12s} "
              f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
              f"{r['collective_s']:9.4f} {r['dominant']:>9s} "
              f"{r['useful_ratio']:7.2f} "
              f"{100 * r['roofline_fraction']:6.1f}%")


if __name__ == "__main__":
    main()
