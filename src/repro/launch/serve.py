"""End-to-end serving driver: continuous batching over batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --reduced \
        --requests 16 --batch 4 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.factory import build_model
from repro.serve.engine import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    batcher = ContinuousBatcher(model, params, batch_size=args.batch,
                                max_len=args.max_len)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              rng.integers(4, 12)).astype(np.int32)
        extras = None
        if cfg.family == "vlm":
            extras = {"image_embeds": rng.normal(size=(
                cfg.num_image_tokens, cfg.d_model)).astype(np.float32)}
        if cfg.family == "encdec":
            extras = {"frames": 0.1 * rng.normal(size=(
                1500, cfg.d_model)).astype(np.float32)}
        batcher.submit(Request(rid=rid, prompt=prompt,
                               max_new_tokens=args.max_new,
                               extras=extras))
    t0 = time.perf_counter()
    out = batcher.run()
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: {len(out)} requests, "
          f"{batcher.tokens_out} tokens in {batcher.steps} decode steps, "
          f"{dt:.2f}s ({batcher.tokens_out / dt:.1f} tok/s)")
    for rid in sorted(out)[:4]:
        print(f"  req {rid}: {out[rid]}")


if __name__ == "__main__":
    main()
