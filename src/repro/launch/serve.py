"""End-to-end serving driver: continuous batching over batched requests.

Two workloads share the serving posture (DESIGN.md §4):

  lm     token serving — ContinuousBatcher over a reduced model twin
  graph  graph-query serving — the FPPSession streaming executor admits
         asynchronously-arriving SSSP/PPR batches into the in-flight
         buffered engine (fpp/streaming.py)

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --reduced \
        --requests 16 --batch 4 --max-new 12
    PYTHONPATH=src python -m repro.launch.serve --workload graph \
        --graph road-ca --requests 32 --batch 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def serve_lm(args):
    import jax
    from repro.configs.base import get_config
    from repro.models.factory import build_model
    from repro.serve.engine import ContinuousBatcher, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    batcher = ContinuousBatcher(model, params, batch_size=args.batch,
                                max_len=args.max_len)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              rng.integers(4, 12)).astype(np.int32)
        extras = None
        if cfg.family == "vlm":
            extras = {"image_embeds": rng.normal(size=(
                cfg.num_image_tokens, cfg.d_model)).astype(np.float32)}
        if cfg.family == "encdec":
            extras = {"frames": 0.1 * rng.normal(size=(
                1500, cfg.d_model)).astype(np.float32)}
        batcher.submit(Request(rid=rid, prompt=prompt,
                               max_new_tokens=args.max_new,
                               extras=extras))
    t0 = time.perf_counter()
    out = batcher.run()
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: {len(out)} requests, "
          f"{batcher.tokens_out} tokens in {batcher.steps} decode steps, "
          f"{dt:.2f}s ({batcher.tokens_out / dt:.1f} tok/s)")
    for rid in sorted(out)[:4]:
        print(f"  req {rid}: {out[rid]}")


def serve_graph(args):
    """Staggered graph-query serving through the session streaming path."""
    from repro.fpp import FPPSession
    from repro.graphs.generators import build_suite

    g = build_suite(args.graph)
    rng = np.random.default_rng(args.seed)
    deg = g.out_degree()
    cand = np.flatnonzero(deg > 0)
    sources = rng.choice(cand, size=min(args.requests, cand.size),
                         replace=False)
    sess = FPPSession(g).plan(num_queries=args.batch,
                              block_size=args.block_size)
    stream = sess.stream(args.kind, capacity=args.batch)
    t0 = time.perf_counter()
    qids = []
    # arrivals: feed one batch, let the engine work, feed the next —
    # the serving twin of Alg. 2's dynamic partition scheduling
    for lo in range(0, len(sources), args.batch):
        qids += stream.submit(sources[lo: lo + args.batch])
        stream.pump(args.pump_visits)
    out = stream.run()
    dt = time.perf_counter() - t0
    done = [q for q in qids if q in out]
    print(f"[serve] graph={args.graph} |V|={g.n} kind={args.kind}: "
          f"{len(done)}/{len(qids)} queries in {stream.visits} visits, "
          f"{dt:.2f}s ({len(done) / max(dt, 1e-9):.1f} q/s, "
          f"B={sess.current_plan.block_size}, capacity={args.batch})")
    assert len(done) == len(qids), "stream failed to drain every query"
    if done:
        lat = [stream.result(q).finished_visit
               - stream.result(q).submitted_visit for q in done]
        print(f"  visit-latency p50/p95: {np.percentile(lat, 50):.0f}/"
              f"{np.percentile(lat, 95):.0f} visits")


def main():
    from repro.graphs.generators import SUITES   # jax-free import

    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "graph"), default="lm")
    # lm workload
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    # graph workload
    ap.add_argument("--graph", default="road-ca", choices=sorted(SUITES))
    ap.add_argument("--kind", choices=("sssp", "bfs", "ppr"), default="sssp")
    ap.add_argument("--block-size", type=int, default=256,
                    help="partition size; omit planner autotune on CPU demo")
    ap.add_argument("--pump-visits", type=int, default=8,
                    help="visits to run between arriving batches")
    # shared
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.workload == "graph":
        serve_graph(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
