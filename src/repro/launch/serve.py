"""End-to-end serving driver: continuous batching over batched requests.

Two workloads share the serving posture (DESIGN.md §4):

  lm     token serving — ContinuousBatcher over a reduced model twin
         (DESIGN.md §4.1)
  graph  graph-query serving — a multi-tenant GraphServer (DESIGN.md §4.2)
         multiplexes an arrival stream of mixed-kind requests onto
         per-(graph, kind) lane pools over the streaming megastep

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --reduced \
        --requests 16 --batch 4 --max-new 12
    PYTHONPATH=src python -m repro.launch.serve --workload graph \
        --graph road-ca --kind mixed --requests 32 --batch 8 --tenants 2
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def serve_lm(args):
    import jax
    from repro.configs.base import get_config
    from repro.models.factory import build_model
    from repro.serve.engine import ContinuousBatcher, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    batcher = ContinuousBatcher(model, params, batch_size=args.batch,
                                max_len=args.max_len)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              rng.integers(4, 12)).astype(np.int32)
        extras = None
        if cfg.family == "vlm":
            extras = {"image_embeds": rng.normal(size=(
                cfg.num_image_tokens, cfg.d_model)).astype(np.float32)}
        if cfg.family == "encdec":
            extras = {"frames": 0.1 * rng.normal(size=(
                1500, cfg.d_model)).astype(np.float32)}
        batcher.submit(Request(rid=rid, prompt=prompt,
                               max_new_tokens=args.max_new,
                               extras=extras))
    t0 = time.perf_counter()
    out = batcher.run()
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: {len(out)} requests, "
          f"{batcher.tokens_out} tokens in {batcher.steps} decode steps, "
          f"{dt:.2f}s ({batcher.tokens_out / dt:.1f} tok/s)")
    for rid in sorted(out)[:4]:
        print(f"  req {rid}: {out[rid]}")


def serve_graph(args):
    """Multi-tenant graph-query serving through the GraphServer pump."""
    from repro.graphs.generators import build_suite
    from repro.serve import GraphRequest, GraphServer

    g = build_suite(args.graph)
    rng = np.random.default_rng(args.seed)
    cand = np.flatnonzero(g.out_degree() > 0)
    sources = rng.choice(cand, size=args.requests, replace=True)
    kinds = (("sssp", "ppr") if args.kind == "mixed" else (args.kind,))
    # tenant 0 is the hot tenant (most of the offered load); equal weights,
    # so fair admission alone must keep the cold tenants served
    tenants = [f"tenant{i}" for i in range(args.tenants)]

    server = GraphServer(capacity=args.batch, k_visits=args.pump_visits,
                         seed=args.seed)
    server.register_graph(args.graph, g, num_queries=args.batch,
                          block_size=args.block_size)

    def arrivals():
        # one submission batch per serving round — the arrival process the
        # synchronous pump interleaves with chunk execution
        for lo in range(0, len(sources), args.batch):
            yield [GraphRequest(kind=kinds[i % len(kinds)], source=int(s),
                                graph=args.graph,
                                tenant=(tenants[0] if i % 4 else
                                        tenants[(i // 4) % len(tenants)]))
                   for i, s in enumerate(sources[lo: lo + args.batch],
                                         start=lo)]

    t0 = time.perf_counter()
    out = server.serve_forever(arrivals())
    dt = time.perf_counter() - t0
    ok = [r for r in out.values() if r.status == "ok"]
    if len(out) != len(sources):
        raise RuntimeError(
            f"server answered {len(out)} of {len(sources)} requests — "
            f"every submitted request must get a terminal response")
    lat = np.array([r.stats["latency_s"] for r in ok]) * 1e3
    print(f"[serve] graph={args.graph} |V|={g.n} kinds={'/'.join(kinds)} "
          f"tenants={args.tenants}: {len(ok)}/{len(out)} ok in "
          f"{server.rounds} rounds, {dt:.2f}s "
          f"({len(ok) / max(dt, 1e-9):.1f} q/s, capacity={args.batch}, "
          f"K={args.pump_visits})")
    if len(lat):
        print(f"  latency p50/p99: {np.percentile(lat, 50):.1f}/"
              f"{np.percentile(lat, 99):.1f} ms; per-request host syncs "
              f"p50: {np.percentile([r.stats['host_syncs'] for r in ok], 50):.0f}")


def main():
    from repro.graphs.generators import SUITES   # jax-free import

    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "graph"), default="lm")
    # lm workload
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    # graph workload
    ap.add_argument("--graph", default="road-ca", choices=sorted(SUITES))
    ap.add_argument("--kind", choices=("sssp", "bfs", "ppr", "mixed"),
                    default="sssp")
    ap.add_argument("--block-size", type=int, default=256,
                    help="partition size; omit planner autotune on CPU demo")
    ap.add_argument("--pump-visits", type=int, default=8,
                    help="megastep chunk size K: visits per serving round")
    ap.add_argument("--tenants", type=int, default=2,
                    help="tenant count for the graph workload (tenant0 hot)")
    # shared
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.workload == "graph":
        serve_graph(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
