"""Per-component cost probes for the roofline composition.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in
EXPERIMENTS.md §Methodology), so a scanned-over-layers program under-counts
FLOPs/bytes/collectives by ~n_layers.  The roofline therefore composes:

    total = sum_units  count(unit) x cost(probe(unit)) x microbatches
          + cost(embed/loss probe) x microbatches
          + analytic optimizer term

where each *probe* is a standalone jitted program for one scan unit (a
layer, a hybrid group, an encoder layer, ...) with the real shardings, so
its HLO has no outer while loop: its cost_analysis and collective bytes are
trip-count-exact and *per device* (SPMD cost_analysis reports the
per-partition module; calibrated in EXPERIMENTS.md).

Train probes differentiate through jax.checkpoint(layer) — remat recompute
is included, exactly as the real train step pays it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import contextlib

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models import attention as attn_lib
from repro.models import encdec as encdec_lib
from repro.models import layers as L
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.factory import Model, cross_entropy
from repro.models.sharding import AxisRules


@dataclasses.dataclass
class Probe:
    name: str
    count: float                  # how many times this unit runs per step
    fn: Callable
    arg_specs: tuple
    in_shardings: tuple


@contextlib.contextmanager
def probe_tracing():
    """Unroll the attention chunk scan while tracing probe programs, so
    cost_analysis (which counts while bodies once) is trip-count-exact."""
    old = attn_lib.CHUNK_OVERRIDE
    attn_lib.CHUNK_OVERRIDE = 1 << 30
    try:
        yield
    finally:
        attn_lib.CHUNK_OVERRIDE = old


def _layer_specs(cfg: ArchConfig, kind: str, rules: AxisRules):
    box = {}

    def f(k):
        p, a = tfm.init_layer(k, cfg, kind)
        box["axes"] = a
        return p
    specs = jax.eval_shape(f, jax.random.PRNGKey(0))
    shard = jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s),
        rules.tree_specs(box["axes"], specs),
        is_leaf=lambda x: isinstance(x, P))
    return specs, shard


def _group_specs(cfg: ArchConfig, rules: AxisRules):
    specs, shards = {}, {}
    for nm, kind in (("rec1", "rec"), ("rec2", "rec"), ("attn", "attn")):
        specs[nm], shards[nm] = _layer_specs(cfg, kind, rules)
    return specs, shards


def _x_spec(cfg, B, S, rules, logical=("batch", "act_seq", None)):
    spec = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.cdtype)
    sh = NamedSharding(rules.mesh, rules.spec(logical, spec.shape))
    return spec, sh


def _ns(rules, logical, shape):
    return NamedSharding(rules.mesh, rules.spec(logical, shape))


# ---------------------------------------------------------------------------
# train probes


def train_probes(cfg: ArchConfig, shape: ShapeConfig,
                 rules: AxisRules) -> List[Probe]:
    mb = max(1, cfg.microbatches)
    B = max(1, shape.global_batch // mb)
    S = shape.seq_len
    if cfg.family == "vlm":
        S = shape.seq_len  # prefix + text = assigned seq_len total
    positions = jnp.arange(S)
    probes = []

    def layer_probe(kind, name, count):
        lspecs, lshard = _layer_specs(cfg, kind, rules)
        xspec, xshard = _x_spec(cfg, B, S, rules)

        # ct is a runtime cotangent: grad of sum(y) would hand XLA a
        # constant cotangent of ones and let it simplify away real
        # backward matmuls (verified: ~30% FLOP undercount).
        def f(lp, x, ct):
            def inner(lp, x):
                y, aux, _, _ = tfm._apply_layer_full(
                    lp, cfg, kind, x, positions, rules)
                return jnp.sum(y.astype(jnp.float32) * ct) + aux
            return jax.grad(inner, argnums=(0, 1))(lp, x)
        ctspec = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
        return Probe(name, count * mb, f, (lspecs, xspec, ctspec),
                     (lshard, xshard, xshard))

    def layer_fwd_probe(kind, name, count):
        # remat recompute = exactly one extra forward per layer
        # (grad(checkpoint(f)) at probe top level is a documented no-op,
        # so the recompute must be accounted as its own unit).
        lspecs, lshard = _layer_specs(cfg, kind, rules)
        xspec, xshard = _x_spec(cfg, B, S, rules)

        def f(lp, x):
            y, _, _, _ = tfm._apply_layer_full(lp, cfg, kind, x,
                                               positions, rules)
            return y
        return Probe(name, count * mb, f, (lspecs, xspec),
                     (lshard, xshard))

    if cfg.family == "hybrid":
        gspecs, gshard = _group_specs(cfg, rules)
        xspec, xshard = _x_spec(cfg, B, S, rules)

        def apply_group(gp, x):
            y, a1, _, _ = tfm._apply_layer_full(
                gp["rec1"], cfg, "rec", x, positions, rules)
            y, a2, _, _ = tfm._apply_layer_full(
                gp["rec2"], cfg, "rec", y, positions, rules)
            y, a3, _, _ = tfm._apply_layer_full(
                gp["attn"], cfg, "attn", y, positions, rules)
            return y, a1 + a2 + a3

        def fg(gp, x, ct):
            def inner(gp, x):
                y, aux = apply_group(gp, x)
                return jnp.sum(y.astype(jnp.float32) * ct) + aux
            return jax.grad(inner, argnums=(0, 1))(gp, x)
        ctspec = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
        ng = cfg.n_layers // 3
        probes.append(Probe("group", ng * mb, fg, (gspecs, xspec, ctspec),
                            (gshard, xshard, xshard)))
        probes.append(Probe("group_remat_fwd", ng * mb,
                            lambda gp, x: apply_group(gp, x)[0],
                            (gspecs, xspec), (gshard, xshard)))
        if cfg.n_layers % 3:
            probes.append(layer_probe("rec", "tail_rec", cfg.n_layers % 3))
            probes.append(layer_fwd_probe("rec", "tail_rec_remat_fwd",
                                          cfg.n_layers % 3))
    elif cfg.family == "encdec":
        probes.extend(_encdec_train_probes(cfg, shape, rules, B, mb))
    else:
        kind = tfm.layer_plan(cfg)[0]
        probes.append(layer_probe(kind, f"layer_{kind}", cfg.n_layers))
        probes.append(layer_fwd_probe(kind, f"layer_{kind}_remat_fwd",
                                      cfg.n_layers))

    if cfg.family != "encdec":
        probes.append(_embed_loss_probe(cfg, shape, rules, B, S, mb))
    return probes


def _embed_loss_probe(cfg, shape, rules, B, S, mb) -> Probe:
    box = {}

    def finit(k):
        p, a = {}, {}
        p["embed"], a["embed"] = L.init_embedding(
            k, L.pad_vocab(cfg.vocab), cfg.d_model, cfg.pdtype,
            cfg.tie_embeddings)
        p["final_norm"], a["final_norm"] = L.init_norm(
            cfg.pdtype, cfg.d_model, cfg.norm)
        box["axes"] = a
        return p
    specs = jax.eval_shape(finit, jax.random.PRNGKey(0))
    shard = jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                         rules.tree_specs(box["axes"], specs),
                         is_leaf=lambda x: isinstance(x, P))
    S_lab = S - (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    tok = jax.ShapeDtypeStruct((B, S_lab), jnp.int32)
    lab = jax.ShapeDtypeStruct((B, S_lab), jnp.int32)
    msk = jax.ShapeDtypeStruct((B, S_lab), jnp.float32)
    bsh = _ns(rules, ("batch", None), tok.shape)

    def f(p, tokens, labels, mask):
        def inner(p):
            x = L.embed(p["embed"], tokens, cfg.cdtype, rules)
            h = L.apply_norm(p["final_norm"], x, cfg.norm)
            logits = L.unembed(p["embed"], h.astype(jnp.float32),
                               cfg.vocab)
            return cross_entropy(logits, labels, mask)
        return jax.grad(inner)(p)
    return Probe("embed_loss", mb, f, (specs, tok, lab, msk),
                 (shard, bsh, bsh, bsh))


def _encdec_train_probes(cfg, shape, rules, B, mb) -> List[Probe]:
    S = shape.seq_len
    F = encdec_lib.N_FRAMES_PAD
    probes = []
    # encoder layer
    especs, eshard = _enc_layer_specs(cfg, rules, decoder=False)
    xspec, xshard = _x_spec(cfg, B, F, rules)
    pos_f = jnp.arange(F)

    ct_f = jax.ShapeDtypeStruct((B, F, cfg.d_model), jnp.float32)

    def enc_apply(lp, x):
        y, _ = encdec_lib._self_block(lp, cfg, x, pos_f, rules,
                                      causal=False)
        return encdec_lib._mlp_block(lp, cfg, y)

    def fe(lp, x, ct):
        def inner(lp, x):
            return jnp.sum(enc_apply(lp, x).astype(jnp.float32) * ct)
        return jax.grad(inner, argnums=(0, 1))(lp, x)
    n_enc = (cfg.n_enc_layers or cfg.n_layers) * mb
    probes.append(Probe("enc_layer", n_enc, fe, (especs, xspec, ct_f),
                        (eshard, xshard, xshard)))
    probes.append(Probe("enc_layer_remat_fwd", n_enc, enc_apply,
                        (especs, xspec), (eshard, xshard)))
    # decoder layer (self + cross + mlp)
    dspecs, dshard = _enc_layer_specs(cfg, rules, decoder=True)
    xs, xsh = _x_spec(cfg, B, S, rules)
    ms, msh = _x_spec(cfg, B, F, rules, ("batch", None, None))
    pos_s = jnp.arange(S)

    ct_s = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)

    def dec_apply(lp, x, mem):
        y, _ = encdec_lib._self_block(lp, cfg, x, pos_s, rules,
                                      causal=True)
        y, _ = encdec_lib._cross_block(lp, cfg, y, mem, rules)
        return encdec_lib._mlp_block(lp, cfg, y)

    def fd(lp, x, mem, ct):
        def inner(lp, x, mem):
            return jnp.sum(dec_apply(lp, x, mem).astype(jnp.float32) * ct)
        return jax.grad(inner, argnums=(0, 1, 2))(lp, x, mem)
    probes.append(Probe("dec_layer", cfg.n_layers * mb, fd,
                        (dspecs, xs, ms, ct_s), (dshard, xsh, msh, xsh)))
    probes.append(Probe("dec_layer_remat_fwd", cfg.n_layers * mb,
                        dec_apply, (dspecs, xs, ms), (dshard, xsh, msh)))
    probes.append(_embed_loss_probe(cfg, shape, rules, B, S, mb))
    return probes


def _enc_layer_specs(cfg, rules, decoder: bool):
    box = {}

    def f(k):
        import jax.random as jr
        k1, k2, k3 = jr.split(k, 3)
        lp, la = {}, {}
        lp["ln1"], la["ln1"] = L.init_norm(cfg.pdtype, cfg.d_model,
                                           cfg.norm)
        lp["attn"], la["attn"] = attn_lib.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_,
            cfg.pdtype)
        lp["ln2"], la["ln2"] = L.init_norm(cfg.pdtype, cfg.d_model,
                                           cfg.norm)
        lp["mlp"], la["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff,
                                          cfg.pdtype, cfg.gated_mlp)
        if decoder:
            lp["ln_x"], la["ln_x"] = L.init_norm(cfg.pdtype, cfg.d_model,
                                                 cfg.norm)
            lp["xattn"], la["xattn"] = attn_lib.init_attention(
                k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.head_dim_, cfg.pdtype)
        box["axes"] = la
        return lp
    specs = jax.eval_shape(f, jax.random.PRNGKey(0))
    shard = jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                         rules.tree_specs(box["axes"], specs),
                         is_leaf=lambda x: isinstance(x, P))
    return specs, shard


# ---------------------------------------------------------------------------
# serve probes (prefill / decode)


def prefill_probes(cfg: ArchConfig, shape: ShapeConfig,
                   rules: AxisRules) -> List[Probe]:
    B, S = shape.global_batch, shape.seq_len
    positions = jnp.arange(S)
    probes = []
    if cfg.family == "encdec":
        # forward-only units of the train probe set (the *_remat_fwd
        # probes are exactly the fwd passes) + the unembed top
        fwd_only = [p for p in _encdec_train_probes(cfg, shape, rules, B, 1)
                    if p.name.endswith("_remat_fwd")]
        for p in fwd_only:
            probes.append(Probe(p.name.replace("_remat_fwd", ""), p.count,
                                p.fn, p.arg_specs, p.in_shardings))
        probes.append(_embed_top_probe(cfg, rules, B, S, train=False))
        return probes

    def layer_probe(kind, name, count):
        lspecs, lshard = _layer_specs(cfg, kind, rules)
        xspec, xshard = _x_spec(cfg, B, S, rules)

        def f(lp, x):
            y, aux, kv, st = tfm._apply_layer_full(
                lp, cfg, kind, x, positions, rules,
                prefix_len=(cfg.num_image_tokens or None),
                return_kv=(kind in ("attn", "moe")))
            return y
        return Probe(name, count, f, (lspecs, xspec), (lshard, xshard))

    if cfg.family == "hybrid":
        gspecs, gshard = _group_specs(cfg, rules)
        xspec, xshard = _x_spec(cfg, B, S, rules)

        def fg(gp, x):
            y, _, _, _ = tfm._apply_layer_full(gp["rec1"], cfg, "rec", x,
                                               positions, rules)
            y, _, _, _ = tfm._apply_layer_full(gp["rec2"], cfg, "rec", y,
                                               positions, rules)
            y, _, _, _ = tfm._apply_layer_full(gp["attn"], cfg, "attn", y,
                                               positions, rules)
            return y
        probes.append(Probe("group", cfg.n_layers // 3, fg,
                            (gspecs, xspec), (gshard, xshard)))
        if cfg.n_layers % 3:
            probes.append(layer_probe("rec", "tail_rec", cfg.n_layers % 3))
    else:
        kind = tfm.layer_plan(cfg)[0]
        probes.append(layer_probe(kind, f"layer_{kind}", cfg.n_layers))
    probes.append(_embed_top_probe(cfg, rules, B, S, train=False))
    return probes


def decode_probes(cfg: ArchConfig, shape: ShapeConfig, rules: AxisRules,
                  mesh) -> List[Probe]:
    B, S = shape.global_batch, shape.seq_len
    probes = []
    dt = cfg.cdtype
    xspec = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
    xsh = _ns(rules, ("batch", None, None), xspec.shape)
    lenspec = jax.ShapeDtypeStruct((B,), jnp.int32)
    lensh = _ns(rules, ("batch",), lenspec.shape)

    def kv_specs(cache_len, shard_seq=True):
        ks = jax.ShapeDtypeStruct((B, cache_len, cfg.n_kv_heads,
                                   cfg.head_dim_), dt)
        ksh = _ns(rules, ("batch", "seq_kv" if shard_seq else "null",
                          "null", "null"), ks.shape)
        return ks, ksh

    if cfg.family == "ssm":
        lspecs, lshard = _layer_specs(cfg, "ssm", rules)
        st = ssm_lib.ssm_state_specs(cfg, B, dt)
        stsh = ssm_lib.SSMState(
            conv=_ns(rules, ("batch", "null", "inner"), st.conv.shape),
            h=_ns(rules, ("batch", "inner", "null"), st.h.shape))

        def f(lp, x, st):
            h = L.apply_norm(lp["ln1"], x, cfg.norm)
            y, nst = ssm_lib.decode_ssm(lp["ssm"], h, cfg, st)
            return x + y, nst
        probes.append(Probe("layer_ssm", cfg.n_layers, f,
                            (lspecs, xspec, st), (lshard, xsh, stsh)))
    elif cfg.family == "hybrid":
        gspecs, gshard = _group_specs(cfg, rules)
        lru = rglru_lib.lru_state_specs(cfg, B, dt)
        lrush = rglru_lib.LRUState(
            conv=_ns(rules, ("batch", "null", "inner"), lru.conv.shape),
            h=_ns(rules, ("batch", "inner"), lru.h.shape))
        cache_len = min(S, cfg.hybrid.window)
        ks, ksh = kv_specs(cache_len, shard_seq=False)

        def fg(gp, x, st1, st2, kc, vc, length):
            def rec_one(lp, x, st):
                h = L.apply_norm(lp["ln1"], x, cfg.norm)
                y, nst = rglru_lib.decode_rglru(lp["rec"], h, st)
                x = x + y
                x, _ = tfm._apply_mlp(lp, cfg, x, rules)
                return x, nst
            x, n1 = rec_one(gp["rec1"], x, st1)
            x, n2 = rec_one(gp["rec2"], x, st2)
            x, nk, nv = tfm._decode_attn_layer(
                gp["attn"], cfg, x, kc, vc, length, None, rules,
                window=cfg.hybrid.window)
            x, _ = tfm._apply_mlp(gp["attn"], cfg, x, rules)
            return x, n1, n2, nk, nv
        probes.append(Probe("group", cfg.n_layers // 3, fg,
                            (gspecs, xspec, lru, lru, ks, ks, lenspec),
                            (gshard, xsh, lrush, lrush, ksh, ksh, lensh)))
        if cfg.n_layers % 3:
            lspecs, lshard = _layer_specs(cfg, "rec", rules)

            def ft(lp, x, st):
                h = L.apply_norm(lp["ln1"], x, cfg.norm)
                y, nst = rglru_lib.decode_rglru(lp["rec"], h, st)
                x = x + y
                x, _ = tfm._apply_mlp(lp, cfg, x, rules)
                return x, nst
            probes.append(Probe("tail_rec", cfg.n_layers % 3, ft,
                                (lspecs, xspec, lru),
                                (lshard, xsh, lrush)))
    else:
        kind = "attn" if cfg.family in ("dense", "vlm") else \
            ("moe" if cfg.family == "moe" else "attn")
        if cfg.family == "encdec":
            return _encdec_decode_probes(cfg, shape, rules, mesh)
        lspecs, lshard = _layer_specs(cfg, kind, rules)
        ks, ksh = kv_specs(S)

        def f(lp, x, kc, vc, length):
            x, nk, nv = tfm._decode_attn_layer(lp, cfg, x, kc, vc, length,
                                               mesh, rules)
            x, _ = tfm._apply_mlp(lp, cfg, x, rules)
            return x, nk, nv
        probes.append(Probe(f"layer_{kind}", cfg.n_layers, f,
                            (lspecs, xspec, ks, ks, lenspec),
                            (lshard, xsh, ksh, ksh, lensh)))
    probes.append(_embed_top_probe(cfg, rules, B, 1, train=False))
    return probes


def _encdec_decode_probes(cfg, shape, rules, mesh) -> List[Probe]:
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.cdtype
    dspecs, dshard = _enc_layer_specs(cfg, rules, decoder=True)
    xspec = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
    xsh = _ns(rules, ("batch", None, None), xspec.shape)
    ks = jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, cfg.head_dim_), dt)
    ksh = _ns(rules, ("batch", "seq_kv", "null", "null"), ks.shape)
    xk = jax.ShapeDtypeStruct((B, encdec_lib.N_FRAMES_PAD, cfg.n_kv_heads,
                               cfg.head_dim_), dt)
    xksh = _ns(rules, ("batch", "null", "null", "null"), xk.shape)
    lenspec = jax.ShapeDtypeStruct((B,), jnp.int32)
    lensh = _ns(rules, ("batch",), lenspec.shape)

    def f(lp, x, kc, vc, xkc, xvc, length):
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        q, k, v = attn_lib.qkv_proj(lp["attn"], h, length[:, None], 0.0)
        kc, vc = attn_lib.cache_update_local(kc, vc, k, v, length)
        if mesh is not None and "model" in mesh.axis_names:
            o = attn_lib.decode_attend_partitioned(q[:, 0], kc, vc,
                                                   length + 1, mesh)
        else:
            o = attn_lib.decode_attend_local(
                q[:, 0], kc, vc, jnp.arange(kc.shape[1]), length + 1)
        x = x + attn_lib.out_proj(lp["attn"], o[:, None])
        h = L.apply_norm(lp["ln_x"], x, cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", h,
                       lp["xattn"]["wq"].astype(h.dtype))
        o = attn_lib.decode_attend_local(
            q[:, 0], xkc, xvc, jnp.arange(xkc.shape[1]),
            jnp.full((B,), encdec_lib.N_FRAMES, jnp.int32))
        x = x + attn_lib.out_proj(lp["xattn"], o[:, None])
        x = encdec_lib._mlp_block(lp, cfg, x)
        return x, kc, vc
    probes = [Probe("dec_layer", cfg.n_layers, f,
                    (dspecs, xspec, ks, ks, xk, xk, lenspec),
                    (dshard, xsh, ksh, ksh, xksh, xksh, lensh))]
    probes.append(_embed_top_probe(cfg, rules, B, 1, train=False))
    return probes


def _embed_top_probe(cfg, rules, B, S, train: bool) -> Probe:
    box = {}

    def finit(k):
        p, a = {}, {}
        p["embed"], a["embed"] = L.init_embedding(
            k, L.pad_vocab(cfg.vocab), cfg.d_model, cfg.pdtype,
            cfg.tie_embeddings)
        p["final_norm"], a["final_norm"] = L.init_norm(
            cfg.pdtype, cfg.d_model, cfg.norm)
        box["axes"] = a
        return p
    specs = jax.eval_shape(finit, jax.random.PRNGKey(0))
    shard = jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                         rules.tree_specs(box["axes"], specs),
                         is_leaf=lambda x: isinstance(x, P))
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    tsh = _ns(rules, ("batch", None), tok.shape)

    def f(p, tokens):
        x = L.embed(p["embed"], tokens, cfg.cdtype, rules)
        h = L.apply_norm(p["final_norm"], x, cfg.norm)
        return L.unembed(p["embed"], h[:, -1].astype(jnp.float32),
                         cfg.vocab)
    return Probe("embed_top", 1, f, (specs, tok), (shard, tsh))


# ---------------------------------------------------------------------------
# analytic optimizer term (AdamW is elementwise: counted, not compiled)


def optimizer_analytic(n_params: int, chips: int) -> dict:
    """Per-device FLOPs/bytes for one AdamW update over 2-D-sharded state."""
    local = n_params / chips
    return {
        "flops": 12.0 * local,           # mul/add chain per element
        "bytes_accessed": (4 + 4 + 4 + 4) * local   # g,m,n read + p rw
        + (4 + 4 + 4) * local,
    }
