"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

NEG = -1e9


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: [BH, Sq, hd]; k, v: [BH, Skv, hd] -> [BH, Sq, hd]."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = kv_pos <= q_pos
    if window is not None:
        mask = mask & (kv_pos > q_pos - window)
    s = jnp.where(mask[None], s, NEG)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None], p, 0.0)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)) \
        / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    return out.astype(q.dtype)
