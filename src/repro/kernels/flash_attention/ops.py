"""Jitted wrapper: GQA folding + padding + CPU/TPU dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.contract import KernelContract, TileSpec
from repro.kernels.flash_attention.flash import (DEFAULT_KV_CHUNK,
                                                 DEFAULT_Q_TILE,
                                                 flash_attention_pallas_call)
from repro.kernels.flash_attention.ref import flash_attention_ref

#: static contract (DESIGN.md §7): canonical bh=8, Sq=Skv=256, hd=64
#: (a reduced-config prefill).  Not reachable from a dispatch table on
#: CPU — models/attention.attend is the XLA twin serving the reduced LM
#:  configs; this kernel is the TPU-native path.  No graph (B, Q), so the
#: planner-model check does not apply; footprint is bounded by VMEM only.
CONTRACTS = (
    KernelContract(
        name="flash_attention",
        module="repro.kernels.flash_attention.flash",
        grid=(8, 2),
        in_tiles=(TileSpec("q", (8, 256, 64), (None, 128, 64)),
                  TileSpec("k", (8, 256, 64), (None, 256, 64)),
                  TileSpec("v", (8, 256, 64), (None, 256, 64))),
        out_tiles=(TileSpec("o", (8, 256, 64), (None, 128, 64)),),
        wired=False,
        note="models/attention.attend is the XLA twin; this kernel is "
             "the TPU-native path for the same blocked online softmax"),
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def flash_attention(q, k, v, *, causal=True, window=None,
                    interpret=None):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,Hkv,hd] -> [B,Sq,H,hd].

    GQA: the group dim folds into batch*kv_heads; each program sees the
    q-rows of one kv-head's group against that head's KV.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    # [B,Hkv,g,Sq,hd] -> [B*Hkv, g*Sq, hd]: within a row-block, q rows of
    # the same kv-head share that head's KV
    qf = (q.transpose(0, 2, 1, 3).reshape(B, Hkv, g, Sq, hd)
          .reshape(B * Hkv, g * Sq, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)
    if g > 1:
        # causal positions must not leak across the folded group dim, so
        # run the kernel per group slice instead
        outs = []
        for gi in range(g):
            outs.append(_run(qf.reshape(B * Hkv, g, Sq, hd)[:, gi],
                             kf, vf, causal, window, interpret))
        of = jnp.stack(outs, axis=1)                  # [B*Hkv, g, Sq, hd]
    else:
        of = _run(qf, kf, vf, causal, window, interpret)[:, None]
    out = of.reshape(B, Hkv, g, Sq, hd).reshape(B, H, Sq, hd)
    return out.transpose(0, 2, 1, 3)


def _run(qf, kf, vf, causal, window, interpret):
    sq0 = qf.shape[1]
    qf, _ = _pad_to(qf, 1, DEFAULT_Q_TILE)
    kf, skv0 = _pad_to(kf, 1, DEFAULT_KV_CHUNK)
    vf, _ = _pad_to(vf, 1, DEFAULT_KV_CHUNK)
    out = flash_attention_pallas_call(qf, kf, vf, causal=causal,
                                      window=window, interpret=interpret,
                                      kv_len=skv0)
    return out[:, :sq0]
