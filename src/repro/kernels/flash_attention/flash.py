"""Pallas TPU flash attention (blocked online softmax).

The LM stack's training/prefill hot path.  XLA-level twin:
models/attention.attend (the chunked scan); this kernel is the TPU-native
version with explicit VMEM tiling:

  grid = (batch*kv_heads, Sq/QT)   one program per (bh, q-tile)
  q tile  [QT, hd]      VMEM (per program)
  k/v     [Skv, hd]     VMEM (whole-KV per program; one HBM->VMEM load is
                        amortized over all q-tiles of the head — the same
                        buffered-reuse argument as the paper's partition
                        residency, DESIGN.md §2)
  inner fori_loop over KV chunks of KC with the online-softmax carry.

GQA is handled in ops.py by folding the q-head group into the q-tile dim.
Causal masking uses absolute positions (q_offset + in-tile iota vs kv
chunk offset).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 128
DEFAULT_KV_CHUNK = 256
NEG = -1e9


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_chunk: int,
                  causal: bool, sq_total: int, window, kv_len: int):
    qt, hd = q_ref.shape
    skv = k_ref.shape[0]
    qi = pl.program_id(1)
    scale = 1.0 / (hd ** 0.5)
    q = q_ref[...].astype(jnp.float32) * scale          # [QT, hd]
    q_pos = qi * qt + jax.lax.broadcasted_iota(jnp.int32, (qt, 1), 0)
    n_chunks = skv // kv_chunk

    def body(ci, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_ref[...], (ci * kv_chunk, 0),
                                  (kv_chunk, hd)).astype(jnp.float32)
        v = jax.lax.dynamic_slice(v_ref[...], (ci * kv_chunk, 0),
                                  (kv_chunk, hd)).astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        kv_pos = ci * kv_chunk + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_chunk), 1)
        mask = jnp.broadcast_to(kv_pos < kv_len, (qt, kv_chunk))
        if causal:
            mask = mask & (kv_pos <= q_pos)
        if window is not None:
            mask = mask & (kv_pos > q_pos - window)
        s = jnp.where(mask, s, NEG)
        mj = jnp.max(s, axis=1)
        m_new = jnp.maximum(m, mj)
        r = jnp.exp(m - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l = l * r + jnp.sum(p, axis=1)
        acc = acc * r[:, None] + jnp.dot(p, v,
                                         preferred_element_type=jnp.float32)
        return m_new, l, acc

    m0 = jnp.full((qt,), NEG, jnp.float32)
    l0 = jnp.zeros((qt,), jnp.float32)
    a0 = jnp.zeros((qt, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "q_tile", "kv_chunk", "causal", "window", "interpret", "kv_len"))
def flash_attention_pallas_call(q, k, v, *, q_tile=DEFAULT_Q_TILE,
                                kv_chunk=DEFAULT_KV_CHUNK, causal=True,
                                window=None, interpret=True, kv_len=None):
    """q: [BH, Sq, hd]; k, v: [BH, Skv, hd] -> [BH, Sq, hd].

    Sq % q_tile == 0 and Skv % kv_chunk == 0 (ops.py pads).
    """
    bh, sq, hd = q.shape
    skv = k.shape[1]
    qt = min(q_tile, sq)
    kc = min(kv_chunk, skv)
    grid = (bh, sq // qt)
    return pl.pallas_call(
        functools.partial(_flash_kernel, kv_chunk=kc, causal=causal,
                          sq_total=sq, window=window,
                          kv_len=kv_len if kv_len is not None else skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, qt, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, skv, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, skv, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, qt, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
