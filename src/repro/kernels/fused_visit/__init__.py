"""Fused Pallas visit kernel: the whole Algorithm-2 visit in one VMEM residency."""
