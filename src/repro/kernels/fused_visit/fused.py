"""One Pallas kernel per partition visit: the fused Algorithm-2 body.

The XLA megastep (``core/visit._make_visit_body``) runs one visit as a
chain of separate ops — frontier consolidation, the relax ``while_loop``,
a vmapped neighbor ``contrib``, a segment-combine scatter, and the
metadata refresh — so the resident partition's ``[Q, B]`` state planes
round-trip HBM between every stage.  This kernel fuses the whole visit
into a single ``pallas_call`` (the paper's "process the partition to
completion while LLC-resident", PAPER.md §4, mapped to VMEM —
DESIGN.md §2.4):

  grid step 0        load the resident partition's packed state block
                     plus its whole adjacency row (diagonal + boundary
                     blocks), consolidate via ``frontier_tile``
                     (minplus) or the ``r += buf`` push begin, relax to
                     convergence / yield in an in-kernel
                     ``lax.while_loop`` built on ``minplus_tile`` /
                     ``push_tile``, then compute ALL neighbor
                     contributions and the full (visit + emission) edge
                     count in one batched shot — contributions park in
                     a VMEM scratch that persists across grid steps.
  grid steps 1..dmax one neighbor partition each: segment-combine the
                     parked contribution into the neighbor's buffer
                     channel (a read-modify-write through the aliased
                     output) and park the combined row for the refresh.
  grid step dmax     additionally refreshes every touched partition's
                     scheduler metadata in one batched scatter into the
                     (single-block) metadata plane.

The batching is the perf: emission work and the scheduler refresh are
O(a few ops) *total* instead of O(30 ops) per neighbor step — at bench
sizes the serialized per-step op dispatch dominates, exactly the
fork-processing overhead the paper's buffering amortizes.

Scalar-prefetched index vectors (``PrefetchScalarGridSpec``) steer each
grid step's state BlockSpec at the visited partition's rows, so only
the rows the visit actually touches move between HBM and VMEM.  Invalid
neighbor slots (the ``-1`` padding of ``dg.nbr_part``) are pointed at
the trash row ``P``, mirroring the XLA path's ``mode="drop"`` scatters
(every invalid slot writes the identical trash values, so duplicate
trash writes stay deterministic).

State is *packed* for the kernel (:meth:`FusedVisit.pack`):

  * the value planes and the buffer row ride as channels of one
    ``[P+1, C, Q, B]`` array (one fetch + one write-back per step
    instead of 2C + 2 of them);
  * all four metadata lanes pack into one int32 ``[P+1, 4]`` plane
    (priority and edge budget ride bit-cast — exact, bit-preserving),
    scheduled as a single block so the last grid step can refresh every
    touched row at once;
  * the per-partition adjacency row is pre-gathered as
    ``[P, 1+dmax, B+1, B]`` with the per-row edge counts folded in as
    row B of each block (exact in f32 below 2^24) — one resident
    operand instead of per-step block + nnz fetches;
  * the visit's round counter rides in lane 0 of the ``[1+Q]``
    edge-counter output.

``make_megastep`` keeps the packed form across a whole K-visit chunk
and unpacks once per dispatch.

Bit-parity with the XLA megastep oracle is by construction, not by
accident (pinned in ``tests/test_fused_visit.py``):

  * the inner-round math is expression-identical (``frontier_tile`` /
    ``push_tile`` vs ``minplus_algebra.begin`` / ``push_algebra.step``),
    and the relax contraction is an exact ``min`` (chunking reassociates
    it losslessly) resp. the very same ``algebra.contrib`` callable,
    vmapped over neighbor blocks exactly as the XLA emission vmaps it;
  * the emission mask is recovered from the relax result —
    ``isfinite(payload)`` ≡ the minplus emit set (an emitted row's value
    is always finite), ``payload > 0`` ≡ the push ``acc > 0`` mask —
    and the emission edge count is the XLA expression verbatim;
  * each neighbor row is written by exactly one grid step
    (``BlockGraph.from_csr`` guarantees unique, diagonal-free neighbor
    lists — validated here at build time), so per-row read-modify-write
    equals the XLA segment-combine scatter, and the batched metadata
    refresh observes the combined rows just as the XLA gather-refresh
    runs after the full scatter;
  * edge counters accumulate in int32, and integer addition is
    order-independent.

``frontier_mode="sparse"`` (minplus only) switches the relax/emission
contractions to ``minplus_tile(skip_inactive=True)``: late-round
frontiers leave most source columns at +inf, and a chunk of +inf sources
contributes only +inf to an exact min — skipped work, identical bits.
The skip predicate depends only on the (unbatched) payload, so it
survives the emission vmap as a genuine branch.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.minplus.minplus import minplus_tile

INF = jnp.inf
#: mirrors core.visit._BIG_STAMP (kernels/ must not import core/)
_BIG_STAMP = np.iinfo(np.int32).max - 1
SPARSE_U_CHUNK = 8

#: lanes of the packed int32 metadata plane
META_PRIO, META_BUDGET, META_OPS, META_STAMP = range(4)


def _f2i(x):
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _i2f(x):
    return jax.lax.bitcast_convert_type(x, jnp.float32)


class PackedState(NamedTuple):
    """Kernel-side layout of ``core.visit.VisitState`` (+ static budget).

    ``state[p, k]`` for ``k < num_planes`` is value plane k of partition
    p; channel ``num_planes`` is the buffered-ops row (row P = trash).
    ``meta[:, META_*]`` carries (priority, edge budget, op count, stamp)
    as int32 lanes; priority and budget are bit-cast f32.
    """
    state: jax.Array  # [P+1, C, Q, B] f32
    meta: jax.Array   # [P+1, 4] i32


class FusedVisit(NamedTuple):
    """The fused visit + the pack/unpack bridges to ``VisitState`` arrays.

    ``visit(packed, p, counter) -> (packed', rounds, eq)`` with ``eq`` the
    exact int32 per-query edge count of this visit.
    """
    pack: Callable
    visit: Callable
    unpack: Callable


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _validate_neighbor_lists(dg) -> None:
    """The RMW emission requires each neighbor row be visited exactly once.

    ``BlockGraph.from_csr`` builds ``nbr_part`` from unique off-diagonal
    (src, dst) partition pairs, so this holds by construction; a graph
    built some other way must satisfy it too or fall back to the XLA
    megastep (whose segment-combine scatter tolerates duplicates).
    """
    nbr = np.asarray(dg.nbr_part)
    P = dg.num_parts
    if (nbr == np.arange(P)[:, None]).any():
        raise ValueError(
            "fused visit: nbr_part contains self-edges — the resident "
            "partition's row is written at grid step 0; use the XLA "
            "megastep for graphs with diagonal neighbor entries")
    s = np.sort(nbr, axis=1)
    if ((s[:, 1:] == s[:, :-1]) & (s[:, 1:] >= 0)).any():
        raise ValueError(
            "fused visit: nbr_part contains duplicate neighbor entries — "
            "the per-row read-modify-write would double-apply them; use "
            "the XLA megastep (its scatter folds duplicates)")


def make_fused_visit(dg, algebra, max_rounds: int, *,
                     frontier: Callable, push: Callable,
                     frontier_mode: str = "dense",
                     u_chunk: Optional[int] = None,
                     interpret: Optional[bool] = None) -> FusedVisit:
    """Build the fused visit for one device graph + algebra.

    ``frontier`` / ``push`` are the kernel-safe tile ops
    (``kernels.frontier.ops.frontier_tile``,
    ``kernels.ppr_push.ops.push_tile``) — passed in by
    ``core/visit.make_megastep`` so the dispatch wiring lives in the
    dispatch table, not in a kernels-internal import.

    ``u_chunk`` chunks the in-kernel minplus contraction; it defaults to
    one full-width chunk for the dense frontier (fewest ops, same bits)
    and to ``SPARSE_U_CHUNK`` for the sparse mode (the skip granularity).
    """
    name = algebra.name
    if name not in ("minplus", "push"):
        raise ValueError(f"fused visit: unknown algebra {name!r}")
    if frontier_mode not in ("dense", "sparse"):
        raise ValueError(f"unknown frontier_mode {frontier_mode!r}; "
                         f"one of ('dense', 'sparse')")
    if frontier_mode == "sparse" and name != "minplus":
        raise ValueError(
            "sparse frontier mode skips all-inf source chunks of an exact "
            "min — only the minplus algebra has that identity; push-mode "
            "PPR runs dense")
    _validate_neighbor_lists(dg)
    if interpret is None:
        interpret = not _on_tpu()
    sparse = frontier_mode == "sparse"
    np_ = algebra.num_planes
    C = np_ + 1
    P = dg.num_parts
    B = dg.block_size
    dmax = dg.nbr_part.shape[1]
    if u_chunk is None:
        u_chunk = SPARSE_U_CHUNK if sparse else B
    window = algebra.param("window") if name == "minplus" else 0.0
    strict = (bool(dict(algebra.params).get("strict", 0.0))
              if name == "minplus" else False)
    alpha = algebra.param("alpha") if name == "push" else 0.0
    eps = algebra.param("eps") if name == "push" else 0.0
    combine = algebra.combine
    contrib = algebra.contrib
    prio_of = algebra.prio_of
    #: the plane the metadata refresh reads (minplus: dist; push: r —
    #: emission leaves both unchanged, so parking them per step is exact)
    prio_plane = 0 if name == "minplus" else 1
    budget_pad = jnp.concatenate(
        [dg.edge_budget, jnp.zeros((1,), jnp.float32)]).astype(jnp.float32)
    #: per-partition adjacency row [P, 1+dmax, B+1, B]: slot 0 the diagonal
    #: block, slots 1.. the boundary blocks (invalid slots zeroed), with the
    #: per-row edge counts folded in as row B (exact in f32 below 2^24)
    w_aug = jnp.concatenate(
        [dg.blocks, dg.row_nnz[:, None, :].astype(jnp.float32)], axis=1)
    nbr_blk = np.asarray(dg.nbr_blk)
    slot_valid = np.asarray(dg.nbr_part) >= 0
    gather = np.concatenate(
        [np.asarray(dg.diag_blk)[:, None],
         np.where(slot_valid, nbr_blk, 0)], axis=1)          # [P, 1+dmax]
    w_vis = (w_aug[jnp.asarray(gather)]
             * jnp.asarray(np.concatenate(
                 [np.ones((P, 1)), slot_valid], axis=1),
                 jnp.float32)[:, :, None, None])
    deg_pad = jnp.concatenate(
        [dg.deg, jnp.zeros((1, B), dg.deg.dtype)])
    sdx = 1 + dmax  # scratch slot 0 = the resident row, 1.. = neighbors

    def kernel(rowb_ref, vld_ref, cnt_ref,
               state_ref, meta_ref, w_ref, deg_ref,
               o_state_ref, o_meta_ref, o_req_ref,
               cand_scr, plane_scr, deg_scr):
        i = pl.program_id(0)
        cnt = cnt_ref[0]
        deg_row = deg_ref[0]

        @pl.when(i == 0)
        def _visit():
            w_all = w_ref[0]          # [1+dmax, B+1, B], the adjacency row
            w_blk = w_all[0, :B]
            nnz_row = w_all[0, B].astype(jnp.int32)
            p_own = rowb_ref[0]
            budget = _i2f(o_meta_ref[p_own, META_BUDGET])
            buf_row = state_ref[0, np_]
            eq0 = jnp.zeros((buf_row.shape[0],), jnp.int32)
            if name == "minplus":
                d0 = state_ref[0, 0]
                d1, _, alpha0, pending0, _ = frontier(buf_row, d0,
                                                      delta=window,
                                                      strict=strict)

                def act_of(d, pending, eq):
                    return (pending & (d <= alpha0 + window)
                            & (eq.astype(jnp.float32) < budget)[:, None])

                def cond(c):
                    d, pending, emit, eq, rounds = c
                    return jnp.logical_and(
                        rounds < max_rounds,
                        jnp.any(act_of(d, pending, eq)))

                def body(c):
                    d, pending, emit, eq, rounds = c
                    act = act_of(d, pending, eq)
                    eq = eq + jnp.sum(jnp.where(act, nnz_row[None, :], 0),
                                      axis=1, dtype=jnp.int32)
                    srcs = jnp.where(act, d, INF)
                    nd = minplus_tile(srcs, w_blk, u_chunk=u_chunk,
                                      skip_inactive=sparse)
                    improved = nd < d
                    return (jnp.minimum(d, nd),
                            (pending & ~act) | improved,
                            emit | act, eq, rounds + 1)

                d, pending, emit, eq, rounds = jax.lax.while_loop(
                    cond, body, (d1, pending0, jnp.zeros_like(pending0),
                                 eq0, jnp.int32(0)))
                payload = jnp.where(emit, d, INF)
                keep = jnp.where(pending, d, INF)
                new_planes = (d,)
                emask = emit
                identity = INF
            else:
                p0, r0 = state_ref[0, 0], state_ref[0, 1]
                degf = deg_row.astype(jnp.float32)
                degc = jnp.maximum(degf, 1.0)
                has_edges = degf > 0

                def act_of(r, eq):
                    return ((r >= eps * degc) & has_edges
                            & (eq.astype(jnp.float32) < budget)[:, None])

                def cond(c):
                    pv, rv, av, eq, rounds = c
                    return jnp.logical_and(rounds < max_rounds,
                                           jnp.any(act_of(rv, eq)))

                def body(c):
                    pv, rv, av, eq, rounds = c
                    lane = (eq.astype(jnp.float32) < budget)[:, None]
                    pv, rv, av, act = push(pv, rv, av, w_blk, degf,
                                           alpha=alpha, eps=eps,
                                           lane_mask=lane, spread=contrib)
                    eq = eq + jnp.sum(jnp.where(act, nnz_row[None, :], 0),
                                      axis=1, dtype=jnp.int32)
                    return pv, rv, av, eq, rounds + 1

                pv, rv, av, eq, rounds = jax.lax.while_loop(
                    cond, body, (p0, r0 + buf_row, jnp.zeros_like(r0),
                                 eq0, jnp.int32(0)))
                payload = av
                keep = jnp.zeros_like(rv)
                new_planes = (pv, rv)
                emask = av > 0
                identity = 0.0

            # ---- batched emission prep: every neighbor contribution and
            # the full emission edge count in one shot (the XLA megastep's
            # vmapped emission, run inside the kernel) ----
            if dmax > 0:
                valid = vld_ref[1:] > 0
                w_nb = w_all[1:, :B]
                nnz_sl = jnp.where(valid[:, None],
                                   w_all[1:, B].astype(jnp.int32), 0)
                if name == "minplus":
                    cands = jax.vmap(
                        lambda w: minplus_tile(payload, w, u_chunk=u_chunk,
                                               skip_inactive=sparse))(w_nb)
                else:
                    cands = jax.vmap(lambda w: contrib(payload, w))(w_nb)
                cand_scr[0] = keep
                plane_scr[0] = new_planes[prio_plane]
                deg_scr[0] = deg_row
                cand_scr[1:] = jnp.where(valid[:, None, None], cands,
                                         identity)
                eq = eq + jnp.sum(
                    jnp.where(emask[None], nnz_sl[:, None, :], 0),
                    axis=(0, 2), dtype=jnp.int32)
            else:
                # no neighbors: no refresh step rides behind this one, so
                # the visited row's metadata is updated here
                own_prio, own_ops = prio_of(keep, new_planes, deg_row)
                m = o_meta_ref[...]
                m = m.at[p_own].set(jnp.stack(
                    [_f2i(own_prio), m[p_own, META_BUDGET], own_ops,
                     jnp.where(jnp.isfinite(own_prio), cnt,
                               jnp.int32(_BIG_STAMP))]))
                o_meta_ref[...] = m

            for k in range(np_):
                o_state_ref[0, k] = new_planes[k]
            o_state_ref[0, np_] = keep
            o_req_ref[...] = jnp.concatenate([rounds[None], eq])

        if dmax > 0:
            @pl.when(i > 0)
            def _emit():
                # RMW through the aliased output: the out-block is fetched
                # from the *current* output array each grid step, so it
                # holds the neighbor's visit-start row (never written
                # earlier — neighbor lists are unique and diagonal-free).
                new_buf = combine(o_state_ref[0, np_], cand_scr[i])
                o_state_ref[0, np_] = new_buf
                cand_scr[i] = new_buf
                plane_scr[i] = state_ref[0, prio_plane]
                deg_scr[i] = deg_row

            @pl.when(i == dmax)
            def _refresh():
                # batched scheduler refresh over the visited row (slot 0)
                # and every touched neighbor — runs after the last combine,
                # so it observes the combined rows exactly like the XLA
                # gather-after-scatter refresh
                idx = rowb_ref[...]
                bufs = cand_scr[...]
                pln = plane_scr[...]
                degs = deg_scr[...]
                if name == "minplus":
                    newprio, newops = jax.vmap(
                        lambda b, d, g: prio_of(b, (d,), g))(bufs, pln, degs)
                else:  # push prio_of only reads the residual plane
                    newprio, newops = jax.vmap(
                        lambda b, r, g: prio_of(b, (r, r), g))(bufs, pln,
                                                               degs)
                m = o_meta_ref[...]
                fin = jnp.isfinite(newprio)
                was_empty = ~jnp.isfinite(_i2f(m[idx, META_PRIO]))
                # slot 0 (the visited row) stamps unconditionally; neighbor
                # rows keep their stamp unless the buffer was empty before
                own = jnp.arange(1 + dmax) == 0
                stamp = jnp.where(
                    own, jnp.where(fin, cnt, jnp.int32(_BIG_STAMP)),
                    jnp.where(was_empty & fin, cnt, m[idx, META_STAMP]))
                rows = jnp.stack([_f2i(newprio), m[idx, META_BUDGET],
                                  newops, stamp], axis=1)
                o_meta_ref[...] = m.at[idx].set(rows)

    def pack(planes: Tuple[jax.Array, ...], buf: jax.Array,
             prio: jax.Array, ops_count: jax.Array,
             stamp: jax.Array) -> PackedState:
        zrow = jnp.zeros((1,) + buf.shape[1:], buf.dtype)
        state = jnp.stack(
            [jnp.concatenate([x, zrow]) for x in planes] + [buf], axis=1)
        meta = jnp.stack(
            [_f2i(jnp.concatenate(
                [prio.astype(jnp.float32),
                 jnp.full((1,), jnp.inf, jnp.float32)])),
             _f2i(budget_pad),
             jnp.concatenate([ops_count.astype(jnp.int32),
                              jnp.zeros((1,), jnp.int32)]),
             jnp.concatenate([stamp.astype(jnp.int32),
                              jnp.full((1,), _BIG_STAMP, jnp.int32)])],
            axis=1)
        return PackedState(state, meta)

    def unpack(pk: PackedState):
        planes = tuple(pk.state[:P, k] for k in range(np_))
        buf = pk.state[:, np_]
        return (planes, buf, _i2f(pk.meta[:P, META_PRIO]),
                pk.meta[:P, META_OPS], pk.meta[:P, META_STAMP])

    @jax.jit
    def visit(pk: PackedState, p, counter):
        Q = pk.state.shape[2]
        p = jnp.asarray(p, jnp.int32)
        parts = dg.nbr_part[p]
        valid = parts >= 0
        rowb = jnp.concatenate(
            [p[None], jnp.where(valid, parts, P)]).astype(jnp.int32)
        vld = jnp.concatenate(
            [jnp.ones((1,), jnp.int32), valid.astype(jnp.int32)])
        cnt = jnp.asarray(counter, jnp.int32)[None]

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(1 + dmax,),
            in_specs=[
                pl.BlockSpec((1, C, Q, B),
                             lambda i, rb, v, c: (rb[i], 0, 0, 0)),
                pl.BlockSpec((P + 1, 4), lambda i, rb, v, c: (0, 0)),
                pl.BlockSpec((1, 1 + dmax, B + 1, B),
                             lambda i, rb, v, c: (rb[0], 0, 0, 0)),
                pl.BlockSpec((1, B), lambda i, rb, v, c: (rb[i], 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, C, Q, B),
                             lambda i, rb, v, c: (rb[i], 0, 0, 0)),
                pl.BlockSpec((P + 1, 4), lambda i, rb, v, c: (0, 0)),
                pl.BlockSpec((1 + Q,), lambda i, rb, v, c: (0,)),
            ],
            scratch_shapes=[pltpu.VMEM((sdx, Q, B), jnp.float32),
                            pltpu.VMEM((sdx, Q, B), jnp.float32),
                            pltpu.VMEM((sdx, B), deg_pad.dtype)],
        )
        state, meta, req = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct(pk.state.shape, pk.state.dtype),
                jax.ShapeDtypeStruct(pk.meta.shape, pk.meta.dtype),
                jax.ShapeDtypeStruct((1 + Q,), jnp.int32),
            ],
            input_output_aliases={3: 0, 4: 1},
            interpret=interpret,
        )(rowb, vld, cnt, pk.state, pk.meta, w_vis, deg_pad)
        return PackedState(state, meta), req[0], req[1:]

    return FusedVisit(pack=pack, visit=visit, unpack=unpack)
