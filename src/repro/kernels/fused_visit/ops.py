"""Dispatch + static contracts for the fused Pallas visit kernel.

``core/visit.make_megastep(fused=True)`` imports :func:`make_fused_visit`
from here and hands it the ``frontier_tile`` / ``push_tile`` inner ops —
the dispatch table stays in ``core/``, the VMEM choreography stays here.

Two canonical contracts are declared, one per algebra, on the tier-1
canonical graph instantiation (grid2d 16x16, B = 64 -> P = 4 partitions,
dmax = 2 neighbor slots, Q = 64 query lanes).  The kernel runs over the
*packed* state layout (``fused.PackedState``):

  * ``state`` [P+1, C, Q, B] f32 — the C = num_planes+1 value planes plus
    the buffered-ops row as channels of one array (row P = trash), so a
    grid step schedules ONE state fetch + ONE write-back instead of 2C+2
    of them — the packing is the perf, not a convenience.  The output is
    aliased onto the input and read-modify-written at scalar-prefetched
    row indices (``update="rmw"``: the index map owns coverage);
  * ``meta``  [P+1, 4] int32 — the full scheduler table (priority and
    edge budget bitcast f32<->i32, op count, stamp) as ONE whole-array
    block, refreshed in a single batched scatter on the last grid step
    (``update="accum"``: one block, not a tiling);
  * ``w``     [P, 1+dmax, B+1, B] — the visited partition's pre-gathered
    adjacency row: the diagonal block plus its boundary blocks, with the
    per-row edge counts folded in as row B (exact in f32 below 2^24), so
    emission needs no second nnz operand;
  * ``req``   [1+Q] int32 — the visit's round counter (lane 0) and the
    exact per-query edge counters (``update="accum"``).

The footprint is checked against ``MemoryModel.fused_working_set``
(``fused_model=True``): a fused visit holds every state channel *and*
the per-slot emission parking scratch (two [Q, B] planes + a degree row
per slot, ``pltpu.VMEM``) resident at once, which is the point.  The
scratch rides on top of the BlockSpec footprint; ``fused_working_set``
budgets it explicitly.
"""
from __future__ import annotations

from repro.kernels.contract import KernelContract, TileSpec
from repro.kernels.fused_visit.fused import make_fused_visit

_META = dict(full=(5, 4), block=(5, 4))

CONTRACTS = (
    KernelContract(
        name="fused_visit_minplus",
        module="repro.kernels.fused_visit.fused",
        grid=(3,),                       # 1 resident visit + dmax=2 emits
        in_tiles=(TileSpec("state", (5, 2, 64, 64), (1, 2, 64, 64)),
                  TileSpec("meta", **_META),
                  TileSpec("w", (4, 3, 65, 64), (1, 3, 65, 64)),
                  TileSpec("deg", (5, 64), (1, 64))),
        out_tiles=(TileSpec("state1", (5, 2, 64, 64), (1, 2, 64, 64),
                            update="rmw"),
                   TileSpec("meta1", **_META, update="accum"),
                   TileSpec("req", (65,), (65,), update="accum")),
        wired=True, block_size=64, num_queries=64,
        fused_model=True, num_planes=1),
    KernelContract(
        name="fused_visit_push",
        module="repro.kernels.fused_visit.fused",
        grid=(3,),
        in_tiles=(TileSpec("state", (5, 3, 64, 64), (1, 3, 64, 64)),
                  TileSpec("meta", **_META),
                  TileSpec("w", (4, 3, 65, 64), (1, 3, 65, 64)),
                  TileSpec("deg", (5, 64), (1, 64))),
        out_tiles=(TileSpec("state1", (5, 3, 64, 64), (1, 3, 64, 64),
                            update="rmw"),
                   TileSpec("meta1", **_META, update="accum"),
                   TileSpec("req", (65,), (65,), update="accum")),
        wired=True, block_size=64, num_queries=64,
        fused_model=True, num_planes=2),
)

__all__ = ["CONTRACTS", "make_fused_visit"]
