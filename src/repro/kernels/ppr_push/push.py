"""Pallas TPU kernel: one fused PPR push round over a VMEM-resident block.

The push-mode engine (engine.make_push_visit) does, per inner round:

    active = (r >= eps*deg) & has_edges
    p     += alpha * r * active
    push   = (1-alpha) * r * active / deg
    r      = r*(1-active) + push @ A_mask
    acc   += push

Unfused, that is 5 HBM round-trips over [Q, B] tensors; fused here the
tile is loaded once (DESIGN.md §2 — the VMEM-residency argument).  The
spread matmul runs on the MXU via the finite-mask of the weight block.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def push_tile(p: jax.Array, r: jax.Array, acc: jax.Array, w: jax.Array,
              deg: jax.Array, *, alpha: float, eps: float,
              lane_mask: Optional[jax.Array] = None,
              spread: Optional[Callable] = None,
              ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One PPR push round over a resident tile, kernel-safe.

    ``p, r, acc``: [QT, B]; ``w``: [B, B] (+inf = absent); ``deg``: [B] or
    broadcastable float row.  Returns ``(p1, r1, acc1, active)``.

    ``lane_mask`` (bool, broadcastable to [QT, B]) further gates the
    active set — the fused visit kernel (DESIGN.md §2.4) passes the
    per-query edge-budget lane there.  ``spread`` replaces the default
    masked matmul (``push @ finite(w)``): the fused path passes the
    algebra's ``contrib`` so both paths run the identical f32 contraction
    and stay bit-identical to the XLA megastep.
    """
    deg = jnp.asarray(deg, r.dtype)
    degc = jnp.maximum(deg, 1.0)
    has_edges = deg > 0
    active = (r >= eps * degc) & has_edges
    if lane_mask is not None:
        active = active & lane_mask
    af = active.astype(r.dtype)
    p1 = p + alpha * r * af
    push = (1.0 - alpha) * r * af / degc
    if spread is None:
        mask = jnp.isfinite(w).astype(r.dtype)
        sp = jnp.dot(push, mask, preferred_element_type=r.dtype)
    else:
        sp = spread(push, w)
    r1 = r * (1.0 - af) + sp
    acc1 = acc + push
    return p1, r1, acc1, active


def _push_kernel(p_ref, r_ref, acc_ref, w_ref, deg_ref, o_p, o_r, o_acc,
                 *, alpha: float, eps: float):
    p1, r1, acc1, _ = push_tile(p_ref[...], r_ref[...], acc_ref[...],
                                w_ref[...], deg_ref[...],
                                alpha=alpha, eps=eps)
    o_p[...] = p1
    o_r[...] = r1
    o_acc[...] = acc1


@functools.partial(jax.jit, static_argnames=("alpha", "eps", "q_tile",
                                             "interpret"))
def ppr_push_pallas_call(p, r, acc, w, deg, *, alpha: float, eps: float,
                         q_tile: int = DEFAULT_Q_TILE,
                         interpret: Optional[bool] = None):
    """p, r, acc: [Q, B]; w: [B, B] (+inf absent); deg: [1, B] float.

    ``interpret=None`` follows the ``_on_tpu()`` autodetect the ``ops.py``
    wrapper uses, so a direct call can't silently run interpreted on TPU."""
    if interpret is None:
        interpret = not _on_tpu()
    q, b = p.shape
    qt = min(q_tile, q) if q % min(q_tile, q) == 0 else q
    grid = (q // qt,)
    tile = pl.BlockSpec((qt, b), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_push_kernel, alpha=alpha, eps=eps),
        grid=grid,
        in_specs=[tile, tile, tile,
                  pl.BlockSpec((b, b), lambda i: (0, 0)),
                  pl.BlockSpec((1, b), lambda i: (0, 0))],
        out_specs=[tile, tile, tile],
        out_shape=[jax.ShapeDtypeStruct((q, b), p.dtype)] * 3,
        interpret=interpret,
    )(p, r, acc, w, deg)
