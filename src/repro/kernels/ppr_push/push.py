"""Pallas TPU kernel: one fused PPR push round over a VMEM-resident block.

The push-mode engine (engine.make_push_visit) does, per inner round:

    active = (r >= eps*deg) & has_edges
    p     += alpha * r * active
    push   = (1-alpha) * r * active / deg
    r      = r*(1-active) + push @ A_mask
    acc   += push

Unfused, that is 5 HBM round-trips over [Q, B] tensors; fused here the
tile is loaded once (DESIGN.md §2 — the VMEM-residency argument).  The
spread matmul runs on the MXU via the finite-mask of the weight block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 128


def _push_kernel(p_ref, r_ref, acc_ref, w_ref, deg_ref, o_p, o_r, o_acc,
                 *, alpha: float, eps: float):
    p = p_ref[...]                       # [QT, B]
    r = r_ref[...]
    acc = acc_ref[...]
    deg = deg_ref[...]                   # [1, B]
    degc = jnp.maximum(deg, 1.0)
    has_edges = deg > 0
    active = (r >= eps * degc) & has_edges
    af = active.astype(r.dtype)
    o_p[...] = p + alpha * r * af
    push = (1.0 - alpha) * r * af / degc
    mask = jnp.isfinite(w_ref[...]).astype(r.dtype)
    spread = jnp.dot(push, mask, preferred_element_type=r.dtype)
    o_r[...] = r * (1.0 - af) + spread
    o_acc[...] = acc + push


@functools.partial(jax.jit, static_argnames=("alpha", "eps", "q_tile",
                                             "interpret"))
def ppr_push_pallas_call(p, r, acc, w, deg, *, alpha: float, eps: float,
                         q_tile: int = DEFAULT_Q_TILE,
                         interpret: bool = True):
    """p, r, acc: [Q, B]; w: [B, B] (+inf absent); deg: [1, B] float."""
    q, b = p.shape
    qt = min(q_tile, q) if q % min(q_tile, q) == 0 else q
    grid = (q // qt,)
    tile = pl.BlockSpec((qt, b), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_push_kernel, alpha=alpha, eps=eps),
        grid=grid,
        in_specs=[tile, tile, tile,
                  pl.BlockSpec((b, b), lambda i: (0, 0)),
                  pl.BlockSpec((1, b), lambda i: (0, 0))],
        out_specs=[tile, tile, tile],
        out_shape=[jax.ShapeDtypeStruct((q, b), p.dtype)] * 3,
        interpret=interpret,
    )(p, r, acc, w, deg)
