"""Jitted wrapper for the fused PPR push kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.contract import KernelContract, TileSpec
from repro.kernels.ppr_push.push import ppr_push_pallas_call, push_tile
from repro.kernels.ppr_push.ref import ppr_push_ref

#: static contract (DESIGN.md §7): canonical B=64 instantiation, tiled
#: q_tile=16 so the per-step footprint (three state planes in and out
#: plus the weight block) stays inside the planner model's working set.
#: Wired: ``push_tile`` is the inner-round body of the fused visit kernel
#: (core/visit.make_megastep(fused=True)) for push-mode PPR, and the
#: standalone pallas_call remains callable directly.
CONTRACTS = (
    KernelContract(
        name="ppr_push", module="repro.kernels.ppr_push.push",
        grid=(4,),
        in_tiles=(TileSpec("p", (64, 64), (16, 64)),
                  TileSpec("r", (64, 64), (16, 64)),
                  TileSpec("acc", (64, 64), (16, 64)),
                  TileSpec("w", (64, 64), (64, 64)),
                  TileSpec("deg", (1, 64), (1, 64))),
        out_tiles=(TileSpec("p1", (64, 64), (16, 64)),
                   TileSpec("r1", (64, 64), (16, 64)),
                   TileSpec("acc1", (64, 64), (16, 64))),
        wired=True,
        block_size=64, num_queries=64),
)

__all__ = ["CONTRACTS", "ppr_push", "ppr_push_pallas", "push_tile"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ppr_push(p, r, acc, w, deg, *, alpha: float, eps: float):
    return ppr_push_ref(p, r, acc, w, deg, alpha=alpha, eps=eps)


def ppr_push_pallas(p, r, acc, w, deg, *, alpha: float, eps: float,
                    interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    if deg.ndim == 1:
        deg = deg[None, :]
    deg = deg.astype(p.dtype)
    q = p.shape[0]
    pad = (-q) % 8
    if pad:
        widths = [(0, pad), (0, 0)]
        p, r, acc = (jnp.pad(x, widths) for x in (p, r, acc))
    po, ro, ao = ppr_push_pallas_call(p, r, acc, w, deg, alpha=alpha,
                                      eps=eps, interpret=interpret)
    return po[:q], ro[:q], ao[:q]
