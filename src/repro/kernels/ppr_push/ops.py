"""Jitted wrapper for the fused PPR push kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ppr_push.push import ppr_push_pallas_call
from repro.kernels.ppr_push.ref import ppr_push_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ppr_push(p, r, acc, w, deg, *, alpha: float, eps: float):
    return ppr_push_ref(p, r, acc, w, deg, alpha=alpha, eps=eps)


def ppr_push_pallas(p, r, acc, w, deg, *, alpha: float, eps: float,
                    interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    if deg.ndim == 1:
        deg = deg[None, :]
    deg = deg.astype(p.dtype)
    q = p.shape[0]
    pad = (-q) % 8
    if pad:
        widths = [(0, pad), (0, 0)]
        p, r, acc = (jnp.pad(x, widths) for x in (p, r, acc))
    po, ro, ao = ppr_push_pallas_call(p, r, acc, w, deg, alpha=alpha,
                                      eps=eps, interpret=interpret)
    return po[:q], ro[:q], ao[:q]
