"""Pure-jnp oracle for the fused PPR push kernel."""
from __future__ import annotations

import jax.numpy as jnp


def ppr_push_ref(p, r, acc, w, deg, *, alpha: float, eps: float):
    degc = jnp.maximum(deg, 1.0)
    has_edges = deg > 0
    active = (r >= eps * degc) & has_edges
    af = active.astype(r.dtype)
    p_out = p + alpha * r * af
    push = (1.0 - alpha) * r * af / degc
    mask = jnp.isfinite(w).astype(r.dtype)
    spread = push @ mask
    r_out = r * (1.0 - af) + spread
    return p_out, r_out, acc + push
