"""Static kernel contracts: what each Pallas kernel promises about VMEM.

Every kernel package's ``ops.py`` declares a :class:`KernelContract` — the
grid and BlockSpec tiling of a *canonical instantiation* (the shapes the
engine's planner actually produces), as plain data.  The fppcheck Pallas
pass (DESIGN.md §7) validates the contracts without tracing anything:

  * tile divisibility — every full dim divides into whole blocks (the
    property ``minplus._tile`` enforces at runtime, checked statically);
  * grid coverage — the grid writes each output element exactly once;
  * memory-model coverage — the per-grid-step footprint (sum of all
    in/out tiles) stays within ``fpp.planner.MemoryModel.covers`` for the
    contract's (block_size, num_queries), for *wired* kernels.  The
    footprint counts BlockSpec tiles, i.e. the HBM<->VMEM transfers the
    grid schedules — kernel-internal ``fori_loop`` temporaries are the
    kernel author's budget, not the planner's.

``wired=False`` declares a kernel not yet reachable from any dispatch
table; the reachability pass cross-checks that claim against the import
graph and demands a ``note`` naming the plan for it (ROADMAP fusion item,
an XLA twin, ...) so dead code is always an *explicit* ruling.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Optional, Tuple

#: kernel packages that must publish a CONTRACT in their ops module
KERNEL_PACKAGES = ("minplus", "frontier", "ppr_push", "fused_visit",
                   "flash_attention")


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """One operand's tiling: the full array and the per-program block.

    ``block`` entries of ``None`` mirror ``pl.BlockSpec`` squeezed dims
    (the program sees the dim collapsed away); they tile the full dim in
    steps of 1.

    ``update`` declares the write discipline of an *output* tile, which
    decides the coverage rule the contract pass applies:

      ``"once"``  every element written by exactly one program — the grid
                  must tile the full array (``num_blocks == grid_size``);
      ``"rmw"``   scalar-prefetch scatter: programs read-modify-write
                  aliased rows, possibly revisiting or skipping blocks —
                  coverage is the index map's job, not the tiling's;
      ``"accum"`` every program accumulates into the same single block
                  (``num_blocks == 1``, e.g. the fused visit's edge
                  counters).
    """
    name: str
    full: Tuple[int, ...]
    block: Tuple[Optional[int], ...]
    dtype_bytes: int = 4
    update: str = "once"

    def block_elems(self) -> int:
        return math.prod((b or 1) for b in self.block)

    def block_bytes(self) -> int:
        return self.block_elems() * self.dtype_bytes

    def num_blocks(self) -> int:
        """Distinct blocks tiling the full array (for coverage checks)."""
        return math.prod(f // (b or 1) for f, b in zip(self.full, self.block))

    def divisible(self) -> bool:
        return (len(self.full) == len(self.block)
                and all(f % (b or 1) == 0
                        for f, b in zip(self.full, self.block)))


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """The canonical instantiation of one Pallas kernel, as static data."""
    name: str                         # kernel package name, e.g. "minplus"
    module: str                       # pallas module the grid comes from
    grid: Tuple[int, ...]
    in_tiles: Tuple[TileSpec, ...]
    out_tiles: Tuple[TileSpec, ...]
    wired: bool                       # reachable from a dispatch table?
    note: str = ""                    # for unwired kernels: the ruling
    block_size: Optional[int] = None  # B of the canonical graph instantiation
    num_queries: Optional[int] = None  # Q of same; None for LM kernels
    #: fused-visit kernels hold np state planes + the scatter fan-out in
    #: VMEM at once; the contract pass then checks the footprint against
    #: ``MemoryModel.fused_working_set`` instead of ``working_set``.
    fused_model: bool = False
    num_planes: Optional[int] = None  # np of the fused instantiation

    @property
    def tiles(self) -> Tuple[TileSpec, ...]:
        return self.in_tiles + self.out_tiles

    def grid_size(self) -> int:
        return math.prod(self.grid)

    def footprint_bytes(self) -> int:
        """Per-grid-step VMEM bytes the BlockSpecs schedule."""
        return sum(t.block_bytes() for t in self.tiles)


def all_contracts() -> Tuple[KernelContract, ...]:
    """Collect every kernel package's declared contract(s)."""
    out = []
    for pkg in KERNEL_PACKAGES:
        ops = importlib.import_module(f"repro.kernels.{pkg}.ops")
        contracts = getattr(ops, "CONTRACTS", None)
        if contracts is None:
            raise RuntimeError(
                f"repro.kernels.{pkg}.ops declares no CONTRACTS — every "
                f"kernel package must publish its static contract "
                f"(DESIGN.md §7)")
        out.extend(contracts)
    return tuple(out)
