"""Pure-jnp oracle for the frontier kernel."""
from __future__ import annotations

import jax.numpy as jnp

INF = jnp.inf


def frontier_ref(buf, dist, *, delta: float):
    pending = jnp.isfinite(buf) & (buf <= dist)
    d1 = jnp.minimum(dist, jnp.where(pending, buf, INF))
    alpha = jnp.min(jnp.where(pending, d1, INF), axis=1, keepdims=True)
    active = pending & (d1 <= alpha + delta)
    srcs = jnp.where(active, d1, INF)
    return d1, srcs, alpha[:, 0]
