"""Pallas TPU kernel for the Δ-window frontier selection + priority reduce.

One partition visit (engine.py) starts by consolidating the buffer into the
distance state and finding (a) which (query, vertex) ops are active under
the Δ-window / yielding rules and (b) the partition's next priority value.
Fused here so the [Q, B] buffer tile makes one HBM->VMEM trip:

    pending = isfinite(buf) & (buf <= dist)
    d1      = min(dist, buf)
    alpha_q = min_v (pending ? d1 : inf)            per-query best
    active  = pending & (d1 <= alpha_q + delta)
    srcs    = active ? d1 : inf
    prio    = min over tile of alpha_q              (SMEM scalar out)

Grid over query tiles; outputs (d1, srcs, per-tile prio row).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 128
INF = jnp.inf


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def frontier_tile(buf: jax.Array, dist: jax.Array, *, delta: float,
                  strict: bool = False,
                  ) -> Tuple[jax.Array, jax.Array, jax.Array,
                             jax.Array, jax.Array]:
    """Δ-window frontier math over one resident [QT, B] tile, kernel-safe.

    Returns ``(d1, srcs, alpha, pending, active)`` with ``alpha`` kept
    [QT, 1] so the fused visit kernel (DESIGN.md §2.4) can re-derive the
    active set each inner round.  Expression-for-expression identical to
    the XLA ``minplus_algebra.begin`` math in ``core/visit.py`` — the
    basis for the fused path's bit-parity with the megastep oracle.
    ``strict`` mirrors ``minplus_algebra(strict=...)``: the zero-weight
    cc instantiation pends ops only on strict improvement (``buf < dist``)
    so equal label re-sends cannot livelock the visit loop.
    """
    pending = jnp.isfinite(buf) & ((buf < dist) if strict
                                   else (buf <= dist))
    d1 = jnp.minimum(dist, jnp.where(pending, buf, INF))
    alpha = jnp.min(jnp.where(pending, d1, INF), axis=1, keepdims=True)
    active = pending & (d1 <= alpha + delta)
    srcs = jnp.where(active, d1, INF)
    return d1, srcs, alpha, pending, active


def _frontier_kernel(buf_ref, dist_ref, o_d_ref, o_src_ref, o_prio_ref, *,
                     delta: float):
    d1, srcs, alpha, _, _ = frontier_tile(buf_ref[...], dist_ref[...],
                                          delta=delta)
    o_d_ref[...] = d1
    o_src_ref[...] = srcs
    o_prio_ref[...] = jnp.min(alpha, axis=1)        # [QT]


@functools.partial(jax.jit, static_argnames=("delta", "q_tile",
                                             "interpret"))
def frontier_pallas_call(buf, dist, *, delta: float,
                         q_tile: int = DEFAULT_Q_TILE,
                         interpret: Optional[bool] = None):
    """buf, dist: [Q, B] -> (d1 [Q, B], srcs [Q, B], prio_rows [Q]).

    ``interpret=None`` follows the ``_on_tpu()`` autodetect the ``ops.py``
    wrapper uses, so a direct call can't silently run interpreted on TPU."""
    if interpret is None:
        interpret = not _on_tpu()
    q, b = buf.shape
    qt = min(q_tile, q) if q % min(q_tile, q) == 0 else q
    grid = (q // qt,)
    return pl.pallas_call(
        functools.partial(_frontier_kernel, delta=delta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, b), lambda i: (i, 0)),
            pl.BlockSpec((qt, b), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, b), lambda i: (i, 0)),
            pl.BlockSpec((qt, b), lambda i: (i, 0)),
            pl.BlockSpec((qt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, b), buf.dtype),
            jax.ShapeDtypeStruct((q, b), buf.dtype),
            jax.ShapeDtypeStruct((q,), buf.dtype),
        ],
        interpret=interpret,
    )(buf, dist)
