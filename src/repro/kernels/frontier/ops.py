"""Jitted wrapper: pad Q, dispatch kernel/ref by backend."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.contract import KernelContract, TileSpec
from repro.kernels.frontier.frontier import (frontier_pallas_call,
                                             frontier_tile)
from repro.kernels.frontier.ref import frontier_ref

#: static contract (DESIGN.md §7): canonical B=64 instantiation, tiled
#: q_tile=32 so the per-step footprint stays inside the planner model's
#: working set.  Wired: ``frontier_tile`` is the round-0 consolidation of
#: the fused visit kernel (core/visit.make_megastep(fused=True)), and the
#: standalone pallas_call remains callable directly.
CONTRACTS = (
    KernelContract(
        name="frontier", module="repro.kernels.frontier.frontier",
        grid=(2,),
        in_tiles=(TileSpec("buf", (64, 64), (32, 64)),
                  TileSpec("dist", (64, 64), (32, 64))),
        out_tiles=(TileSpec("d1", (64, 64), (32, 64)),
                   TileSpec("srcs", (64, 64), (32, 64)),
                   TileSpec("prio", (64,), (32,))),
        wired=True,
        block_size=64, num_queries=64),
)

__all__ = ["CONTRACTS", "frontier", "frontier_pallas", "frontier_tile"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def frontier(buf, dist, *, delta: float):
    return frontier_ref(buf, dist, delta=delta)


def frontier_pallas(buf, dist, *, delta: float, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    q, b = buf.shape
    pad = (-q) % 8
    if pad:
        buf = jnp.pad(buf, [(0, pad), (0, 0)], constant_values=jnp.inf)
        dist = jnp.pad(dist, [(0, pad), (0, 0)], constant_values=jnp.inf)
    d1, srcs, prio = frontier_pallas_call(buf, dist, delta=delta,
                                          interpret=interpret)
    return d1[:q], srcs[:q], prio[:q]
