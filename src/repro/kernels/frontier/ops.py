"""Jitted wrapper: pad Q, dispatch kernel/ref by backend."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.frontier.frontier import frontier_pallas_call
from repro.kernels.frontier.ref import frontier_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def frontier(buf, dist, *, delta: float):
    return frontier_ref(buf, dist, delta=delta)


def frontier_pallas(buf, dist, *, delta: float, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    q, b = buf.shape
    pad = (-q) % 8
    if pad:
        buf = jnp.pad(buf, [(0, pad), (0, 0)], constant_values=jnp.inf)
        dist = jnp.pad(dist, [(0, pad), (0, 0)], constant_values=jnp.inf)
    d1, srcs, prio = frontier_pallas_call(buf, dist, delta=delta,
                                          interpret=interpret)
    return d1[:q], srcs[:q], prio[:q]
