"""Jitted wrapper: pad Q, dispatch kernel/ref by backend."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.contract import KernelContract, TileSpec
from repro.kernels.frontier.frontier import frontier_pallas_call
from repro.kernels.frontier.ref import frontier_ref

#: static contract (DESIGN.md §7): canonical B=64, Q=64 instantiation.
#: Not yet reachable from a dispatch table — the visit loop's XLA frontier
#: math wins on CPU; this kernel is an input to the ROADMAP fused Pallas
#: visit kernel (frontier + minplus + scatter in one VMEM residency).
CONTRACTS = (
    KernelContract(
        name="frontier", module="repro.kernels.frontier.frontier",
        grid=(1,),
        in_tiles=(TileSpec("buf", (64, 64), (64, 64)),
                  TileSpec("dist", (64, 64), (64, 64))),
        out_tiles=(TileSpec("d1", (64, 64), (64, 64)),
                   TileSpec("srcs", (64, 64), (64, 64)),
                   TileSpec("prio", (64,), (64,))),
        wired=False,
        note="awaiting the ROADMAP fused Pallas visit kernel "
             "(frontier+minplus+scatter in one VMEM residency)",
        block_size=64, num_queries=64),
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def frontier(buf, dist, *, delta: float):
    return frontier_ref(buf, dist, delta=delta)


def frontier_pallas(buf, dist, *, delta: float, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    q, b = buf.shape
    pad = (-q) % 8
    if pad:
        buf = jnp.pad(buf, [(0, pad), (0, 0)], constant_values=jnp.inf)
        dist = jnp.pad(dist, [(0, pad), (0, 0)], constant_values=jnp.inf)
    d1, srcs, prio = frontier_pallas_call(buf, dist, delta=delta,
                                          interpret=interpret)
    return d1[:q], srcs[:q], prio[:q]
