"""Pure-jnp oracle for the tropical (min-plus) block relaxation.

``relax(d, W)[q, v] = min_u d[q, u] + W[u, v]``

This is the dense vectorized form of one edge-relaxation sweep of all Q queries
over a VMEM-resident partition block — the TPU adaptation of the paper's
"sequential algorithm on the cache-resident partition" (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_ref(d: jax.Array, w: jax.Array, chunk: int = 128) -> jax.Array:
    """d: [Q, B] distances (+inf inactive). w: [B, B] weights (+inf absent).

    Chunked over the contraction dim so peak memory is Q*chunk*B, not Q*B*B.
    """
    q, b = d.shape
    if w.shape != (b, b):
        raise ValueError(
            f"weight block must be square [{b}, {b}] to match d "
            f"{(q, b)}; got {w.shape}")
    chunk = min(chunk, b)
    nchunk = -(-b // chunk)
    pad = nchunk * chunk - b
    if pad:
        d = jnp.pad(d, ((0, 0), (0, pad)), constant_values=jnp.inf)
        w = jnp.pad(w, ((0, pad), (0, 0)), constant_values=jnp.inf)
    dc = d.reshape(q, nchunk, chunk).transpose(1, 0, 2)      # [nc, Q, c]
    wc = w.reshape(nchunk, chunk, b)                         # [nc, c, B]

    def body(carry, xs):
        dd, ww = xs                                          # [Q, c], [c, B]
        cand = jnp.min(dd[:, :, None] + ww[None, :, :], axis=1)
        return jnp.minimum(carry, cand), None

    init = jnp.full((q, b), jnp.inf, dtype=d.dtype)
    out, _ = jax.lax.scan(body, init, (dc, wc))
    return out


def masked_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """PPR spread oracle: ``out[q, v] = sum_u x[q, u] * [w[u, v] finite]``."""
    mask = jnp.isfinite(w).astype(x.dtype)
    return x @ mask
