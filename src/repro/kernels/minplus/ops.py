"""Dispatching wrappers for the intra-partition relaxation primitives.

``minplus`` / ``masked_matmul``  — pure-jnp (XLA) paths, the default on CPU.
``minplus_pallas`` / ``masked_matmul_pallas`` — Pallas kernels; on TPU they
compile natively, elsewhere they run in interpret mode (correct but slow, used
by the kernel test sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.minplus import minplus as _k
from repro.kernels.minplus.ref import masked_matmul_ref, minplus_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def minplus(d: jax.Array, w: jax.Array) -> jax.Array:
    return minplus_ref(d, w)


def masked_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return masked_matmul_ref(x, w)


def _pad_q(x: jax.Array, tile: int):
    q = x.shape[0]
    if q % tile == 0 or q < tile:
        return x, q
    pad = (-q) % tile
    return jnp.pad(x, ((0, pad), (0, 0)), constant_values=jnp.inf), q


def minplus_pallas(d: jax.Array, w: jax.Array, q_tile: int = 128) -> jax.Array:
    dp, q = _pad_q(d, q_tile)
    out = _k.minplus_pallas_call(dp, w, q_tile=q_tile,
                                 interpret=not _on_tpu())
    return out[:q]


def masked_matmul_pallas(x: jax.Array, w: jax.Array,
                         q_tile: int = 128) -> jax.Array:
    xp, q = _pad_q(x, q_tile)
    out = _k.masked_matmul_pallas_call(xp, w, q_tile=q_tile,
                                       interpret=not _on_tpu())
    return out[:q]
