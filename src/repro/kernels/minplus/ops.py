"""Dispatching wrappers for the intra-partition relaxation primitives.

``minplus`` / ``masked_matmul``  — pure-jnp (XLA) paths, the default on CPU.
``minplus_pallas`` / ``masked_matmul_pallas`` — Pallas kernels; on TPU they
compile natively, elsewhere they run in interpret mode (correct but slow, used
by the kernel test sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.contract import KernelContract, TileSpec
from repro.kernels.minplus import minplus as _k
from repro.kernels.minplus.ref import masked_matmul_ref, minplus_ref

#: static contracts (DESIGN.md §7): canonical instantiation at the
#: planner's smallest block B=64 with a full Q=64 query tile (qt = min(
#: DEFAULT_Q_TILE, Q) = 64, grid collapses to one program).  Both kernels
#: are wired: core/visit and core/baselines dispatch them per visit.
CONTRACTS = (
    KernelContract(
        name="minplus", module="repro.kernels.minplus.minplus",
        grid=(1,),
        in_tiles=(TileSpec("d", (64, 64), (64, 64)),
                  TileSpec("w", (64, 64), (64, 64))),
        out_tiles=(TileSpec("out", (64, 64), (64, 64)),),
        wired=True, block_size=64, num_queries=64),
    KernelContract(
        name="masked_matmul", module="repro.kernels.minplus.minplus",
        grid=(1,),
        in_tiles=(TileSpec("x", (64, 64), (64, 64)),
                  TileSpec("w", (64, 64), (64, 64))),
        out_tiles=(TileSpec("out", (64, 64), (64, 64)),),
        wired=True, block_size=64, num_queries=64),
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def minplus(d: jax.Array, w: jax.Array) -> jax.Array:
    return minplus_ref(d, w)


def masked_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return masked_matmul_ref(x, w)


def _pad_q(x: jax.Array, tile: int, identity: float):
    """Pad the query axis to a tile multiple with the *mode identity*
    (``+inf`` for min-plus, ``0`` for the masked matmul) so padded rows are
    inert under the kernel's combine and the kernel can require exact
    divisibility (minplus._tile) instead of silently un-tiling."""
    q = x.shape[0]
    if q % tile == 0 or q < tile:
        return x, q
    pad = (-q) % tile
    return jnp.pad(x, ((0, pad), (0, 0)), constant_values=identity), q


def minplus_pallas(d: jax.Array, w: jax.Array, q_tile: int = 128) -> jax.Array:
    dp, q = _pad_q(d, q_tile, jnp.inf)
    out = _k.minplus_pallas_call(dp, w, q_tile=q_tile,
                                 interpret=not _on_tpu())
    return out[:q]


def masked_matmul_pallas(x: jax.Array, w: jax.Array,
                         q_tile: int = 128) -> jax.Array:
    xp, q = _pad_q(x, q_tile, 0.0)
    out = _k.masked_matmul_pallas_call(xp, w, q_tile=q_tile,
                                       interpret=not _on_tpu())
    return out[:q]
