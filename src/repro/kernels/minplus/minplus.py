"""Pallas TPU kernels for the intra-partition batched relaxation.

Two kernels, both tiled so one partition visit's working set is VMEM-resident
(the paper's "partition fits into LLC", DESIGN.md §2):

  minplus_kernel       out[q, v] = min_u d[q, u] + w[u, v]   (tropical semiring,
                       VPU; one SSSP/BFS relaxation sweep for a Q-tile of
                       queries against a [B, B] adjacency block)
  masked_matmul_kernel out[q, v] = sum_u x[q, u] * finite(w[u, v])  (MXU; the
                       PPR residual spread)

Tiling: grid over query tiles; the adjacency block [B, B] is broadcast to all
programs (one HBM->VMEM load amortized over Q/QT programs — the cache-reuse
argument of the paper in BlockSpec form).  The contraction dim is chunked with
a fori_loop so the [QT, UC, B] broadcast temp stays small.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 128
DEFAULT_U_CHUNK = 8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def minplus_tile(d: jax.Array, w: jax.Array, *,
                 u_chunk: int = DEFAULT_U_CHUNK,
                 skip_inactive: bool = False) -> jax.Array:
    """One tropical relaxation over a resident [QT, B] tile, kernel-safe.

    The contraction dim is chunked with a ``fori_loop`` so the broadcast
    temp stays [QT, UC, B].  Chunking only reassociates an exact ``min``
    (every candidate is the same f32 sum ``d[q, u] + w[u, v]``), so the
    result is bitwise equal to ``ref.minplus_ref`` regardless of chunk
    size — the property the fused-visit parity harness pins.

    ``skip_inactive=True`` guards each chunk with a ``lax.cond`` on
    ``any(isfinite(du))``: a chunk whose source columns are all +inf can
    only contribute +inf candidates, so skipping it is bit-identical while
    a late sparse frontier skips most of the compute (the fused visit's
    sparse-frontier mode, DESIGN.md §2.4).
    """
    qt, b = d.shape
    uc = u_chunk if b % u_chunk == 0 else b
    if uc == b and not skip_inactive:
        # single-chunk fast path: min(+inf, cand) == cand bitwise (weights
        # are finite or +inf, so no NaN candidates), skip the loop scaffold
        return jnp.min(d[:, :, None] + w[None, :, :], axis=1)

    def chunk(i, acc):
        du = jax.lax.dynamic_slice(d, (0, i * uc), (qt, uc))
        wu = jax.lax.dynamic_slice(w, (i * uc, 0), (uc, b))
        cand = jnp.min(du[:, :, None] + wu[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    if skip_inactive:
        def body(i, acc):
            du = jax.lax.dynamic_slice(d, (0, i * uc), (qt, uc))
            return jax.lax.cond(jnp.any(jnp.isfinite(du)),
                                lambda a: chunk(i, a), lambda a: a, acc)
    else:
        body = chunk

    acc0 = jnp.full((qt, b), jnp.inf, dtype=d.dtype)
    return jax.lax.fori_loop(0, b // uc, body, acc0)


def _minplus_kernel(d_ref, w_ref, o_ref, *, u_chunk: int):
    o_ref[...] = minplus_tile(d_ref[...], w_ref[...], u_chunk=u_chunk)


def _masked_matmul_kernel(x_ref, w_ref, o_ref):
    mask = jnp.isfinite(w_ref[...]).astype(x_ref.dtype)
    o_ref[...] = jnp.dot(x_ref[...], mask,
                         preferred_element_type=x_ref.dtype)


def _tile(q: int, q_tile: int) -> int:
    """Resolve the query-tile size; Q must divide into whole tiles.

    A non-dividing Q used to silently collapse the grid to one [Q, B]
    program, defeating the tiling (and the VMEM working-set bound) exactly
    when Q grew past the tile.  ``ops.py`` already pads the query axis with
    the mode identity, so inside this module divisibility is a contract,
    not a fallback.
    """
    qt = min(q_tile, q)
    if q % qt != 0:
        raise ValueError(
            f"Q={q} does not divide into q_tile={qt} tiles; pad the query "
            f"axis to a tile multiple first (repro.kernels.minplus.ops pads "
            f"with the mode identity)")
    return qt


@functools.partial(jax.jit, static_argnames=("q_tile", "u_chunk", "interpret"))
def minplus_pallas_call(d: jax.Array, w: jax.Array,
                        q_tile: int = DEFAULT_Q_TILE,
                        u_chunk: int = DEFAULT_U_CHUNK,
                        interpret: Optional[bool] = None) -> jax.Array:
    """d: [Q, B], w: [B, B] -> [Q, B].  Q must divide by the chosen tile
    (ops.py pads); B must divide by u_chunk (blocks are powers of two).

    ``interpret=None`` follows the same ``_on_tpu()`` autodetect the
    ``ops.py`` wrappers use, so a direct call can't silently run
    interpreted on TPU."""
    if interpret is None:
        interpret = not _on_tpu()
    q, b = d.shape
    qt = _tile(q, q_tile)
    uc = u_chunk if b % u_chunk == 0 else b
    grid = (q // qt,)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, u_chunk=uc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, b), lambda i: (i, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((qt, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, b), d.dtype),
        interpret=interpret,
    )(d, w)


@functools.partial(jax.jit, static_argnames=("q_tile", "interpret"))
def masked_matmul_pallas_call(x: jax.Array, w: jax.Array,
                              q_tile: int = DEFAULT_Q_TILE,
                              interpret: Optional[bool] = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    q, b = x.shape
    qt = _tile(q, q_tile)
    grid = (q // qt,)
    return pl.pallas_call(
        _masked_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, b), lambda i: (i, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((qt, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, b), x.dtype),
        interpret=interpret,
    )(x, w)
