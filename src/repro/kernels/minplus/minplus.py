"""Pallas TPU kernels for the intra-partition batched relaxation.

Two kernels, both tiled so one partition visit's working set is VMEM-resident
(the paper's "partition fits into LLC", DESIGN.md §2):

  minplus_kernel       out[q, v] = min_u d[q, u] + w[u, v]   (tropical semiring,
                       VPU; one SSSP/BFS relaxation sweep for a Q-tile of
                       queries against a [B, B] adjacency block)
  masked_matmul_kernel out[q, v] = sum_u x[q, u] * finite(w[u, v])  (MXU; the
                       PPR residual spread)

Tiling: grid over query tiles; the adjacency block [B, B] is broadcast to all
programs (one HBM->VMEM load amortized over Q/QT programs — the cache-reuse
argument of the paper in BlockSpec form).  The contraction dim is chunked with
a fori_loop so the [QT, UC, B] broadcast temp stays small.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 128
DEFAULT_U_CHUNK = 8


def _minplus_kernel(d_ref, w_ref, o_ref, *, u_chunk: int):
    d = d_ref[...]                      # [QT, B]
    w = w_ref[...]                      # [B, B]
    qt, b = d.shape
    n_chunks = b // u_chunk

    def body(i, acc):
        du = jax.lax.dynamic_slice(d, (0, i * u_chunk), (qt, u_chunk))
        wu = jax.lax.dynamic_slice(w, (i * u_chunk, 0), (u_chunk, b))
        cand = jnp.min(du[:, :, None] + wu[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    acc0 = jnp.full((qt, b), jnp.inf, dtype=d.dtype)
    o_ref[...] = jax.lax.fori_loop(0, n_chunks, body, acc0)


def _masked_matmul_kernel(x_ref, w_ref, o_ref):
    mask = jnp.isfinite(w_ref[...]).astype(x_ref.dtype)
    o_ref[...] = jnp.dot(x_ref[...], mask,
                         preferred_element_type=x_ref.dtype)


def _tile(q: int, q_tile: int) -> int:
    """Resolve the query-tile size; Q must divide into whole tiles.

    A non-dividing Q used to silently collapse the grid to one [Q, B]
    program, defeating the tiling (and the VMEM working-set bound) exactly
    when Q grew past the tile.  ``ops.py`` already pads the query axis with
    the mode identity, so inside this module divisibility is a contract,
    not a fallback.
    """
    qt = min(q_tile, q)
    if q % qt != 0:
        raise ValueError(
            f"Q={q} does not divide into q_tile={qt} tiles; pad the query "
            f"axis to a tile multiple first (repro.kernels.minplus.ops pads "
            f"with the mode identity)")
    return qt


@functools.partial(jax.jit, static_argnames=("q_tile", "u_chunk", "interpret"))
def minplus_pallas_call(d: jax.Array, w: jax.Array,
                        q_tile: int = DEFAULT_Q_TILE,
                        u_chunk: int = DEFAULT_U_CHUNK,
                        interpret: bool = True) -> jax.Array:
    """d: [Q, B], w: [B, B] -> [Q, B].  Q must divide by the chosen tile
    (ops.py pads); B must divide by u_chunk (blocks are powers of two)."""
    q, b = d.shape
    qt = _tile(q, q_tile)
    uc = u_chunk if b % u_chunk == 0 else b
    grid = (q // qt,)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, u_chunk=uc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, b), lambda i: (i, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((qt, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, b), d.dtype),
        interpret=interpret,
    )(d, w)


@functools.partial(jax.jit, static_argnames=("q_tile", "interpret"))
def masked_matmul_pallas_call(x: jax.Array, w: jax.Array,
                              q_tile: int = DEFAULT_Q_TILE,
                              interpret: bool = True) -> jax.Array:
    q, b = x.shape
    qt = _tile(q, q_tile)
    grid = (q // qt,)
    return pl.pallas_call(
        _masked_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, b), lambda i: (i, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((qt, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, b), x.dtype),
        interpret=interpret,
    )(x, w)
