"""Train step factory: microbatched grad accumulation + AdamW + metrics.

Distribution is declarative: the step is written globally and jitted with
in/out shardings derived from the logical-axis rules; GSPMD inserts the
gradient collectives (reduce-scatter/all-gather for FSDP params on the
"data" axis, all-reduce on the "pod" axis — the hierarchical pattern of
DESIGN.md §6).

Gradient int8 compression with error feedback is implemented as
quantize/dequantize around the (implicit) all-reduce boundary with the EF
residual carried in ``TrainState.ef``.  On CPU this simulates the wire
format exactly (numerics are faithful); on a real pod the same functions
wrap an explicit shard_map psum over int8 (see train/compress.py, which
also provides that collective and its test).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.factory import Model
from repro.models.sharding import AxisRules
from repro.train import compress as compress_lib
from repro.train.optimizer import AdamState, AdamW


class TrainState(NamedTuple):
    params: dict
    opt: AdamState
    step: jax.Array
    ef: Optional[dict] = None     # error-feedback residual (compression)


def init_train_state(model: Model, key, optimizer: AdamW,
                     compression: bool = False) -> TrainState:
    params, _ = model.init(key)
    ef = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
          if compression else None)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def make_train_step(model: Model, optimizer: AdamW, lr_fn: Callable, *,
                    rules: AxisRules = None, microbatches: int = 1,
                    remat: bool = True,
                    compression: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, rules, remat)
        return loss, metrics

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mbs = jax.tree.map(split, batch)

        def acc_fn(carry, mb):
            g_acc, m_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                 g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)
        m0 = {"loss": jnp.float32(0), "ce": jnp.float32(0),
              "aux": jnp.float32(0)}
        (g, m), _ = jax.lax.scan(acc_fn, (g0, m0), mbs)
        inv = 1.0 / microbatches
        return (jax.tree.map(lambda x: x * inv, g),
                jax.tree.map(lambda x: x * inv, m))

    def train_step(state: TrainState, batch):
        grads, metrics = grads_of(state.params, batch)
        ef = state.ef
        if compression:
            grads, ef = compress_lib.compress_with_error_feedback(grads, ef)
        lr = lr_fn(state.step)
        params, opt = optimizer.update(grads, state.opt, state.params, lr)
        metrics = dict(metrics)
        metrics["lr"] = lr
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return TrainState(params=params, opt=opt, step=state.step + 1,
                          ef=ef), metrics

    return train_step


# ---------------------------------------------------------------------------
# sharding helpers for jitting the step


def state_shardings(state_or_specs, axes, rules: AxisRules):
    """PartitionSpec tree for a TrainState given param logical axes."""
    pspec = rules.tree_specs(axes, state_or_specs.params)
    from jax.sharding import NamedSharding, PartitionSpec as P

    def ns(spec):
        return NamedSharding(rules.mesh, spec)
    param_sh = jax.tree.map(ns, pspec,
                            is_leaf=lambda x: isinstance(x, P))
    repl = NamedSharding(rules.mesh, P())
    ef = state_or_specs.ef
    master = getattr(state_or_specs.opt, "master", None)
    return TrainState(
        params=param_sh,
        opt=AdamState(mu=param_sh, nu=param_sh, count=repl,
                      master=None if master is None else param_sh),
        step=repl,
        ef=None if ef is None else param_sh)


def batch_shardings(batch_specs, rules: AxisRules):
    from jax.sharding import NamedSharding, PartitionSpec as P
    b = rules.rules["batch"]
    return {k: NamedSharding(rules.mesh,
                             P(b, *([None] * (len(v.shape) - 1))))
            for k, v in batch_specs.items()}
