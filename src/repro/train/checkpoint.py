"""Fault-tolerant checkpointing.

Design (DESIGN.md §6):
* one ``.npy`` file per pytree leaf, named by its flattened key path;
* ``manifest.json`` records tree structure, shapes, dtypes, step and a
  CRC32 per file — restore verifies integrity before any state is touched;
* writes go to ``<dir>/tmp.<step>`` and commit with one atomic
  ``os.rename`` to ``<dir>/step_<n>`` — a job killed mid-write leaves the
  previous checkpoint intact (tests kill a writer to prove it);
* an async writer thread keeps the train loop running during saves
  (``AsyncCheckpointer``); ``wait()`` joins before exit;
* restore is *resharding*: leaves are materialized host-side and then
  ``jax.device_put`` against whatever shardings the new mesh wants, so a
  checkpoint written on mesh (16,16) restores onto (2,16,16) or onto a
  single CPU (elastic re-mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

_SEP = "::"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out, treedef


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and d.split("_")[1].isdigit()]
    return max(steps) if steps else None


def save(directory: str, step: int, tree: Any, extra: dict = None) -> str:
    """Blocking save.  Returns the committed path."""
    flat, _ = _flatten(tree)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fname = f"{zlib.crc32(key.encode()):08x}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        with open(fpath, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "crc32": crc}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    return final


def restore(directory: str, step: Optional[int] = None, *,
            target: Any = None, shardings: Any = None,
            strict_crc: bool = True):
    """Restore a checkpoint.

    target: pytree with the desired structure (leaves can be arrays or
    ShapeDtypeStructs); if None, returns the flat {key: np.ndarray} dict.
    shardings: optional pytree of NamedShardings congruent with target —
    leaves are device_put against them (resharding restore).
    Returns (tree_or_flat, step, extra).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for key, meta in manifest["leaves"].items():
        fpath = os.path.join(path, meta["file"])
        if strict_crc:
            with open(fpath, "rb") as f:
                crc = zlib.crc32(f.read())
            if crc != meta["crc32"]:
                raise IOError(f"CRC mismatch for {key} in {path}")
        flat[key] = np.load(fpath)
    if target is None:
        return flat, manifest["step"], manifest["extra"]
    tflat, treedef = _flatten(target)
    missing = set(tflat) - set(flat)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    leaves = []
    sflat = None
    if shardings is not None:
        sflat, _ = _flatten(shardings)
    for key, tgt in tflat.items():
        arr = flat[key]
        want = np.dtype(tgt.dtype) if hasattr(tgt, "dtype") else None
        if want is not None and arr.dtype != want:
            arr = arr.astype(want)
        if sflat is not None and key in sflat:
            leaves.append(jax.device_put(arr, sflat[key]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    _, treedef2 = jax.tree_util.tree_flatten(target)
    tree = jax.tree_util.tree_unflatten(treedef2, leaves)
    return tree, manifest["step"], manifest["extra"]


class AsyncCheckpointer:
    """Background-thread writer; at most one save in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, extra: dict = None):
        self.wait()
        # snapshot to host *before* handing to the thread so training can
        # donate/overwrite device buffers immediately
        host = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.directory, step, host, extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(s for s in (
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
