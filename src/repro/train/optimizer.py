"""AdamW + schedules, built from scratch (no optax dependency).

Optimizer state is a pytree congruent with params, so it inherits the
params' 2-D (TP x FSDP) sharding for free — optimizer-state sharding is
what makes the 123B configs fit (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class AdamState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array
    # f32 master weights when params are kept in bf16 (mixed-precision
    # recipe: fwd/bwd move bf16 — half the FSDP gather bytes and half the
    # weight-grad-partial temps — while the update stays f32-exact)
    master: Optional[dict] = None


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                             params)
        needs_master = any(p.dtype != jnp.float32
                           for p in jax.tree.leaves(params))
        master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
                  if needs_master else None)
        return AdamState(mu=zeros,
                         nu=jax.tree.map(jnp.zeros_like, zeros),
                         count=jnp.zeros((), jnp.int32), master=master)

    def update(self, grads, state: AdamState, params, lr):
        scale = jnp.float32(1.0)
        if self.clip_norm is not None:
            # fused clip: scale inside the update instead of materializing
            # a clipped copy of the full gradient tree
            norm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm
                                / jnp.maximum(norm, 1e-9))
        count = state.count + 1
        tf = count.astype(jnp.float32)
        bc1 = 1.0 - self.b1 ** tf
        bc2 = 1.0 - self.b2 ** tf

        def upd(g, m, n, p, w):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            n = self.b2 * n + (1 - self.b2) * g * g
            mhat = m / bc1
            nhat = n / bc2
            step = mhat / (jnp.sqrt(nhat) + self.eps)
            w32 = p.astype(jnp.float32) if w is None else w
            step = step + self.weight_decay * w32
            new_w = w32 - lr * step
            return new_w.astype(p.dtype), m, n, new_w

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        mflat = treedef.flatten_up_to(state.mu)
        nflat = treedef.flatten_up_to(state.nu)
        wflat = (treedef.flatten_up_to(state.master)
                 if state.master is not None else [None] * len(flat))
        out = [upd(g, m, n, p, w)
               for g, m, n, p, w in zip(gflat, mflat, nflat, flat, wflat)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_n = treedef.unflatten([o[2] for o in out])
        master = (treedef.unflatten([o[3] for o in out])
                  if state.master is not None else None)
        return new_p, AdamState(mu=new_m, nu=new_n, count=count,
                                master=master)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale)
                        .astype(x.dtype), tree)


# ---------------------------------------------------------------------------
# schedules


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return lr


def constant(base_lr: float):
    return lambda step: jnp.float32(base_lr)


def rsqrt(base_lr: float, warmup: int = 1000):
    def lr(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return base_lr * jnp.minimum(s / warmup, jnp.sqrt(warmup / s))
    return lr
