from repro.train.optimizer import AdamW, warmup_cosine, constant, rsqrt  # noqa
from repro.train.train_step import (TrainState, init_train_state,  # noqa
                                    make_train_step)
from repro.train.loop import LoopConfig, run_loop  # noqa
