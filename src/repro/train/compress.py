"""Int8 gradient compression with error feedback.

Two pieces:

* ``compress_with_error_feedback`` — per-tensor symmetric int8
  quantize/dequantize with the quantization residual accumulated into an
  error-feedback buffer (Seide et al. / 1-bit-SGD style EF), applied to the
  gradient pytree at the all-reduce boundary inside train_step.  On CPU it
  simulates the wire format bit-exactly; convergence behaviour is the real
  object of study and is what tests/test_train.py checks.

* ``compressed_psum`` — the explicit collective for real meshes: a
  shard_map psum that quantizes to int8 before the wire and dequantizes
  after, halving-x4 the inter-pod gradient bytes.  Validated against a f32
  psum in tests/test_distributed_train.py on fake devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_error_feedback(grads, ef):
    """grads, ef: congruent pytrees.  Returns (decompressed_grads, new_ef)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), gf - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compressed_psum(x: jax.Array, axis_name: str):
    """psum with int8 wire format (call inside shard_map).

    Each shard quantizes its contribution with a *shared* scale (psum-max
    of local amax) so the sum of int8 payloads is decodable; the reduction
    itself is an int32 psum (int8 would overflow at >127 shards).
    """
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12), axis_name)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale
