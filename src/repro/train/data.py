"""Synthetic data pipeline.

``batch_for_step`` is a *pure function of (config, shape, step)* — the
stream is deterministic and random-access, so a restarted job regenerates
exactly the batches it would have seen (the bitwise-resume test in
tests/test_checkpoint.py depends on this, and on a real cluster it means
data does not need checkpointing).

Tokens follow a Zipf-like distribution over the vocab (real-text-ish
marginals make the CE loss move like a real run rather than saturating).
Per-host sharding on a real pod: each host materializes only its
``process_index`` slice of the batch dim (``host_slice``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models.encdec import N_FRAMES


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_tokens(key, shape, vocab: int, a: float):
    """Zipf-ish marginal via inverse-CDF on uniform samples."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    # rank ~ u^(-1/(a-1)) truncated to vocab
    r = jnp.power(u, -1.0 / max(a - 1.0, 0.05))
    toks = jnp.clip(r.astype(jnp.int32) - 1, 0, vocab - 1)
    # random permutation of ranks -> token ids so ids are not ordered by freq
    return toks


def batch_for_step(cfg: ArchConfig, shape: ShapeConfig, step: int,
                   dc: DataConfig = DataConfig()) -> Dict[str, jax.Array]:
    B, S = shape.global_batch, shape.seq_len
    key = jax.random.fold_in(jax.random.PRNGKey(dc.seed), step)
    k_tok, k_len, k_x = jax.random.split(key, 3)
    if cfg.family == "vlm":
        S_text = S - cfg.num_image_tokens
    else:
        S_text = S
    stream = _zipf_tokens(k_tok, (B, S_text + 1), cfg.vocab, dc.zipf_a)
    tokens, labels = stream[:, :-1], stream[:, 1:]
    # variable document lengths -> loss mask (exercises masked CE)
    doc_len = jax.random.randint(k_len, (B,), S_text // 2, S_text + 1)
    mask = (jnp.arange(S_text)[None, :] < doc_len[:, None]).astype(
        jnp.float32)
    batch = {"tokens": tokens, "labels": labels, "loss_mask": mask}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jax.random.normal(
            k_x, (B, cfg.num_image_tokens, cfg.d_model)).astype(cfg.cdtype)
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            k_x, (B, N_FRAMES, cfg.d_model)).astype(cfg.cdtype)
    return batch


def host_slice(batch: Dict[str, jax.Array], process_index: int,
               process_count: int) -> Dict[str, jax.Array]:
    """The slice of the global batch this host feeds (multi-host input)."""
    def sl(x):
        b = x.shape[0]
        per = b // process_count
        return x[process_index * per:(process_index + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


def data_iterator(cfg: ArchConfig, shape: ShapeConfig, start_step: int = 0,
                  dc: DataConfig = DataConfig()) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_for_step(cfg, shape, step, dc)
        step += 1
