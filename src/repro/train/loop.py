"""Training loop: restore -> step -> (async) checkpoint -> straggler watch.

Fault-tolerance posture (DESIGN.md §6):
* restore-on-start from the latest intact checkpoint (CRC-verified);
  data is random-access by step, so resume is bitwise identical
  (tests/test_checkpoint.py proves it by killing a run mid-flight);
* async checkpointing every ``ckpt_every`` steps;
* straggler mitigation: a ring buffer of step times; a step slower than
  ``straggler_factor`` x the running median fires ``on_straggler`` —
  on a real cluster this hook re-shards away from the slow host / asks the
  coordinator for a replacement; here it logs and counts (simulated via a
  fault-injection hook in tests).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib
from repro.train.train_step import TrainState


@dataclasses.dataclass
class LoopConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_window: int = 32


@dataclasses.dataclass
class LoopStats:
    steps_run: int = 0
    restored_step: Optional[int] = None
    straggler_events: int = 0
    step_times: list = dataclasses.field(default_factory=list)
    history: list = dataclasses.field(default_factory=list)


def run_loop(train_step: Callable, state: TrainState, data_fn: Callable,
             cfg: LoopConfig, *, log: Callable = print,
             on_straggler: Callable = None,
             fault_hook: Callable = None) -> tuple:
    """data_fn(step)->batch.  Returns (state, LoopStats).

    fault_hook(step): test-only hook called before each step; may raise to
    simulate a node failure mid-run.
    """
    stats = LoopStats()
    ckpt = (ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir)
            if cfg.ckpt_dir else None)
    start = 0
    if ckpt is not None and ckpt_lib.latest_step(cfg.ckpt_dir) is not None:
        state, start, _ = ckpt_lib.restore(cfg.ckpt_dir, target=state)
        stats.restored_step = start
        log(f"[loop] restored checkpoint at step {start}")
    ring = collections.deque(maxlen=cfg.straggler_window)
    try:
        state = _step_loop(train_step, state, data_fn, cfg, stats, ring,
                           start, ckpt, log, on_straggler, fault_hook)
    except BaseException:
        # a dying run must not abandon an in-flight async checkpoint:
        # the commit rename is what the restarted job restores from
        if ckpt is not None:
            try:
                ckpt.wait()
            except Exception:
                pass             # surface the original failure, not the writer's
        raise
    if ckpt is not None:
        ckpt.wait()
        ckpt.save(cfg.n_steps, state)
        ckpt.wait()
    return state, stats


def _step_loop(train_step, state, data_fn, cfg, stats, ring, start, ckpt,
               log, on_straggler, fault_hook):
    for step in range(start, cfg.n_steps):
        if fault_hook is not None:
            fault_hook(step)
        batch = data_fn(step)
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        stats.step_times.append(dt)
        if len(ring) >= 8 and dt > cfg.straggler_factor * np.median(ring):
            stats.straggler_events += 1
            if on_straggler is not None:
                on_straggler(step, dt, float(np.median(ring)))
            else:
                log(f"[loop] straggler: step {step} took {dt:.3f}s "
                    f"(median {np.median(ring):.3f}s)")
        ring.append(dt)
        stats.steps_run += 1
        if step % cfg.log_every == 0 or step == cfg.n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            stats.history.append({"step": step, **m})
            log(f"[loop] step {step:5d} loss {m['loss']:.4f} "
                f"lr {m.get('lr', 0):.2e} {dt * 1e3:7.1f} ms")
        if ckpt is not None and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save(step + 1, state)
    return state
