"""Synthetic graph generators covering the paper's dataset families.

The paper evaluates on road networks (Ca/Us/Eu — high diameter, low degree),
social networks (Or/Lj/Tw — power-law, low diameter), a hyperlink network (Wk)
and a citation network (Pt).  Offline we generate the same families:

  grid2d          road-like: 2D lattice + random diagonals, high diameter
  rmat            social-like: power-law R-MAT
  erdos_renyi     uniform random
  watts_strogatz  small-world (hyperlink-like)

Edge weights follow the paper: uniform in [1, log|V|).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.graph import CSRGraph

#: committed edge-list fixtures (SNAP-style text, gz) live with the tests;
#: overridable so an installed package can point at its own data directory
FIXTURE_DIR = os.environ.get(
    "FPP_FIXTURE_DIR",
    os.path.join(os.path.dirname(os.path.abspath(__file__)),
                 os.pardir, os.pardir, os.pardir, "tests", "data"))


def _weights(rng: np.random.Generator, m: int, n: int) -> np.ndarray:
    hi = max(2.0, np.log(max(n, 3)))
    return rng.uniform(1.0, hi, size=m).astype(np.float32)


def grid2d(rows: int, cols: int, seed: int = 0, weighted: bool = True) -> CSRGraph:
    """Road-network-like 2D grid (4-neighborhood), symmetrized."""
    rng = np.random.default_rng(seed)
    n = rows * cols
    ids = np.arange(n).reshape(rows, cols)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], axis=1)
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()], axis=1)
    e = np.concatenate([right, down], axis=0)
    w = _weights(rng, e.shape[0], n) if weighted else np.ones(e.shape[0], np.float32)
    return CSRGraph.from_edges(n, e[:, 0], e[:, 1], w, symmetrize=True)


def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0, weighted: bool = True,
         symmetrize: bool = True) -> CSRGraph:
    """Graph500-style R-MAT: power-law, social-network-like."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for level in range(scale):
        r = rng.random(m)
        src_bit = (r >= ab).astype(np.int64)
        r2 = rng.random(m)
        thresh = np.where(src_bit == 0, a / ab, c / (1.0 - ab))
        dst_bit = (r2 >= thresh).astype(np.int64)
        src |= src_bit << level
        dst |= dst_bit << level
    # permute ids to break degree-id correlation
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    w = _weights(rng, m, n) if weighted else np.ones(m, np.float32)
    return CSRGraph.from_edges(n, src, dst, w, symmetrize=symmetrize)


def erdos_renyi(n: int, avg_deg: float = 8.0, seed: int = 0,
                weighted: bool = True) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    w = _weights(rng, m, n) if weighted else np.ones(m, np.float32)
    return CSRGraph.from_edges(n, src, dst, w, symmetrize=True)


def watts_strogatz(n: int, k: int = 8, beta: float = 0.1, seed: int = 0,
                   weighted: bool = True) -> CSRGraph:
    rng = np.random.default_rng(seed)
    base = np.arange(n, dtype=np.int64)
    srcs, dsts = [], []
    for off in range(1, k // 2 + 1):
        srcs.append(base)
        dsts.append((base + off) % n)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    rewire = rng.random(src.size) < beta
    dst = np.where(rewire, rng.integers(0, n, size=src.size), dst)
    w = _weights(rng, src.size, n) if weighted else np.ones(src.size, np.float32)
    return CSRGraph.from_edges(n, src, dst, w, symmetrize=True)


def snap_fixture(name: str = "snap_tiny.txt.gz", seed: int = 0,
                 weighted: bool = True) -> CSRGraph:
    """The committed SNAP-style edge-list fixture, through the real
    ingestion path (``graphs.io.load_edge_list``): sparse 64-bit vertex
    ids compacted on load, integer weights, a hub-heavy degree tail.

    Unlike the generators above this is *data*, not code — ``seed`` is
    accepted (and ignored) only so :func:`build_suite` can treat the
    fixture like any other suite entry; ``weighted=False`` reads the same
    file with unit weights.
    """
    from repro.graphs.io import load_edge_list
    path = os.path.join(FIXTURE_DIR, name)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"fixture {name} not found under {FIXTURE_DIR} — set "
            f"FPP_FIXTURE_DIR if the repo's tests/data is elsewhere")
    return load_edge_list(path, symmetrize=True, weighted=bool(weighted))


SUITES = {
    # name: (builder, kwargs) — small stand-ins for the paper's 8 datasets,
    # scaled to single-core-CPU test budgets.
    "snap-tiny": (snap_fixture, dict()),  # committed ingested fixture (|V|=960)
    "road-ca": (grid2d, dict(rows=96, cols=96)),          # |V|=9.2k, high diameter
    "road-us": (grid2d, dict(rows=160, cols=160)),        # |V|=25.6k
    "social-lj": (rmat, dict(scale=13, edge_factor=12)),  # |V|=8.2k power law
    "social-or": (rmat, dict(scale=12, edge_factor=24)),  # denser
    "web-wk": (watts_strogatz, dict(n=8192, k=12, beta=0.2)),
    "cite-pt": (erdos_renyi, dict(n=16384, avg_deg=4.0)),
}


def build_suite(name: str, seed: int = 0, weighted: bool = True) -> CSRGraph:
    fn, kw = SUITES[name]
    return fn(seed=seed, weighted=weighted, **kw)
