"""Graph I/O: edge-list / npz round-trips for CSRGraph.

Real deployments feed SNAP/DIMACS-style edge lists; the npz form is the
fast binary cache (one file, mmap-able).
"""
from __future__ import annotations

import gzip
import os

import numpy as np

from repro.core.graph import CSRGraph


def save_npz(path: str, g: CSRGraph):
    np.savez_compressed(path, indptr=g.indptr, indices=g.indices,
                        weights=g.weights, n=np.int64(g.n),
                        m=np.int64(g.m))


def load_npz(path: str) -> CSRGraph:
    z = np.load(path)
    return CSRGraph(indptr=z["indptr"], indices=z["indices"],
                    weights=z["weights"], n=int(z["n"]), m=int(z["m"]))


def load_edge_list(path: str, *, symmetrize: bool = True,
                   weighted: bool | None = None,
                   comment: str = "#") -> CSRGraph:
    """SNAP-style whitespace edge list: ``src dst [weight]`` per line.
    Vertex ids are compacted to 0..n-1.  .gz transparently supported."""
    opener = gzip.open if path.endswith(".gz") else open
    src, dst, w = [], [], []
    with opener(path, "rt") as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(comment):
                continue
            parts = line.split()
            src.append(int(parts[0]))
            dst.append(int(parts[1]))
            if weighted is None:
                weighted = len(parts) > 2
            if weighted:
                w.append(float(parts[2]) if len(parts) > 2 else 1.0)
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    ids = np.unique(np.concatenate([src, dst]))
    remap = np.zeros(ids.max() + 1 if ids.size else 1, np.int64)
    remap[ids] = np.arange(ids.size)
    weights = (np.asarray(w, np.float32) if weighted
               else np.ones(src.size, np.float32))
    return CSRGraph.from_edges(int(ids.size), remap[src], remap[dst],
                               weights, symmetrize=symmetrize)


def save_edge_list(path: str, g: CSRGraph):
    src, dst, w = g.edges()
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "wt") as f:
        f.write(f"# |V|={g.n} |E|={g.m}\n")
        for s, d, ww in zip(src, dst, w):
            f.write(f"{s} {d} {ww:.6g}\n")
