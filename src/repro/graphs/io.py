"""Graph I/O: edge-list / npz round-trips for CSRGraph.

Real deployments feed SNAP/DIMACS-style edge lists; the npz form is the
fast binary cache (one file, mmap-able).
"""
from __future__ import annotations

import gzip
import os

import numpy as np

from repro.core.graph import CSRGraph


def save_npz(path: str, g: CSRGraph):
    np.savez_compressed(path, indptr=g.indptr, indices=g.indices,
                        weights=g.weights, n=np.int64(g.n),
                        m=np.int64(g.m))


def load_npz(path: str) -> CSRGraph:
    z = np.load(path)
    return CSRGraph(indptr=z["indptr"], indices=z["indices"],
                    weights=z["weights"], n=int(z["n"]), m=int(z["m"]))


def load_edge_list(path: str, *, symmetrize: bool = True,
                   weighted: bool | None = None,
                   comment: str = "#") -> CSRGraph:
    """SNAP-style whitespace edge list: ``src dst [weight]`` per line.
    Vertex ids are compacted to 0..n-1.  .gz transparently supported.

    ``weighted=None`` infers from the *whole* file: all 3-column lines
    means weighted, all 2-column means unit weights, and a mix raises
    (inferring from the first line silently dropped weights in mixed
    files).  An explicit ``weighted=True``/``False`` keeps the lenient
    behavior — missing third columns read as 1.0 / extra columns are
    ignored.
    """
    opener = gzip.open if path.endswith(".gz") else open
    src, dst, w = [], [], []
    arities = set()
    with opener(path, "rt") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line or line.startswith(comment):
                continue
            parts = line.split()
            src.append(int(parts[0]))
            dst.append(int(parts[1]))
            has_w = len(parts) > 2
            arities.add(has_w)
            if weighted is None and len(arities) > 1:
                raise ValueError(
                    f"{path}:{lineno}: inconsistent edge-list arity — the "
                    f"file mixes 2-column and 3-column lines; pass "
                    f"weighted=True or weighted=False to disambiguate")
            w.append(float(parts[2]) if has_w else 1.0)
    if weighted is None:
        weighted = True in arities
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    # id compaction via sorted-search, not a dense [0, ids.max()] table —
    # SNAP dumps carry sparse 64-bit ids and a dense remap would OOM
    ids = np.unique(np.concatenate([src, dst]))
    src = np.searchsorted(ids, src)
    dst = np.searchsorted(ids, dst)
    weights = (np.asarray(w, np.float32) if weighted
               else np.ones(src.size, np.float32))
    return CSRGraph.from_edges(int(ids.size), src, dst,
                               weights, symmetrize=symmetrize)


def save_edge_list(path: str, g: CSRGraph):
    src, dst, w = g.edges()
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "wt") as f:
        f.write(f"# |V|={g.n} |E|={g.m}\n")
        for s, d, ww in zip(src, dst, w):
            f.write(f"{s} {d} {ww:.6g}\n")
