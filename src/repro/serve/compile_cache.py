"""Warm megastep executables for the serving layer (DESIGN.md §4.2).

A streaming executor's first ``pump`` pays a trace+compile stall — tens of
milliseconds to seconds, charged to whichever request had the bad luck of
arriving first after a pool was created or resized.  Under continuous
batching that stall happens *inside* the dispatch lane, so every tenant on
the pool eats it.  This module moves the cost to ``register_graph`` time:

  * :func:`build_warm_megastep` AOT-lowers and compiles the exact megastep
    a :class:`~repro.fpp.streaming.StreamingExecutor` would trace for the
    same parameters — both sides build through
    ``streaming.build_stream_engine`` / ``build_stream_megastep``, so the
    compiled program and the would-have-been-traced one are the same
    function of the same baked graph constants (``session.prepared`` caches
    one (BlockGraph, perm) per session, so "same graph" is by identity,
    not just by value).
  * :class:`MegastepCache` memoizes those executables under
    ``(graph, kind, K, capacity, fused, alpha, eps, schedule, seed,
    session_uid)`` — the uid (:func:`session_uid`) pins the executable to
    the session whose constants it baked in, so a cache shared across
    servers can never hand one graph's program to a different graph that
    happens to reuse the same registered name.  Capacity is the raw lane
    count — the *server* snaps demand to pow2
    buckets (``planner.pow2_bucket``) before asking, which keeps the set
    of distinct compiled shapes logarithmic in load instead of linear.

Anything that changes the traced program must be in the key: ``alpha`` and
``eps`` are closed over by the push algebra, ``schedule`` picks the
on-device partition policy, ``fused`` swaps the visit body, ``seed`` feeds
the engine's scheduler PRNG stream.  Yield-config overrides are deliberately
*not* keyed — the serving layer never passes one (it always uses the
planner default for (kind, graph)); hand-rolled executors with custom yield
configs should not share this cache.

Compiles run outside the cache lock (a per-key in-flight event dedupes
concurrent warmers), so a background warm thread never blocks admission.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import visit as _visit
from repro.fpp.streaming import build_stream_engine, build_stream_megastep

_uid_lock = threading.Lock()
_uid_counter = itertools.count()


def session_uid(session) -> int:
    """A process-unique token for this session, minted on first use.

    The compiled megastep bakes in the session's graph constants
    (``session.prepared`` caches one BlockGraph per session), so cache
    keys must identify the *session*, not just its registered name —
    two servers sharing a :class:`MegastepCache` may both call a
    different graph ``"default"``.  A stored attribute rather than
    ``id(session)``: ids are recycled after GC, a minted uid never is.
    """
    uid = getattr(session, "_megastep_cache_uid", None)
    if uid is None:
        with _uid_lock:
            uid = getattr(session, "_megastep_cache_uid", None)
            if uid is None:
                uid = next(_uid_counter)
                session._megastep_cache_uid = uid
    return uid


def warm_key(session, graph: str, kind: str, k_visits: int, capacity: int, *,
             fused: bool = False, alpha: float = 0.15, eps: float = 1e-4,
             schedule: str = "priority", seed: int = 0, k: int = 8,
             length: int = 32, walk_seed: int = 0) -> tuple:
    """The cache key: every parameter that reaches the traced program,
    including the identity of the session whose graph constants the
    executable bakes in (:func:`session_uid`).  ``k`` (the kreach hop
    budget) shifts the engine's finalize, ``length``/``walk_seed``
    parameterize the rw walk visit — each reaches some kind's compiled
    program, so each is in every key."""
    return (str(graph), str(kind), int(k_visits), int(capacity),
            bool(fused), float(alpha), float(eps), str(schedule), int(seed),
            int(k), int(length), int(walk_seed), session_uid(session))


def build_warm_megastep(session, kind: str, capacity: int, *,
                        schedule: str = "priority", alpha: float = 0.15,
                        eps: float = 1e-4, seed: int = 0, k_visits: int = 64,
                        fused: bool = False, k: int = 8, length: int = 32,
                        walk_seed: int = 0):
    """AOT-compile the streaming megastep for these parameters.

    Returns a ``jax.stages.Compiled`` with the executor's calling
    convention ``(state, counter, limit, key) -> (state', MegastepStats)``
    — ``counter``/``limit`` are int32 scalars and ``key`` a PRNG key, so
    one executable serves every chunk the executor will ever dispatch at
    this capacity.  Injected via ``StreamingExecutor(megastep=...)`` (or
    ``session.stream(megastep=...)``) it replaces the trace the executor
    would otherwise do on first pump.

    ``kind="rw"`` has no megastep — its lane is the buffered walk visit —
    so the warm executable is the AOT-compiled ``make_walk_visit`` for
    (``length``, ``walk_seed``) at this capacity, injected via
    ``WalkExecutor(visit=...)`` through the same ``megastep=`` plumbing.
    """
    if kind == "rw":
        from repro.core.engine import DeviceGraph
        from repro.core.randomwalk import make_walk_visit
        from repro.core.yielding import NO_YIELD
        bg, _perm = session.prepared()
        dg = DeviceGraph.build(bg, NO_YIELD, int(capacity))
        visit = make_walk_visit(dg, int(length), int(walk_seed))
        Q = int(capacity)
        zi = jnp.zeros(Q, jnp.int32)
        occ = jnp.zeros((Q, dg.num_parts * dg.block_size), jnp.float32)
        return visit.lower(zi, zi, zi, zi, jnp.zeros(Q, jnp.uint32), occ,
                           jnp.int32(0)).compile()
    engine, _bg, _perm = build_stream_engine(
        session, kind, int(capacity), schedule=schedule, alpha=alpha,
        eps=eps, seed=seed, k_visits=k_visits, fused=fused, k=k)
    megastep = build_stream_megastep(engine, schedule)
    state = _visit.init_engine_state(
        engine.algebra, engine.dg, np.empty(0, dtype=np.int64),
        num_queries=int(capacity))
    return megastep.lower(state, jnp.int32(0),
                          jnp.int32(engine.k_visits),
                          jax.random.PRNGKey(seed)).compile()


class MegastepCache:
    """Thread-safe LRU memo of warm megastep executables.

    ``get_or_build`` is the one entry point: a hit returns instantly, a
    miss compiles *outside* the lock while other keys stay available, and
    two threads racing on the same key compile once (the loser waits on
    the winner's in-flight event).  ``warm_async`` wraps it in a daemon
    thread for register-time prewarming that must not block registration.

    ``max_entries`` bounds the memo: a long-lived multi-graph server (or a
    ``bench_serve`` sweep re-registering graphs across points) would
    otherwise accumulate one executable per distinct key forever.  The
    default is generous — pow2 capacity snapping already keeps the live
    key set logarithmic in load, so eviction only bites when graphs churn
    — and every hit/peek refreshes recency, so what gets dropped is the
    executable nothing has asked for longest (``evictions`` in
    ``stats()`` counts the drops).
    """

    def __init__(self, max_entries: int = 256):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self._lock = threading.Lock()
        self._cache: "collections.OrderedDict[tuple, object]" = \
            collections.OrderedDict()
        self._inflight: Dict[tuple, threading.Event] = {}
        self.max_entries = int(max_entries)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compile_s = 0.0      # total seconds spent compiling

    def __len__(self) -> int:
        with self._lock:
            return len(self._cache)

    def peek(self, key: tuple):
        """The executable if already warm, else None; never compiles.
        A found key is refreshed — a peeked executable is about to be
        injected into an executor, which is as live as a hit."""
        with self._lock:
            exe = self._cache.get(key)
            if exe is not None:
                self._cache.move_to_end(key)
            return exe

    def get_or_build(self, session, graph: str, kind: str, capacity: int, *,
                     k_visits: int = 64, fused: bool = False,
                     alpha: float = 0.15, eps: float = 1e-4,
                     schedule: str = "priority", seed: int = 0,
                     k: int = 8, length: int = 32, walk_seed: int = 0):
        key = warm_key(session, graph, kind, k_visits, capacity, fused=fused,
                       alpha=alpha, eps=eps, schedule=schedule, seed=seed,
                       k=k, length=length, walk_seed=walk_seed)
        while True:
            with self._lock:
                if key in self._cache:
                    self.hits += 1
                    self._cache.move_to_end(key)
                    return self._cache[key]
                ev = self._inflight.get(key)
                if ev is None:
                    self._inflight[key] = ev = threading.Event()
                    self.misses += 1
                    building = True
                else:
                    building = False
            if not building:
                ev.wait()
                continue        # winner published (or failed) — re-check
            try:
                t0 = time.perf_counter()
                exe = build_warm_megastep(
                    session, kind, capacity, schedule=schedule, alpha=alpha,
                    eps=eps, seed=seed, k_visits=k_visits, fused=fused,
                    k=k, length=length, walk_seed=walk_seed)
                with self._lock:
                    self._cache[key] = exe
                    self._cache.move_to_end(key)
                    while len(self._cache) > self.max_entries:
                        self._cache.popitem(last=False)
                        self.evictions += 1
                    self.compile_s += time.perf_counter() - t0
                return exe
            finally:
                with self._lock:
                    self._inflight.pop(key, None)
                ev.set()

    def warm_async(self, session, graph: str, kind: str, capacity: int,
                   **params) -> threading.Thread:
        """Fire-and-forget prewarm; returns the (daemon) thread for tests
        that want to join it."""
        t = threading.Thread(
            target=self.get_or_build,
            args=(session, graph, kind, capacity), kwargs=params,
            name=f"warm-{graph}-{kind}-{capacity}", daemon=True)
        t.start()
        return t

    def stats(self) -> dict:
        with self._lock:
            return {"size": len(self._cache), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "max_entries": self.max_entries,
                    "compile_s": round(self.compile_s, 3)}
