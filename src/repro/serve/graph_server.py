"""GraphServer: multi-tenant FPP serving over the streaming megastep.

The paper's fork-processing pattern — many independent queries sharing one
graph — is exactly the shape of a serving workload, and DESIGN.md §4.2
documents this module as its production-facing front end.  A
:class:`GraphServer` accepts a continuous stream of heterogeneous
:class:`GraphRequest`\\ s — mixed kinds (sssp/bfs/ppr/cc/kreach/rw), mixed
priorities, multiple registered graphs, multiple tenants — and multiplexes
them onto per-(graph, kind) **lane pools**, each backed by the §3.3
``StreamingExecutor`` and its device-resident K-visit megastep (§2.3)
(random-walk pools ride the ``WalkExecutor``'s buffered walk visit behind
the same executor surface; ``k``/``length``/``walk_seed`` parameterize the
kreach and rw pools server-wide, exactly as ``alpha``/``eps`` do ppr).

Serving runs as a **continuous-batching engine** with three concurrent
lanes (serve/dispatch.py):

  * **admission** — ``submit`` is thread-safe and never touches a device:
    it books the request, coalesces duplicates, and parks it in the
    pool's backlog (weighted-fair start-time queueing over per-tenant
    virtual time: admitting one request from tenant *t* advances
    ``vtime[t] += 1/weight[t]``, so a hot tenant at 10x offered load gets
    at most its weight share of lanes);
  * **pumping** — one dispatch thread per pool drives
    ``StreamingExecutor.pump``, refilling free lanes from the backlog at
    every megastep chunk boundary — the only points where admission and
    harvest are ever legal (the §3.3 exactness argument, now enforced by
    the executor lock instead of by single-threadedness);
  * **delivery** — a dedicated thread turns finished lanes into
    :class:`GraphResponse`\\ s and wakes ``result(rid, timeout=...)``
    callers, so building/fanning out answers never stalls the next chunk.

Compiles never sit on the serving path: a :class:`MegastepCache`
(serve/compile_cache.py) AOT-compiles megasteps keyed by
``(graph, kind, K, capacity, ...)`` — warmed at ``register_graph`` time
(``prewarm=``) and on every pool resize — and pool capacities snap to
pow2 buckets (``planner.pow2_bucket``) so autoscaling revisits a
logarithmic set of executables instead of retracing per demand level.

Identical in-flight requests — same ``(graph, kind, source, alpha,
eps)`` — coalesce onto one lane at admission time and fan the answer out
on delivery, with the lane's visit/edge/host-sync work billed to *every*
requester (``dedup=False`` to disable).  Deadline-expired queued requests
are rejected with an explicit ``status="expired"`` response, never
silently dropped; an expired coalescing primary promotes its oldest
live follower onto the backlog.

*Completed* answers are reused too: a byte-budgeted LRU of finished
result planes (serve/result_cache.py) is checked in ``submit`` **before**
the dedup window — a repeat of a hot source that already finished is
answered from the cache through the ordinary delivery lane (``cached:
True``, zero billed visits/edges/host_syncs, exact queue wait) without
ever touching a lane; ``_deliver`` populates the cache once per primary.
``update_graph`` re-registers a name with new graph data and bumps its
**epoch** — part of every cache key — so planes computed against the
replaced graph can never be served (the staleness bound for dynamic
graphs); ``result_cache=False`` disables the tier.

    server = GraphServer(capacity=8, prewarm=("sssp",))
    server.register_graph("road", road_csr)
    server.start()                            # spin up the three lanes
    rid = server.submit(GraphRequest(kind="sssp", source=7, graph="road"))
    resp = server.result(rid, timeout=30)     # block for the answer
    server.shutdown()

The synchronous path is still here and unchanged in semantics —
``serve()`` pumps rounds inline with explicit ``PartitionScheduler`` pool
arbitration (request priorities feed it; ``prefer_older_ties`` rotates
equal-priority pools) and is the parity oracle the concurrent lanes are
tested against.  ``serve_forever(arrivals)`` now feeds the arrival stream
to the running lanes and blocks until drained.

``launch/serve.py --workload graph`` and ``benchmarks/bench_serve.py``
drive the same front end with synthetic (open-loop) arrival processes.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import PartitionScheduler
from repro.fpp import planner as _planner
from repro.fpp.session import FPPSession
from repro.serve.compile_cache import MegastepCache, session_uid, warm_key
from repro.serve.result_cache import CacheEntry, ResultCache, result_key

SERVABLE_KINDS = ("sssp", "bfs", "ppr", "cc", "kreach", "rw")

#: stamp value for pools with nothing queued or in flight (never selected —
#: their priority is +inf — but keeps the stamp array total)
_IDLE_STAMP = np.iinfo(np.int64).max - 1


@dataclasses.dataclass
class GraphRequest:
    """One graph query as a tenant submits it (original vertex ids).

    ``priority`` follows the engine's convention: lower is more urgent
    (it orders admission within a pool and feeds the synchronous path's
    pool arbitration).  ``deadline_s`` is a time-to-live from submission:
    a request still *queued* when it lapses is rejected with
    ``status="expired"``; once admitted to a lane it always runs to
    completion.  A coalesced follower shares its primary's fate.
    """
    kind: str
    source: int
    graph: str = "default"
    tenant: str = "default"
    priority: float = 0.0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class GraphResponse:
    """The server's answer: values on success, always an explicit status.

    ``status`` is ``"ok"`` or ``"expired"``.  ``stats`` carries the
    per-request accounting: ``visits`` (executor visits while the request
    was in flight), ``edges`` (exact integral edge work of this lane),
    ``host_syncs`` (device->host round trips billed to the request's
    in-flight window), ``queue_wait_s``/``queue_wait_rounds`` (time and
    scheduling rounds spent waiting for a lane), ``latency_s`` (submit to
    response).  A coalesced follower carries ``coalesced: True`` plus the
    *same* visit/edge/host-sync bill as the lane it rode (per-request
    attribution, not divided); its primary carries ``fanout: n``.
    """
    rid: int
    tenant: str
    graph: str
    kind: str
    source: int
    status: str
    values: Optional[np.ndarray]
    residual: Optional[np.ndarray]
    stats: dict


@dataclasses.dataclass
class _Ticket:
    """Server-side lifecycle record for one request."""
    rid: int
    req: GraphRequest
    submit_t: float
    submit_round: int
    admit_t: float = -1.0
    admit_round: int = -1


class _LanePool:
    """One (graph, kind) lane pool: a StreamingExecutor plus its backlog."""

    def __init__(self, graph: str, kind: str, session: FPPSession,
                 capacity: int, k_visits: int, alpha: float, eps: float,
                 *, fused: bool = False, megastep=None,
                 lock: Optional[threading.RLock] = None,
                 k: int = 8, length: int = 32, walk_seed: int = 0):
        self.graph = graph
        self.kind = kind
        self.session = session
        self.capacity = int(capacity)
        self.k_visits = int(k_visits)
        self.alpha, self.eps = alpha, eps
        self.k = int(k)
        self.length, self.walk_seed = int(length), int(walk_seed)
        self.fused = bool(fused)
        self.exec = session.stream(kind, capacity=self.capacity,
                                   k_visits=self.k_visits,
                                   alpha=alpha, eps=eps,
                                   fused=self.fused, megastep=megastep,
                                   k=self.k, length=self.length,
                                   seed=self.walk_seed)
        # tenant -> heap of (priority, seq, rid): priority then arrival
        self.queues: Dict[str, List[Tuple[float, int, int]]] = {}
        self.qid_rid: Dict[int, int] = {}      # executor qid -> server rid
        self.stamp: int = _IDLE_STAMP          # round backlog became non-empty
        self.retired = False                   # set by update_graph; the
        #                                        pool's worker exits on sight
        # the pump worker parks here while idle; submit() notifies.
        # Shares the server lock so wait/notify and backlog state agree.
        self.cv = threading.Condition(lock or threading.RLock())

    # ------------------------------------------------------------- backlog

    def enqueue(self, tenant: str, prio: float, seq: int, rid: int):
        heapq.heappush(self.queues.setdefault(tenant, []),
                       (float(prio), int(seq), int(rid)))

    @property
    def queued(self) -> int:
        return sum(len(h) for h in self.queues.values())

    @property
    def active(self) -> int:
        return len(self.qid_rid)

    def best_priority(self, tickets: Dict[int, _Ticket]) -> float:
        """Most urgent request priority across backlog + in-flight lanes."""
        best = np.inf
        for heap in self.queues.values():
            if heap:
                best = min(best, heap[0][0])
        for rid in self.qid_rid.values():
            best = min(best, tickets[rid].req.priority)
        return best

    def resize(self, capacity: int, megastep=None):
        """Rebuild the executor at a new capacity.  Only legal when idle
        (no in-flight lane state to move); the backlog is server-side, so
        nothing else changes.  ``megastep`` injects the warm executable
        for the new capacity so the rebuilt executor never traces."""
        if self.active:
            raise RuntimeError("cannot resize a pool with in-flight lanes")
        self.capacity = int(capacity)
        self.exec = self.session.stream(self.kind, capacity=self.capacity,
                                        k_visits=self.k_visits,
                                        alpha=self.alpha, eps=self.eps,
                                        fused=self.fused, megastep=megastep,
                                        k=self.k, length=self.length,
                                        seed=self.walk_seed)
        self.qid_rid = {}


def default_autoscaler(pool_stats: dict) -> int:
    """Planner-backed capacity hint: demand snapped to a pow2 bucket,
    clamped by the memory model."""
    return _planner.autoscale_capacity(
        pool_stats["queued"], pool_stats["active"],
        mem=pool_stats["mem"], n_vertices=pool_stats["n_vertices"],
        block_size=pool_stats["block_size"],
        min_capacity=pool_stats["min_capacity"],
        max_capacity=pool_stats["max_capacity"])


class GraphServer:
    """Multi-tenant continuous-batching front end over lane pools.

    ``capacity`` seeds every pool's lane count, snapped to a pow2 bucket
    (the autoscaler revises it between chunks, bounded by
    ``max_capacity`` and the memory model); ``k_visits`` is each pool's
    megastep chunk size — the scheduling quantum of the whole server,
    since admission, harvest and deadline checks all happen at chunk
    boundaries; ``schedule`` picks the synchronous path's pool-arbitration
    policy (any ``core/scheduler.py`` policy; request priorities feed
    it); ``alpha``/``eps`` parameterize the push (ppr) pools exactly as
    they do ``FPPSession.run``; ``autoscaler`` replaces the default
    capacity hint (callable: pool-stats dict -> suggested capacity, or
    ``None`` to disable resizing); ``clock`` is injectable for
    deterministic deadline tests.

    Continuous-batching knobs: ``fused`` selects each pool's visit body —
    ``"auto"`` (default) picks per kind from the committed dispatch
    yardsticks (``planner.auto_fused``; fused wins for minplus kinds, the
    XLA megastep for ppr — see BENCH_engine.json bench_notes), or
    True/False to force; ``dedup`` coalesces identical in-flight requests
    (see module docstring); ``cache`` shares a :class:`MegastepCache`
    across servers (benchmarks reuse warmth across sweep points);
    ``prewarm`` is the default set of kinds whose megasteps
    ``register_graph`` AOT-compiles in the background; ``idle_wait_s`` is
    how long an idle pump worker parks between deadline checks.

    Result-cache knobs: ``result_cache`` is True (default — a private
    :class:`ResultCache`), False/None (disable the tier), or a
    :class:`ResultCache` instance to share completed planes across
    servers; ``cache_bytes`` fixes its byte budget — by default each
    ``register_graph`` grows the budget to
    ``planner.result_cache_budget`` for the largest graph served (a
    small multiple of one query lane's §3.1 plane set).
    """

    def __init__(self, *, capacity: int = 8, max_capacity: int = 64,
                 k_visits: int = 64, schedule: str = "priority",
                 alpha: float = 0.15, eps: float = 1e-4,
                 k: int = 8, length: int = 32, walk_seed: int = 0,
                 autoscaler: Optional[Callable[[dict], int]]
                 = default_autoscaler,
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0,
                 fused: object = "auto", dedup: bool = True,
                 cache: Optional[MegastepCache] = None,
                 result_cache: object = True,
                 cache_bytes: Optional[int] = None,
                 prewarm: Iterable[str] = (),
                 idle_wait_s: float = 0.05):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if fused not in (True, False, "auto"):
            raise ValueError(f"fused must be True, False or 'auto', "
                             f"got {fused!r}")
        self.capacity = int(capacity)
        self.max_capacity = int(max_capacity)
        self.k_visits = int(k_visits)
        self.alpha, self.eps = float(alpha), float(eps)
        # per-kind answer parameters, server-wide like alpha/eps: the
        # kreach hop budget, the rw walk length and tape seed
        self.k = int(k)
        self.length, self.walk_seed = int(length), int(walk_seed)
        self.autoscaler = autoscaler
        self.clock = clock
        self.fused = fused
        self.dedup = bool(dedup)
        self.cache = cache if cache is not None else MegastepCache()
        if isinstance(result_cache, ResultCache):
            self.result_cache: Optional[ResultCache] = result_cache
        elif result_cache:
            self.result_cache = ResultCache()
        else:
            self.result_cache = None
        self.cache_bytes = None if cache_bytes is None else int(cache_bytes)
        if self.result_cache is not None and self.cache_bytes is not None:
            self.result_cache.reserve(self.cache_bytes)
        self.prewarm = tuple(prewarm)
        self.idle_wait_s = float(idle_wait_s)
        self.rounds = 0
        self.responses: Dict[int, GraphResponse] = {}
        self._sessions: Dict[str, FPPSession] = {}
        self._pools: Dict[Tuple[str, str], _LanePool] = {}
        self._pool_order: List[_LanePool] = []
        self._weights: Dict[str, float] = {}
        self._vtime: Dict[str, float] = {}
        self._tickets: Dict[int, _Ticket] = {}
        self._epochs: Dict[str, int] = {}      # graph name -> update epoch
        self._coalesced_total = 0              # follower rides booked
        self._fanout_total = 0                 # follower responses fanned out
        self._arb = PartitionScheduler(schedule, 0, seed)
        self._next_rid = 0
        self._seq = 0
        # --- continuous-batching state (serve/dispatch.py) ---
        # ONE lock guards all server-side state; pool cvs and the
        # response cv are views of it.  Executor locks nest strictly
        # inside it (server lock -> executor lock, never the reverse).
        self._lock = threading.RLock()
        self._resp_cv = threading.Condition(self._lock)
        self._running = False
        self._workers: List[threading.Thread] = []
        self._delivery = None
        self._outstanding = 0                  # requests without a response
        self._round_budget: Optional[int] = None
        # in-flight dedup: coalesce key -> primary rid; primary rid ->
        # follower rids (fan-out happens at delivery)
        self._dedup: Dict[tuple, int] = {}
        self._followers: Dict[int, List[int]] = {}

    # ---------------------------------------------------------- registration

    def register_graph(self, name: str, graph_or_session,
                       prewarm: Optional[Iterable[str]] = None, **plan_kw):
        """Register a graph under ``name``; requests address it by name.

        Accepts a host CSR graph (a session is planned for it with
        ``plan_kw`` forwarded) or a ready :class:`FPPSession` — passing the
        session a test already ran ``session.run`` on guarantees the served
        plan is identical, which is how the bit-parity tests pin the
        contract.  ``prewarm`` (default: the server's ``prewarm`` set)
        names kinds whose megasteps are AOT-compiled in the background so
        the first request never pays the trace.  Chainable.
        """
        if name in self._sessions:
            raise ValueError(f"graph {name!r} already registered")
        # validate everything before mutating server state or kicking off
        # warm threads: a rejected register_graph must have no effect, so
        # the caller's corrected retry doesn't hit "already registered"
        kinds = self.prewarm if prewarm is None else tuple(prewarm)
        for kind in kinds:
            if kind not in SERVABLE_KINDS:
                raise ValueError(f"prewarm kind must be one of "
                                 f"{SERVABLE_KINDS}, got {kind!r}")
        session = self._build_session(graph_or_session, plan_kw)
        self._sessions[name] = session
        self._epochs.setdefault(name, 0)
        self._reserve_cache_budget(session)
        cap0 = _planner.pow2_bucket(self.capacity,
                                    max_capacity=max(self.max_capacity,
                                                     self.capacity))
        for kind in kinds:
            self.cache.warm_async(session, name, kind, cap0,
                                  **self._warm_params(session, kind))
        return self

    def _build_session(self, graph_or_session, plan_kw: dict) -> FPPSession:
        if isinstance(graph_or_session, FPPSession):
            if plan_kw:
                raise ValueError("plan_kw only applies when registering a "
                                 "raw graph, not a planned FPPSession")
            return graph_or_session
        plan_kw.setdefault("num_queries", self.capacity)
        return FPPSession(graph_or_session).plan(**plan_kw)

    def _reserve_cache_budget(self, session: FPPSession):
        """Grow the result cache's byte budget for this graph: the explicit
        ``cache_bytes`` if given, else the planner's plane-set default."""
        if self.result_cache is None:
            return
        budget = (self.cache_bytes if self.cache_bytes is not None
                  else _planner.result_cache_budget(
                      session.mem, session.graph.n,
                      session.current_plan.block_size))
        self.result_cache.reserve(budget)

    def update_graph(self, name: str, graph_or_session,
                     prewarm: Optional[Iterable[str]] = None, **plan_kw):
        """Re-register ``name`` with new graph data; requests keep the name.

        The dynamic-graph path: the registered name's **epoch** is bumped,
        and since the epoch is part of every result-cache key, planes
        computed against the replaced graph can never be served again —
        staleness is bounded by the update, not by TTL guesswork (the old
        session's entries are also dropped eagerly to free their bytes).
        The name's lane pools are retired (their workers exit; fresh pools
        build from the new session on the next request) and the new
        session's megasteps prewarm exactly as at first registration.

        Only legal while the name has no queued or in-flight work — an
        update must never splice two different graphs into one answer, so
        drain (``wait_drained``) before updating.  Validation happens
        before any mutation: a rejected update leaves the old graph
        serving.  Chainable.
        """
        with self._lock:
            if name not in self._sessions:
                raise ValueError(f"graph {name!r} not registered "
                                 f"(have {sorted(self._sessions)}); use "
                                 f"register_graph for new names")
            kinds = self.prewarm if prewarm is None else tuple(prewarm)
            for kind in kinds:
                if kind not in SERVABLE_KINDS:
                    raise ValueError(f"prewarm kind must be one of "
                                     f"{SERVABLE_KINDS}, got {kind!r}")
            for (g, kind), pool in self._pools.items():
                if g == name and (pool.queued or pool.active):
                    raise RuntimeError(
                        f"cannot update graph {name!r} with requests "
                        f"queued or in flight on pool ({g}, {kind}); "
                        f"drain first (wait_drained)")
            session = self._build_session(graph_or_session, plan_kw)
            old = self._sessions[name]
            self._epochs[name] += 1
            if self.result_cache is not None:
                self.result_cache.invalidate_session(session_uid(old))
            self._sessions[name] = session
            self._reserve_cache_budget(session)
            for key in [k for k in self._pools if k[0] == name]:
                pool = self._pools.pop(key)
                pool.retired = True
                self._pool_order.remove(pool)
                pool.cv.notify_all()
            cap0 = _planner.pow2_bucket(
                self.capacity, max_capacity=max(self.max_capacity,
                                                self.capacity))
            for kind in kinds:
                self.cache.warm_async(session, name, kind, cap0,
                                      **self._warm_params(session, kind))
        return self

    def register_tenant(self, name: str, weight: float = 1.0):
        """Set a tenant's fair-share weight (admissions per unit virtual
        time).  Unknown tenants are auto-registered at weight 1 on first
        submit.  Chainable."""
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        with self._lock:
            self._weights[name] = float(weight)
            self._vtime.setdefault(name, 0.0)
        return self

    def _resolve_fused(self, session: FPPSession, kind: str) -> bool:
        if kind == "rw":
            return False     # the walk visit has no megastep body to fuse
        if self.fused == "auto":
            from repro.core.queries import WEIGHT_VARIANTS
            bg, _ = session.prepared(
                weights=WEIGHT_VARIANTS.get(kind, "natural"))
            return _planner.auto_fused(kind, self.k_visits,
                                       dmax=bg.nbr_part.shape[1])
        return bool(self.fused)

    def _warm_params(self, session: FPPSession, kind: str) -> dict:
        """kwargs completing a megastep cache key for one of our pools —
        everything beyond (graph, kind, capacity)."""
        return dict(k_visits=self.k_visits,
                    fused=self._resolve_fused(session, kind),
                    alpha=self.alpha, eps=self.eps,
                    schedule=session.current_plan.schedule, seed=0,
                    k=self.k, length=self.length, walk_seed=self.walk_seed)

    def _pool(self, graph: str, kind: str) -> _LanePool:
        key = (graph, kind)
        if key not in self._pools:
            session = self._sessions[graph]
            cap = _planner.pow2_bucket(
                self.capacity, max_capacity=max(self.max_capacity,
                                                self.capacity))
            params = self._warm_params(session, kind)
            # peek, don't build: pool creation happens under the server
            # lock (first submit), so a cold cache must not stall it —
            # the executor traces lazily in the pump lane instead
            megastep = self.cache.peek(warm_key(session, graph, kind,
                                                params["k_visits"], cap,
                                                **{k: v for k, v
                                                   in params.items()
                                                   if k != "k_visits"}))
            pool = _LanePool(graph, kind, session, cap, self.k_visits,
                             self.alpha, self.eps, fused=params["fused"],
                             megastep=megastep, lock=self._lock,
                             k=self.k, length=self.length,
                             walk_seed=self.walk_seed)
            self._pools[key] = pool
            self._pool_order.append(pool)
            if self._running:
                self._spawn_worker(pool)
        return self._pools[key]

    # --------------------------------------------------------------- submit

    def _kind_params(self, kind: str) -> tuple:
        """The per-kind answer identity beyond (kind, source, alpha, eps):
        whatever else changes what the lane computes.  Folded into both
        the dedup window and the result-cache key so a kreach answer at
        one hop budget (or a walk at one length/seed) can never be served
        for another."""
        if kind == "kreach":
            return (self.k,)
        if kind == "rw":
            return (self.length, self.walk_seed)
        return ()

    def _dedup_key(self, req: GraphRequest) -> tuple:
        return (req.graph, req.kind, int(req.source), self.alpha,
                self.eps) + self._kind_params(req.kind)

    def _result_key(self, req: GraphRequest) -> tuple:
        """The result-cache key for this request: the dedup identity with
        the graph name replaced by (session uid, epoch) — value identity
        that survives name reuse and bounds staleness across updates."""
        return result_key(session_uid(self._sessions[req.graph]),
                          self._epochs[req.graph], req.kind, req.source,
                          self.alpha, self.eps,
                          params=self._kind_params(req.kind))

    def submit(self, req: GraphRequest) -> int:
        """Book one request; returns its rid (``result``/``poll`` for the
        response).  Thread-safe and device-free: the heavy lifting happens
        on the pump lane at the next chunk boundary."""
        if req.kind not in SERVABLE_KINDS:
            raise ValueError(f"kind must be one of {SERVABLE_KINDS}, "
                             f"got {req.kind!r}")
        with self._lock:
            if req.graph not in self._sessions:
                raise ValueError(f"graph {req.graph!r} not registered "
                                 f"(have {sorted(self._sessions)})")
            n = self._sessions[req.graph].graph.n
            if not 0 <= int(req.source) < n:
                raise ValueError(f"source {req.source} out of range for "
                                 f"graph {req.graph!r} with {n} vertices")
            if req.tenant not in self._weights:
                self.register_tenant(req.tenant)
            rid = self._next_rid
            self._next_rid += 1
            t = _Ticket(rid=rid, req=req, submit_t=self.clock(),
                        submit_round=self.rounds)
            self._tickets[rid] = t
            self._outstanding += 1
            if self.result_cache is not None:
                # completed-answer reuse, checked BEFORE the dedup window:
                # cache covers finished hot sources, dedup the in-flight
                # gap.  A hit never touches a lane — it rides the delivery
                # lane so result()/poll() semantics are unchanged.
                entry = self.result_cache.get(self._result_key(req))
                if entry is not None:
                    self._queue_cached(rid, entry)
                    return rid
            if self.dedup:
                primary = self._dedup.get(self._dedup_key(req))
                if primary is not None:
                    # ride the in-flight twin's lane; answer fans out at
                    # delivery with this request billed the same work
                    self._followers.setdefault(primary, []).append(rid)
                    self._coalesced_total += 1
                    return rid
                self._dedup[self._dedup_key(req)] = rid
            pool = self._pool(req.graph, req.kind)
            if pool.queued == 0 and pool.active == 0:
                pool.stamp = self.rounds
            if not self._tenant_has_work(req.tenant):
                # a tenant returning from idle joins at the busy tenants'
                # pace instead of burning banked virtual time as a
                # monopoly burst
                busy = [self._vtime[tn] for tn in self._weights
                        if tn != req.tenant and self._tenant_has_work(tn)]
                if busy:
                    self._vtime[req.tenant] = max(self._vtime[req.tenant],
                                                  min(busy))
            pool.enqueue(req.tenant, req.priority, self._seq, rid)
            self._seq += 1
            pool.cv.notify_all()
            return rid

    def _tenant_has_work(self, tenant: str) -> bool:
        """True while the tenant has anything queued or in flight — the
        condition under which its virtual time is live rather than banked."""
        for p in self._pool_order:
            if p.queues.get(tenant):
                return True
            for rid in p.qid_rid.values():
                if self._tickets[rid].req.tenant == tenant:
                    return True
        return False

    def submit_all(self, reqs: Iterable[GraphRequest]) -> List[int]:
        return [self.submit(r) for r in reqs]

    # ------------------------------------------------------------ deadlines

    def _expired(self, t: _Ticket, now: float) -> bool:
        d = t.req.deadline_s
        return d is not None and (now - t.submit_t) >= d

    def _reject(self, t: _Ticket, now: float):
        self._finish(GraphResponse(
            rid=t.rid, tenant=t.req.tenant, graph=t.req.graph,
            kind=t.req.kind, source=t.req.source, status="expired",
            values=None, residual=None, stats={
                "queue_wait_s": now - t.submit_t,
                "queue_wait_rounds": self.rounds - t.submit_round,
                "latency_s": now - t.submit_t,
            }))
        key = self._dedup_key(t.req)
        if self._dedup.get(key) == t.rid:
            # an expired coalescing primary hands its lane claim to the
            # oldest follower still inside its own deadline
            del self._dedup[key]
            followers = self._followers.pop(t.rid, [])
            while followers:
                frid = followers.pop(0)
                ft = self._tickets[frid]
                if self._expired(ft, now):
                    self._reject(ft, now)
                    continue
                self._dedup[key] = frid
                if followers:
                    self._followers[frid] = followers
                pool = self._pool(ft.req.graph, ft.req.kind)
                if pool.queued == 0 and pool.active == 0:
                    pool.stamp = self.rounds
                pool.enqueue(ft.req.tenant, ft.req.priority, self._seq, frid)
                self._seq += 1
                pool.cv.notify_all()
                break

    def _police_pool(self, pool: _LanePool, now: float):
        """Reject every queued request in this pool whose deadline lapsed
        (explicit expired response — never a silent drop).

        Two phases: pull expired items out of every tenant heap *first*,
        then reject.  ``_reject`` on a coalescing primary promotes a
        follower via ``pool.enqueue`` — possibly into this very pool —
        which would corrupt a heap still being iterated and let the
        rebuild drop the promotion; rejecting only after the heaps are
        rebuilt makes the promotion an ordinary push."""
        expired: List[_Ticket] = []
        for tenant, heap in list(pool.queues.items()):
            keep = []
            for item in heap:
                t = self._tickets[item[2]]
                if self._expired(t, now):
                    expired.append(t)
                else:
                    keep.append(item)
            if len(keep) != len(heap):
                heapq.heapify(keep)
                pool.queues[tenant] = keep
        for t in expired:
            self._reject(t, now)

    def _police_deadlines(self, now: float):
        for pool in self._pool_order:
            self._police_pool(pool, now)

    # ------------------------------------------------------------ admission

    def _pick_tenant(self, pool: _LanePool) -> Optional[str]:
        """Lowest virtual time among tenants with backlog in this pool
        (name-ordered tie break for determinism)."""
        best = None
        for tenant, heap in pool.queues.items():
            if not heap:
                continue
            key = (self._vtime[tenant], tenant)
            if best is None or key < best[0]:
                best = (key, tenant)
        return None if best is None else best[1]

    def _admit(self, pool: _LanePool, now: float):
        """Fill free lanes by weighted-fair start-time order; expired
        requests discovered here are rejected without charging their
        tenant's virtual time."""
        ex = pool.exec
        while ex.free_slots and pool.queued:
            tenant = self._pick_tenant(pool)
            _, _, rid = heapq.heappop(pool.queues[tenant])
            t = self._tickets[rid]
            if self._expired(t, now):
                self._reject(t, now)
                continue
            qid = ex.submit([t.req.source])[0]
            if ex.queue_depth != 0:
                raise RuntimeError(
                    f"admission must be immediate: lane pool reported a "
                    f"free lane but submit left queue_depth="
                    f"{ex.queue_depth}")
            pool.qid_rid[qid] = rid
            t.admit_t = now
            t.admit_round = self.rounds
            self._vtime[tenant] += 1.0 / self._weights[tenant]

    # -------------------------------------------------------------- delivery

    def _finish(self, resp: GraphResponse):
        """Store a response and wake every ``result``/drain waiter."""
        self.responses[resp.rid] = resp
        self._outstanding = max(0, self._outstanding - 1)
        self._resp_cv.notify_all()

    def _queue_cached(self, rid: int, entry: CacheEntry):
        """Route a cache hit through the delivery lane (inline when the
        lanes aren't running — the synchronous path's fallback, matching
        ``_queue_delivery``)."""
        d = self._delivery
        if d is not None:
            d.put_cached(rid, entry)
        else:
            self._finish_cached(rid, entry, self.clock())

    def _finish_cached(self, rid: int, entry: CacheEntry, now: float):
        """Build and store the response for one cache hit (under the
        server lock).  Zero billed visits/edges/host_syncs — no lane ever
        ran — but exact queue wait: the time from submit until the
        delivery lane got to it."""
        t = self._tickets[rid]
        self._finish(GraphResponse(
            rid=rid, tenant=t.req.tenant, graph=t.req.graph,
            kind=t.req.kind, source=t.req.source, status="ok",
            values=entry.values, residual=entry.residual, stats={
                "visits": 0, "edges": 0.0, "host_syncs": 0,
                "queue_wait_s": now - t.submit_t,
                "queue_wait_rounds": self.rounds - t.submit_round,
                "latency_s": now - t.submit_t,
                "cached": True,
            }))

    def _deliver(self, pool: _LanePool, qids: Iterable[int], now: float):
        """Turn finished executor lanes into responses (+ dedup fan-out)."""
        for qid in qids:
            rid = pool.qid_rid.pop(qid, None)
            if rid is None:
                continue
            t = self._tickets[rid]
            q = pool.exec.queries[qid]
            stats = {
                "visits": q.finished_visit - q.admitted_visit,
                "edges": q.edges,
                "host_syncs": q.finished_sync - q.admitted_sync,
                "queue_wait_s": t.admit_t - t.submit_t,
                "queue_wait_rounds": t.admit_round - t.submit_round,
                "latency_s": now - t.submit_t,
            }
            key = self._dedup_key(t.req)
            if self._dedup.get(key) == rid:
                del self._dedup[key]
            followers = self._followers.pop(rid, [])
            if followers:
                stats["fanout"] = len(followers)
                self._fanout_total += len(followers)
            if (self.result_cache is not None
                    and self._sessions.get(pool.graph) is pool.session):
                # populate once per primary — fan-out followers below ride
                # the same planes; the session-identity guard means a pool
                # that somehow outlived an update_graph can never poison
                # the new epoch (update_graph refuses in-flight work, so
                # this is belt and braces)
                self.result_cache.put(self._result_key(t.req),
                                      q.values, q.residual)
            self._finish(GraphResponse(
                rid=rid, tenant=t.req.tenant, graph=pool.graph,
                kind=pool.kind, source=t.req.source, status="ok",
                values=q.values, residual=q.residual, stats=stats))
            for frid in followers:
                ft = self._tickets[frid]
                self._finish(GraphResponse(
                    rid=frid, tenant=ft.req.tenant, graph=pool.graph,
                    kind=pool.kind, source=ft.req.source, status="ok",
                    values=q.values, residual=q.residual, stats={
                        # the lane's work billed to every requester
                        "visits": stats["visits"], "edges": q.edges,
                        "host_syncs": stats["host_syncs"],
                        "queue_wait_s": max(0.0, t.admit_t - ft.submit_t),
                        "queue_wait_rounds": max(
                            0, t.admit_round - ft.submit_round),
                        "latency_s": now - ft.submit_t,
                        "coalesced": True,
                    }))

    def _queue_delivery(self, pool: _LanePool, qids: List[int]):
        """Hand finished lanes to the delivery thread (inline fallback
        during shutdown, when the delivery lane is already gone)."""
        d = self._delivery
        if d is not None:
            d.put(pool, qids)
        else:
            with self._lock:
                self._deliver(pool, qids, self.clock())

    # ------------------------------------------------------------ autoscale

    def _resize_hint(self, pool: _LanePool) -> Optional[int]:
        """A pow2-snapped target capacity, or None to leave the pool be.
        Only idle pools resize — no in-flight lane state ever moves."""
        if self.autoscaler is None or pool.active:
            return None
        plan = pool.session.current_plan
        hint = int(self.autoscaler({
            "queued": pool.queued, "active": pool.active,
            "capacity": pool.capacity, "mem": plan.mem,
            "n_vertices": pool.session.graph.n,
            "block_size": pool.exec.bg.block_size,
            "min_capacity": 1, "max_capacity": self.max_capacity,
        }))
        if hint < 1:
            return None
        hint = _planner.pow2_bucket(hint, max_capacity=self.max_capacity)
        return hint if hint != pool.capacity else None

    def _warm_executable(self, pool: _LanePool, capacity: int):
        """The warm megastep for this pool at ``capacity`` — compiled now
        if the cache misses (callers keep the server lock released)."""
        return self.cache.get_or_build(
            pool.session, pool.graph, pool.kind, capacity,
            **self._warm_params(pool.session, pool.kind))

    def _apply_resize(self, pool: _LanePool, capacity: int, megastep):
        pool.resize(capacity, megastep=megastep)

    # --------------------------------------------------- continuous batching

    def start(self):
        """Spin up the pump + delivery lanes; idempotent.  Chainable."""
        from repro.serve.dispatch import DeliveryWorker
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._delivery = DeliveryWorker(self)
            self._delivery.start()
            for pool in self._pool_order:
                self._spawn_worker(pool)
        return self

    def _spawn_worker(self, pool: _LanePool):
        from repro.serve.dispatch import PoolWorker
        w = PoolWorker(self, pool)
        self._workers.append(w)
        w.start()

    def _take_round(self) -> bool:
        """Charge one scheduling round against the budget; a spent budget
        halts the lanes (``serve_forever`` then returns what completed)."""
        if self._round_budget is not None and self.rounds >= self._round_budget:
            self._halt_locked()
            return False
        self.rounds += 1
        return True

    def _halt_locked(self):
        self._running = False
        for p in self._pool_order:
            p.cv.notify_all()
        self._resp_cv.notify_all()

    def shutdown(self) -> Dict[int, GraphResponse]:
        """Stop the lanes at their next chunk boundary and join them.
        Unserved requests stay booked — ``start()`` again to resume —
        and the response table so far is returned."""
        with self._lock:
            self._halt_locked()
            workers, self._workers = self._workers, []
            delivery, self._delivery = self._delivery, None
        for w in workers:
            w.join()
        if delivery is not None:
            delivery.stop()
            delivery.join()
        return self.responses

    def wait_drained(self, timeout: Optional[float] = None) -> bool:
        """Block until every booked request has a response (True), the
        lanes halt, or ``timeout`` elapses (False)."""
        with self._lock:
            self._resp_cv.wait_for(
                lambda: self._outstanding == 0 or not self._running, timeout)
            return self._outstanding == 0

    def result(self, rid: int, timeout: Optional[float] = None
               ) -> GraphResponse:
        """Block until ``rid``'s response is ready and return it.

        Requires running lanes (``start``/``serve_forever``) unless the
        response already exists; raises ``KeyError`` for unknown rids,
        ``TimeoutError`` on timeout, ``RuntimeError`` if the server halts
        first."""
        with self._lock:
            resp = self.responses.get(rid)
            if resp is not None:
                return resp
            if rid not in self._tickets:
                raise KeyError(f"unknown request id {rid}")
            if not self._running:
                raise RuntimeError(
                    f"request {rid} has no response and the serving lanes "
                    f"are stopped; start() the server or pump serve()")
            self._resp_cv.wait_for(
                lambda: rid in self.responses or not self._running, timeout)
            resp = self.responses.get(rid)
            if resp is None:
                if self._running:
                    raise TimeoutError(
                        f"request {rid} not served within {timeout}s")
                raise RuntimeError(
                    f"serving lanes halted before request {rid} completed")
            return resp

    # ----------------------------------------------------------------- pump

    @property
    def pending(self) -> int:
        """Requests without a response yet (queued, in flight, or riding
        a coalesced twin's lane)."""
        return self._outstanding

    def _arbitrate(self) -> Optional[_LanePool]:
        if not self._pool_order:
            return None
        prio = np.array([p.best_priority(self._tickets)
                         for p in self._pool_order], dtype=np.float64)
        stamp = np.array([p.stamp for p in self._pool_order], dtype=np.int64)
        ops = np.array([p.queued + p.active for p in self._pool_order],
                       dtype=np.int64)
        idx = self._arb.select(prio, stamp, ops, prefer_older_ties=True)
        return None if idx is None else self._pool_order[idx]

    def step(self) -> bool:
        """One synchronous serving round: police deadlines, arbitrate a
        pool, admit at the chunk boundary, pump one megastep chunk,
        deliver responses, revisit capacity.  Returns False when no pool
        holds work.  The parity oracle for the concurrent lanes — raises
        if they are running (one pump per pool at a time)."""
        with self._lock:
            if self._running:
                raise RuntimeError("step() is the synchronous pump; the "
                                   "background lanes are running — use "
                                   "submit/result, or shutdown() first")
            now = self.clock()
            self._police_deadlines(now)
            pool = self._arbitrate()
            if pool is None:
                return False
            hint = self._resize_hint(pool)
            if hint is not None:
                self._apply_resize(pool, hint,
                                   self._warm_executable(pool, hint))
            self._admit(pool, now)
            if pool.active:
                pool.exec.pump(self.k_visits)
                self._deliver(pool, pool.exec.take_finished(), self.clock())
            if pool.queued == 0 and pool.active == 0:
                pool.stamp = _IDLE_STAMP
            else:
                # refresh: the just-served pool becomes the youngest, so
                # equal-priority pools rotate least-recently-served
                # instead of the oldest stamp monopolizing every tie
                pool.stamp = self.rounds
            self.rounds += 1
            return True

    def serve(self, max_rounds: Optional[int] = None
              ) -> Dict[int, GraphResponse]:
        """Synchronously pump until everything submitted so far has a
        response (or the round budget runs out); returns the response
        table."""
        start = self.rounds
        while self.pending and (max_rounds is None
                                or self.rounds - start < max_rounds):
            if not self.step():
                break
        return self.responses

    def serve_forever(self, arrivals: Optional[
            Iterator[Iterable[GraphRequest]]] = None, *,
            max_rounds: int = 100_000,
            drain_timeout: Optional[float] = None
            ) -> Dict[int, GraphResponse]:
        """Continuous serving: start the lanes, feed the arrival stream
        (an iterator of request batches — iterating it paces the open
        loop; submissions interleave with chunk execution on the pump
        threads), block until drained, then stop the lanes and return the
        response table.  With ``arrivals=None`` the lanes stay up and
        this blocks until ``shutdown()`` is called from another thread.
        ``max_rounds`` bounds total pumped chunks across all pools — a
        spent budget halts the lanes and returns what completed."""
        with self._lock:
            self._round_budget = self.rounds + int(max_rounds)
        self.start()
        try:
            if arrivals is None:
                with self._lock:
                    self._resp_cv.wait_for(lambda: not self._running)
                return self.responses
            for batch in arrivals:
                self.submit_all(batch)
            self.wait_drained(timeout=drain_timeout)
        finally:
            with self._lock:
                self._round_budget = None
            if arrivals is not None:
                self.shutdown()
        return self.responses

    def poll(self, rid: int) -> Optional[GraphResponse]:
        """The response for ``rid``, or None while it is still in the
        queue/in flight."""
        return self.responses.get(rid)

    def stats(self) -> dict:
        """A serving snapshot: per-pool occupancy, both cache tiers, and
        the flat reuse counters — ``cache_*`` (result-cache hits, misses,
        evictions, resident bytes), ``coalesced``/``fanout`` (dedup
        totals) — so ``bench_serve.py`` and operators read one dict
        instead of poking server internals."""
        with self._lock:
            rc = (self.result_cache.stats() if self.result_cache is not None
                  else {"entries": 0, "bytes": 0, "budget_bytes": 0,
                        "hits": 0, "misses": 0, "evictions": 0,
                        "invalidations": 0})
            return {
                "running": self._running,
                "rounds": self.rounds,
                "outstanding": self._outstanding,
                "pools": {f"{p.graph}/{p.kind}": {
                    "capacity": p.capacity, "active": p.active,
                    "queued": p.queued, "fused": p.fused,
                    "visits": p.exec.visits,
                    "host_syncs": p.exec.host_syncs,
                } for p in self._pool_order},
                "epochs": dict(self._epochs),
                "cache_hits": rc["hits"],
                "cache_misses": rc["misses"],
                "cache_evictions": rc["evictions"],
                "cache_bytes": rc["bytes"],
                "coalesced": self._coalesced_total,
                "fanout": self._fanout_total,
                "result_cache": rc,
                "compile_cache": self.cache.stats(),
                # legacy alias (pre-result-cache callers read the compile
                # cache under "cache")
                "cache": self.cache.stats(),
            }
