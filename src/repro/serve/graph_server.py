"""GraphServer: multi-tenant FPP serving over the streaming megastep.

The paper's fork-processing pattern — many independent queries sharing one
graph — is exactly the shape of a serving workload, and DESIGN.md §4.2
documents this module as its production-facing front end.  A
:class:`GraphServer` accepts a continuous stream of heterogeneous
:class:`GraphRequest`\\ s — mixed kinds (sssp/bfs/ppr), mixed priorities,
multiple registered graphs, multiple tenants — and multiplexes them onto
per-(graph, kind) **lane pools**, each backed by the §3.3
``StreamingExecutor`` and its device-resident K-visit megastep (§2.3).

The serving loop is three decisions per round, all at megastep chunk
boundaries (the only points where admission/harvest are ever legal — the
§3.3 exactness argument):

  * **pool arbitration** — which (graph, kind) pool gets the next chunk of
    device time.  Pools are "partitions" to ``core/scheduler.py``'s
    :class:`PartitionScheduler`: pool priority is the best queued/in-flight
    request priority, so request priorities plumb through the same policy
    set that orders partition visits (``prefer_older_ties`` breaks
    equal-priority ties toward the longest-waiting pool);
  * **weighted-fair admission** — which tenant's request takes each free
    lane.  Start-time fair queueing over per-tenant virtual time: admitting
    one request from tenant *t* advances ``vtime[t] += 1/weight[t]``, and
    the lowest vtime among tenants with queued work goes first, so a hot
    tenant at 10x offered load gets at most its weight share of lanes and
    cannot starve the rest (tests/test_graph_server.py pins the bound);
  * **deadline policing** — a request whose deadline lapses while queued is
    *rejected* with an explicit ``status="expired"`` response (never
    silently dropped); it is checked before every admission.

Completed lanes come back as :class:`GraphResponse` with exact per-request
stats (in-flight visits, integral edge work, host syncs billed to the
request, queue wait in seconds and in scheduling rounds).  Between chunks
an idle pool may be resized by the pluggable autoscaling hint (default:
``fpp/planner.autoscale_capacity``, the §3.1 memory model applied to queue
depth), so ``capacity`` tracks load without ever moving an in-flight lane.

    server = GraphServer(capacity=8)
    server.register_graph("road", road_csr)
    rid = server.submit(GraphRequest(kind="sssp", source=7, graph="road"))
    server.serve()                       # synchronous pump until drained
    resp = server.poll(rid)              # values + per-request stats

``launch/serve.py --workload graph`` and ``benchmarks/bench_serve.py``
drive the same pump with synthetic arrival processes.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import PartitionScheduler
from repro.fpp import planner as _planner
from repro.fpp.session import FPPSession

SERVABLE_KINDS = ("sssp", "bfs", "ppr")

#: stamp value for pools with nothing queued or in flight (never selected —
#: their priority is +inf — but keeps the stamp array total)
_IDLE_STAMP = np.iinfo(np.int64).max - 1


@dataclasses.dataclass
class GraphRequest:
    """One graph query as a tenant submits it (original vertex ids).

    ``priority`` follows the engine's convention: lower is more urgent
    (it feeds pool arbitration directly, see module docstring).
    ``deadline_s`` is a time-to-live from submission: a request still
    *queued* when it lapses is rejected with ``status="expired"``; once
    admitted to a lane it always runs to completion.
    """
    kind: str
    source: int
    graph: str = "default"
    tenant: str = "default"
    priority: float = 0.0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class GraphResponse:
    """The server's answer: values on success, always an explicit status.

    ``status`` is ``"ok"`` or ``"expired"``.  ``stats`` carries the
    per-request accounting: ``visits`` (executor visits while the request
    was in flight), ``edges`` (exact integral edge work of this lane),
    ``host_syncs`` (device->host round trips billed to the request's
    in-flight window), ``queue_wait_s``/``queue_wait_rounds`` (time and
    scheduling rounds spent waiting for a lane), ``latency_s`` (submit to
    response).
    """
    rid: int
    tenant: str
    graph: str
    kind: str
    source: int
    status: str
    values: Optional[np.ndarray]
    residual: Optional[np.ndarray]
    stats: dict


@dataclasses.dataclass
class _Ticket:
    """Server-side lifecycle record for one request."""
    rid: int
    req: GraphRequest
    submit_t: float
    submit_round: int
    admit_t: float = -1.0
    admit_round: int = -1


class _LanePool:
    """One (graph, kind) lane pool: a StreamingExecutor plus its backlog."""

    def __init__(self, graph: str, kind: str, session: FPPSession,
                 capacity: int, k_visits: int, alpha: float, eps: float):
        self.graph = graph
        self.kind = kind
        self.session = session
        self.capacity = int(capacity)
        self.k_visits = int(k_visits)
        self.alpha, self.eps = alpha, eps
        self.exec = session.stream(kind, capacity=self.capacity,
                                   k_visits=self.k_visits,
                                   alpha=alpha, eps=eps)
        # tenant -> heap of (priority, seq, rid): priority then arrival
        self.queues: Dict[str, List[Tuple[float, int, int]]] = {}
        self.qid_rid: Dict[int, int] = {}      # executor qid -> server rid
        self.stamp: int = _IDLE_STAMP          # round backlog became non-empty

    # ------------------------------------------------------------- backlog

    def enqueue(self, tenant: str, prio: float, seq: int, rid: int):
        heapq.heappush(self.queues.setdefault(tenant, []),
                       (float(prio), int(seq), int(rid)))

    @property
    def queued(self) -> int:
        return sum(len(h) for h in self.queues.values())

    @property
    def active(self) -> int:
        return len(self.qid_rid)

    def best_priority(self, tickets: Dict[int, _Ticket]) -> float:
        """Most urgent request priority across backlog + in-flight lanes."""
        best = np.inf
        for heap in self.queues.values():
            if heap:
                best = min(best, heap[0][0])
        for rid in self.qid_rid.values():
            best = min(best, tickets[rid].req.priority)
        return best

    def resize(self, capacity: int):
        """Rebuild the executor at a new capacity.  Only legal when idle
        (no in-flight lane state to move); the backlog is server-side, so
        nothing else changes."""
        if self.active:
            raise RuntimeError("cannot resize a pool with in-flight lanes")
        self.capacity = int(capacity)
        self.exec = self.session.stream(self.kind, capacity=self.capacity,
                                        k_visits=self.k_visits,
                                        alpha=self.alpha, eps=self.eps)
        self.qid_rid = {}


def default_autoscaler(pool_stats: dict) -> int:
    """Planner-backed capacity hint: demand clamped by the memory model."""
    return _planner.autoscale_capacity(
        pool_stats["queued"], pool_stats["active"],
        mem=pool_stats["mem"], n_vertices=pool_stats["n_vertices"],
        block_size=pool_stats["block_size"],
        min_capacity=pool_stats["min_capacity"],
        max_capacity=pool_stats["max_capacity"])


class GraphServer:
    """Multi-tenant serving front end over per-(graph, kind) lane pools.

    ``capacity`` seeds every pool's lane count (the autoscaler may revise
    it between chunks, bounded by ``max_capacity`` and the memory model);
    ``k_visits`` is each pool's megastep chunk size — the scheduling
    quantum of the whole server, since admission, harvest, arbitration and
    deadline checks all happen at chunk boundaries; ``schedule`` picks the
    pool-arbitration policy (any ``core/scheduler.py`` policy; request
    priorities feed it); ``alpha``/``eps`` parameterize the push (ppr)
    pools exactly as they do ``FPPSession.run``; ``autoscaler`` replaces
    the default capacity hint
    (callable: pool-stats dict -> suggested capacity, or ``None`` to
    disable resizing); ``clock`` is injectable for deterministic deadline
    tests.
    """

    def __init__(self, *, capacity: int = 8, max_capacity: int = 64,
                 k_visits: int = 64, schedule: str = "priority",
                 alpha: float = 0.15, eps: float = 1e-4,
                 autoscaler: Optional[Callable[[dict], int]]
                 = default_autoscaler,
                 clock: Callable[[], float] = time.monotonic,
                 seed: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.max_capacity = int(max_capacity)
        self.k_visits = int(k_visits)
        self.alpha, self.eps = float(alpha), float(eps)
        self.autoscaler = autoscaler
        self.clock = clock
        self.rounds = 0
        self.responses: Dict[int, GraphResponse] = {}
        self._sessions: Dict[str, FPPSession] = {}
        self._pools: Dict[Tuple[str, str], _LanePool] = {}
        self._pool_order: List[_LanePool] = []
        self._weights: Dict[str, float] = {}
        self._vtime: Dict[str, float] = {}
        self._tickets: Dict[int, _Ticket] = {}
        self._arb = PartitionScheduler(schedule, 0, seed)
        self._next_rid = 0
        self._seq = 0

    # ---------------------------------------------------------- registration

    def register_graph(self, name: str, graph_or_session, **plan_kw):
        """Register a graph under ``name``; requests address it by name.

        Accepts a host CSR graph (a session is planned for it with
        ``plan_kw`` forwarded) or a ready :class:`FPPSession` — passing the
        session a test already ran ``session.run`` on guarantees the served
        plan is identical, which is how the bit-parity tests pin the
        contract.  Chainable.
        """
        if name in self._sessions:
            raise ValueError(f"graph {name!r} already registered")
        if isinstance(graph_or_session, FPPSession):
            if plan_kw:
                raise ValueError("plan_kw only applies when registering a "
                                 "raw graph, not a planned FPPSession")
            self._sessions[name] = graph_or_session
        else:
            plan_kw.setdefault("num_queries", self.capacity)
            self._sessions[name] = FPPSession(graph_or_session).plan(**plan_kw)
        return self

    def register_tenant(self, name: str, weight: float = 1.0):
        """Set a tenant's fair-share weight (admissions per unit virtual
        time).  Unknown tenants are auto-registered at weight 1 on first
        submit.  Chainable."""
        if weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        self._weights[name] = float(weight)
        self._vtime.setdefault(name, 0.0)
        return self

    def _pool(self, graph: str, kind: str) -> _LanePool:
        key = (graph, kind)
        if key not in self._pools:
            pool = _LanePool(graph, kind, self._sessions[graph],
                             self.capacity, self.k_visits,
                             self.alpha, self.eps)
            self._pools[key] = pool
            self._pool_order.append(pool)
        return self._pools[key]

    # --------------------------------------------------------------- submit

    def submit(self, req: GraphRequest) -> int:
        """Enqueue one request; returns its rid (poll for the response)."""
        if req.kind not in SERVABLE_KINDS:
            raise ValueError(f"kind must be one of {SERVABLE_KINDS}, "
                             f"got {req.kind!r}")
        if req.graph not in self._sessions:
            raise ValueError(f"graph {req.graph!r} not registered "
                             f"(have {sorted(self._sessions)})")
        n = self._sessions[req.graph].graph.n
        if not 0 <= int(req.source) < n:
            raise ValueError(f"source {req.source} out of range for graph "
                             f"{req.graph!r} with {n} vertices")
        if req.tenant not in self._weights:
            self.register_tenant(req.tenant)
        rid = self._next_rid
        self._next_rid += 1
        t = _Ticket(rid=rid, req=req, submit_t=self.clock(),
                    submit_round=self.rounds)
        self._tickets[rid] = t
        pool = self._pool(req.graph, req.kind)
        if pool.queued == 0 and pool.active == 0:
            pool.stamp = self.rounds
        if not self._tenant_has_work(req.tenant):
            # a tenant returning from idle joins at the busy tenants' pace
            # instead of burning banked virtual time as a monopoly burst
            busy = [self._vtime[tn] for tn in self._weights
                    if tn != req.tenant and self._tenant_has_work(tn)]
            if busy:
                self._vtime[req.tenant] = max(self._vtime[req.tenant],
                                              min(busy))
        pool.enqueue(req.tenant, req.priority, self._seq, rid)
        self._seq += 1
        return rid

    def _tenant_has_work(self, tenant: str) -> bool:
        """True while the tenant has anything queued or in flight — the
        condition under which its virtual time is live rather than banked."""
        for p in self._pool_order:
            if p.queues.get(tenant):
                return True
            for rid in p.qid_rid.values():
                if self._tickets[rid].req.tenant == tenant:
                    return True
        return False

    def submit_all(self, reqs: Iterable[GraphRequest]) -> List[int]:
        return [self.submit(r) for r in reqs]

    # ------------------------------------------------------------ deadlines

    def _expired(self, t: _Ticket, now: float) -> bool:
        d = t.req.deadline_s
        return d is not None and (now - t.submit_t) >= d

    def _reject(self, t: _Ticket, now: float):
        self.responses[t.rid] = GraphResponse(
            rid=t.rid, tenant=t.req.tenant, graph=t.req.graph,
            kind=t.req.kind, source=t.req.source, status="expired",
            values=None, residual=None, stats={
                "queue_wait_s": now - t.submit_t,
                "queue_wait_rounds": self.rounds - t.submit_round,
                "latency_s": now - t.submit_t,
            })

    def _police_deadlines(self, now: float):
        """Reject every queued request whose deadline lapsed (explicit
        expired response — never a silent drop)."""
        for pool in self._pool_order:
            for tenant, heap in pool.queues.items():
                keep = []
                for item in heap:
                    t = self._tickets[item[2]]
                    if self._expired(t, now):
                        self._reject(t, now)
                    else:
                        keep.append(item)
                if len(keep) != len(heap):
                    heapq.heapify(keep)
                    pool.queues[tenant] = keep

    # ------------------------------------------------------------ admission

    def _pick_tenant(self, pool: _LanePool) -> Optional[str]:
        """Lowest virtual time among tenants with backlog in this pool
        (name-ordered tie break for determinism)."""
        best = None
        for tenant, heap in pool.queues.items():
            if not heap:
                continue
            key = (self._vtime[tenant], tenant)
            if best is None or key < best[0]:
                best = (key, tenant)
        return None if best is None else best[1]

    def _admit(self, pool: _LanePool, now: float):
        """Fill free lanes by weighted-fair start-time order; expired
        requests discovered here are rejected without charging their
        tenant's virtual time."""
        ex = pool.exec
        while ex.free_slots and pool.queued:
            tenant = self._pick_tenant(pool)
            _, _, rid = heapq.heappop(pool.queues[tenant])
            t = self._tickets[rid]
            if self._expired(t, now):
                self._reject(t, now)
                continue
            qid = ex.submit([t.req.source])[0]
            if ex.queue_depth != 0:
                raise RuntimeError(
                    f"admission must be immediate: lane pool reported a "
                    f"free lane but submit left queue_depth="
                    f"{ex.queue_depth}")
            pool.qid_rid[qid] = rid
            t.admit_t = now
            t.admit_round = self.rounds
            self._vtime[tenant] += 1.0 / self._weights[tenant]

    # -------------------------------------------------------------- harvest

    def _collect(self, pool: _LanePool, now: float):
        for qid in [q for q, _ in pool.qid_rid.items()
                    if pool.exec.queries[q].done]:
            rid = pool.qid_rid.pop(qid)
            t = self._tickets[rid]
            q = pool.exec.queries[qid]
            self.responses[rid] = GraphResponse(
                rid=rid, tenant=t.req.tenant, graph=pool.graph,
                kind=pool.kind, source=t.req.source, status="ok",
                values=q.values, residual=q.residual, stats={
                    "visits": q.finished_visit - q.admitted_visit,
                    "edges": q.edges,
                    "host_syncs": q.finished_sync - q.admitted_sync,
                    "queue_wait_s": t.admit_t - t.submit_t,
                    "queue_wait_rounds": t.admit_round - t.submit_round,
                    "latency_s": now - t.submit_t,
                })

    # ------------------------------------------------------------ autoscale

    def _maybe_resize(self, pool: _LanePool):
        if self.autoscaler is None or pool.active:
            return
        plan = pool.session.current_plan
        hint = int(self.autoscaler({
            "queued": pool.queued, "active": pool.active,
            "capacity": pool.capacity, "mem": plan.mem,
            "n_vertices": pool.session.graph.n,
            "block_size": pool.exec.bg.block_size,
            "min_capacity": 1, "max_capacity": self.max_capacity,
        }))
        if hint != pool.capacity and hint >= 1:
            pool.resize(hint)

    # ----------------------------------------------------------------- pump

    @property
    def pending(self) -> int:
        """Requests without a response yet (queued + in flight)."""
        return sum(p.queued + p.active for p in self._pool_order)

    def _arbitrate(self) -> Optional[_LanePool]:
        if not self._pool_order:
            return None
        prio = np.array([p.best_priority(self._tickets)
                         for p in self._pool_order], dtype=np.float64)
        stamp = np.array([p.stamp for p in self._pool_order], dtype=np.int64)
        ops = np.array([p.queued + p.active for p in self._pool_order],
                       dtype=np.int64)
        idx = self._arb.select(prio, stamp, ops, prefer_older_ties=True)
        return None if idx is None else self._pool_order[idx]

    def step(self) -> bool:
        """One serving round: police deadlines, arbitrate a pool, admit at
        the chunk boundary, pump one megastep chunk, harvest responses,
        revisit capacity.  Returns False when no pool holds work."""
        now = self.clock()
        self._police_deadlines(now)
        pool = self._arbitrate()
        if pool is None:
            return False
        self._maybe_resize(pool)
        self._admit(pool, now)
        if pool.active:
            pool.exec.pump(self.k_visits)
            self._collect(pool, self.clock())
        if pool.queued == 0 and pool.active == 0:
            pool.stamp = _IDLE_STAMP
        else:
            # refresh: the just-served pool becomes the youngest, so
            # equal-priority pools rotate least-recently-served instead of
            # the oldest stamp monopolizing every tie
            pool.stamp = self.rounds
        self.rounds += 1
        return True

    def serve(self, max_rounds: Optional[int] = None
              ) -> Dict[int, GraphResponse]:
        """Pump until everything submitted so far has a response (or the
        round budget runs out); returns the response table."""
        start = self.rounds
        while self.pending and (max_rounds is None
                                or self.rounds - start < max_rounds):
            if not self.step():
                break
        return self.responses

    def serve_forever(self, arrivals: Optional[
            Iterator[Iterable[GraphRequest]]] = None, *,
            max_rounds: int = 100_000) -> Dict[int, GraphResponse]:
        """The synchronous serving pump: draw one batch of requests from
        ``arrivals`` per round (an iterator of request iterables — the
        arrival process), interleave with chunk execution, and keep pumping
        until the arrival stream is exhausted and every request has a
        response.  ``max_rounds`` bounds loop iterations — idle ones
        included, so an open-loop arrival stream yielding empty batches
        cannot spin the pump forever."""
        it = iter(arrivals) if arrivals is not None else None
        for _ in range(max_rounds):
            if it is not None:
                batch = next(it, None)
                if batch is None:
                    it = None
                else:
                    self.submit_all(batch)
            progressed = self.step()
            if it is None and not progressed and not self.pending:
                break
        return self.responses

    def poll(self, rid: int) -> Optional[GraphResponse]:
        """The response for ``rid``, or None while it is still in the
        queue/in flight."""
        return self.responses.get(rid)
