"""Serving engine: prefill/decode steps + continuous batching.

The decode step is the paper's technique as a first-class serving feature
(DESIGN.md §4.1): B independent requests are the FPP queries, the KV cache
sharded over the "model" axis is the partitioned shared structure, and each
decode step is one buffered partition visit with an LSE psum as the
boundary-op exchange (models/attention.decode_attend_partitioned).

``ContinuousBatcher`` keeps the decode batch full: a finished sequence's
slot is refilled by running prefill for the next queued request at
batch=1 and *inserting* the resulting cache into the slot (per-sequence
lengths make the insert exact) — inter-query parallelism with no
head-of-line blocking, the serving twin of Alg. 2's dynamic partition
scheduling.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.factory import Model


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def make_prefill_step(model: Model, *, max_len: int, rules=None):
    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch, max_len=max_len,
                                      rules=rules)
        return greedy_sample(logits), state
    return prefill_step


def make_decode_step(model: Model, *, mesh=None, rules=None):
    def decode_step(params, tokens, state):
        logits, state = model.decode(params, tokens, state, mesh=mesh,
                                     rules=rules)
        return greedy_sample(logits)[:, None], logits, state
    return decode_step


def insert_slot(state, pstate, slot: int):
    """Write a batch=1 prefill state into batch slot ``slot``."""
    def ins(dst, src):
        # batch dim: KVCache k/v [L,B,S,...] -> axis 1; length [B] -> 0;
        # ssm/lru leaves [L,B,...] -> axis 1
        if dst.ndim == 1:
            return dst.at[slot].set(src[0])
        return dst.at[:, slot].set(src[:, 0])
    return jax.tree.map(ins, state, pstate)


# ---------------------------------------------------------------------------
# continuous batching


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    extras: Optional[dict] = None  # vlm image_embeds / encdec frames
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class SlotInfo:
    rid: int = -1
    remaining: int = 0


class ContinuousBatcher:
    def __init__(self, model: Model, params, batch_size: int, max_len: int,
                 *, mesh=None, rules=None, decode_fn=None,
                 prefill_fn=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.state = model.decode_state_init(batch_size, max_len)
        self.slots: List[SlotInfo] = [SlotInfo() for _ in range(batch_size)]
        self.queue: collections.deque = collections.deque()
        self.requests: Dict[int, Request] = {}
        self.tokens = np.zeros((batch_size, 1), np.int32)
        self._decode = decode_fn or jax.jit(
            make_decode_step(model, mesh=mesh, rules=rules))
        self._prefill = prefill_fn or make_prefill_step(
            model, max_len=max_len, rules=rules)
        self.steps = 0
        self.tokens_out = 0

    def submit(self, req: Request):
        self.requests[req.rid] = req
        self.queue.append(req.rid)

    def _admit(self):
        for slot in range(self.B):
            if self.slots[slot].rid == -1 and self.queue:
                rid = self.queue.popleft()
                req = self.requests[rid]
                batch = {"tokens": jnp.asarray(req.prompt[None, :],
                                               jnp.int32)}
                if req.extras:
                    batch.update({k: jnp.asarray(v[None])
                                  for k, v in req.extras.items()})
                first, pstate = self._prefill(self.params, batch)
                self.state = insert_slot(self.state, pstate, slot)
                tok = int(np.asarray(first)[0])
                req.generated.append(tok)
                self.tokens_out += 1
                self.tokens[slot, 0] = tok
                self.slots[slot] = SlotInfo(
                    rid=rid, remaining=req.max_new_tokens - 1)

    def step(self) -> bool:
        self._admit()
        if not any(s.rid != -1 for s in self.slots):
            return False
        nxt, logits, self.state = self._decode(
            self.params, jnp.asarray(self.tokens), self.state)
        nxt = np.asarray(nxt)
        self.steps += 1
        for slot, info in enumerate(self.slots):
            if info.rid == -1:
                continue
            req = self.requests[info.rid]
            tok = int(nxt[slot, 0])
            req.generated.append(tok)
            self.tokens_out += 1
            info.remaining -= 1
            if info.remaining <= 0 or (req.eos_id is not None
                                       and tok == req.eos_id):
                req.done = True
                self.slots[slot] = SlotInfo()
            else:
                self.tokens[slot, 0] = tok
        return True

    def run(self, max_steps: int = 10_000):
        while (any(s.rid != -1 for s in self.slots) or self.queue) \
                and self.steps < max_steps:
            if not self.step():
                break
        return {r.rid: r.generated for r in self.requests.values()}
