"""Hot-source result cache: an LRU of *completed* result planes.

PR 8's admission-time dedup coalesces identical **in-flight** requests —
but the FPP workloads the paper motivates (NCP fires tens of thousands of
PPRs whose source popularity is Zipf-skewed) repeat the *same* hot sources
long after the first answer finished, and a repeat arriving a millisecond
after its twin completed recomputes the whole query from scratch.  This
module is the serving layer's answer-reuse tier (DESIGN.md §4.2): a
process-wide, byte-budgeted LRU of finished result planes, keyed exactly
like the dedup window —

    (session_uid, epoch, kind, source, alpha, eps)

``session_uid`` (serve/compile_cache.py) pins an entry to the session
whose graph produced it, so a cache shared across servers can never serve
one graph's plane for a different graph that reuses a registered name;
``epoch`` is the staleness bound for dynamic graphs — ``GraphServer
.update_graph`` bumps the registered name's epoch, so planes computed
against the replaced graph miss by construction even if the same session
object (or uid) is reused.  ``kind`` folds in everything that
distinguishes answer families (bfs runs unit weights; ppr planes depend
on ``alpha``/``eps``, which are keyed explicitly like the dedup key does).

The byte budget is governed by the same §3.1 :class:`MemoryModel` that
sizes everything else: ``fpp/planner.result_cache_budget`` prices the
default as a small multiple of one query lane's HBM plane set
(``MemoryModel.state_bytes`` at Q=1), and ``GraphServer(cache_bytes=...)``
overrides it.  Per-entry accounting is exact (``values.nbytes`` plus the
residual plane when present); inserting past the budget evicts
least-recently-used entries, and an entry larger than the whole budget is
simply not cached — one giant plane must not flush every hot one.

Cached arrays are marked read-only: a hit hands out the *same* plane the
populating response carried (no copy — reuse is the point), so a client
mutating a response in place must fail loudly rather than silently
poisoning every later hit.

``GraphServer.submit`` checks this cache **before** the dedup window —
cache covers completed answers, dedup the in-flight gap — and a hit is
delivered through the ordinary delivery lane with ``cached: True`` and
zero billed visits/edges/host_syncs (no lane was ever touched).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Optional

import numpy as np


def result_key(session_uid: int, epoch: int, kind: str, source: int,
               alpha: float, eps: float, params: tuple = ()) -> tuple:
    """The cache key: the dedup key's identity fields with the graph name
    replaced by (session_uid, epoch) — value identity, not name identity.

    ``params`` carries the extra per-kind answer identity beyond
    (kind, source, alpha, eps): the kreach hop budget, the rw
    (length, seed) pair.  It is part of the tuple, so two kinds whose
    other fields collide (e.g. a cc and an sssp request on the same
    source) still key distinctly through ``kind`` itself."""
    return (int(session_uid), int(epoch), str(kind), int(source),
            float(alpha), float(eps)) + tuple(params)


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    """One completed query's planes (original vertex ids, read-only)."""
    values: np.ndarray
    residual: Optional[np.ndarray]
    nbytes: int


def _freeze(arr: Optional[np.ndarray]) -> Optional[np.ndarray]:
    if arr is not None:
        arr.setflags(write=False)
    return arr


class ResultCache:
    """Thread-safe byte-budgeted LRU of :class:`CacheEntry` planes.

    ``budget_bytes`` may start at 0 and grow later (``reserve`` is
    grow-only): a server derives the default budget per registered graph
    from the planner's memory model, and a cache shared across servers
    keeps the largest budget any of them asked for.  ``get`` refreshes
    recency; ``put`` inserts (or refreshes) and evicts LRU entries until
    the budget holds.  ``invalidate_session`` drops every entry a retired
    session produced — ``update_graph`` calls it so replaced graphs free
    their bytes eagerly instead of waiting for LRU churn (the epoch in the
    key already guarantees they could never be *served*).
    """

    def __init__(self, budget_bytes: int = 0):
        self._lock = threading.Lock()
        self._entries: "collections.OrderedDict[tuple, CacheEntry]" = \
            collections.OrderedDict()
        self.budget_bytes = int(budget_bytes)
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def reserve(self, budget_bytes: int) -> int:
        """Grow the byte budget (never shrinks); returns the live budget."""
        with self._lock:
            self.budget_bytes = max(self.budget_bytes, int(budget_bytes))
            return self.budget_bytes

    # --------------------------------------------------------------- lookup

    def get(self, key: tuple) -> Optional[CacheEntry]:
        """The entry for ``key`` (refreshing its recency), or None."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return entry

    # --------------------------------------------------------------- insert

    def put(self, key: tuple, values: np.ndarray,
            residual: Optional[np.ndarray] = None) -> bool:
        """Cache one completed query's planes; returns True if it stuck.

        The entry's exact byte cost is charged against the budget; LRU
        entries are evicted until it fits.  An entry that cannot fit even
        an empty cache is refused (False) rather than allowed to evict
        everything hot.
        """
        nbytes = int(values.nbytes) + (0 if residual is None
                                       else int(residual.nbytes))
        with self._lock:
            if nbytes > self.budget_bytes:
                return False
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes -= old.nbytes
            while self.bytes + nbytes > self.budget_bytes and self._entries:
                _, evicted = self._entries.popitem(last=False)
                self.bytes -= evicted.nbytes
                self.evictions += 1
            self._entries[key] = CacheEntry(
                values=_freeze(values), residual=_freeze(residual),
                nbytes=nbytes)
            self.bytes += nbytes
            return True

    # ----------------------------------------------------------- invalidate

    def invalidate_session(self, session_uid: int) -> int:
        """Drop every entry produced by ``session_uid``; returns the count.

        Epoch keying already makes stale entries unservable — this frees
        their bytes at ``update_graph`` time instead of via LRU pressure.
        """
        uid = int(session_uid)
        with self._lock:
            doomed = [k for k in self._entries if k[0] == uid]
            for k in doomed:
                self.bytes -= self._entries.pop(k).nbytes
            self.invalidations += len(doomed)
            return len(doomed)

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "bytes": self.bytes,
                    "budget_bytes": self.budget_bytes, "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "invalidations": self.invalidations}
