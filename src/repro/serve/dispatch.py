"""Concurrent serving lanes: dispatch workers + the delivery lane.

The continuous-batching :class:`~repro.serve.graph_server.GraphServer`
(DESIGN.md §4.2) splits serving into three lanes:

  * **admission** — caller threads in ``GraphServer.submit`` (backlog,
    dedup, fair-queueing bookkeeping; never touches an executor);
  * **pumping** — one :class:`PoolWorker` thread per lane pool, driving
    ``StreamingExecutor.pump`` chunk after chunk and refilling lanes at
    every chunk boundary;
  * **delivery** — one :class:`DeliveryWorker` turning finished lanes
    into ``GraphResponse``\\ s and waking blocked ``result()`` callers.

This module owns the two background lanes; the server owns all shared
state and its one lock.  Why the threads compose safely: every structure
has exactly one lock.  Server-side state (backlogs, tickets, virtual
times, responses) is guarded by the server lock; executor state by the
executor's own lock, acquired strictly after the server lock and never
the other way around.  A worker admits under the server lock, then pumps
*outside* it (the executor lock serializes the chunk), so a chunk in
flight never blocks submissions — a submit racing its own pool's chunk
simply parks on the executor lock and lands at the next chunk boundary,
the only point where lane mutation was ever legal (§3.3 exactness).

Pool workers replace the synchronous path's explicit pool arbitration:
each pool pumps on its own thread and the OS scheduler interleaves them,
while request priorities still shape *admission order* within a pool.
The synchronous ``GraphServer.step``/``serve`` path (the parity oracle)
keeps the original ``PartitionScheduler`` arbitration.
"""
from __future__ import annotations

import queue
import threading


class PoolWorker(threading.Thread):
    """The pump lane for one (graph, kind) pool.

    Per iteration, under the server lock: police deadlines, take a resize
    hint (idle pools only), admit queued requests into free lanes.  Then
    *outside* the lock: either warm the resize target through the compile
    cache and apply it, or pump one megastep chunk and hand finished
    lanes to the delivery queue.  Idle pools park on their condition
    variable (woken by ``submit``) with a short timeout so deadline
    policing and shutdown flags are still observed while quiet.
    """

    def __init__(self, server, pool):
        super().__init__(name=f"pump-{pool.graph}-{pool.kind}", daemon=True)
        self.server = server
        self.pool = pool

    def run(self):
        srv, pool = self.server, self.pool
        while True:
            with srv._lock:
                if not srv._running or pool.retired:
                    # retired: update_graph replaced this pool's graph —
                    # the pool was drained by contract, so exiting loses
                    # nothing; fresh pools get fresh workers
                    return
                now = srv.clock()
                srv._police_pool(pool, now)
                hint = srv._resize_hint(pool)
                if hint is None:
                    srv._admit(pool, now)
                    if not pool.active:
                        pool.cv.wait(timeout=srv.idle_wait_s)
                        continue
                    if not srv._take_round():
                        return
            if hint is not None:
                # compile outside the lock: a cache miss (seconds) must
                # not stall admission to other pools
                exe = srv._warm_executable(pool, hint)
                with srv._lock:
                    if srv._running and pool.active == 0 \
                            and pool.capacity != hint:
                        srv._apply_resize(pool, hint, exe)
                continue
            pool.exec.pump(srv.k_visits)
            done = pool.exec.take_finished()
            if done:
                srv._queue_delivery(pool, done)


class DeliveryWorker(threading.Thread):
    """The delivery lane: a queue of (pool, finished qids) batches from
    the pump workers, turned into responses under the server lock.

    Decoupling delivery from pumping means a pool's next chunk dispatches
    while the previous chunk's answers are still being built/fanned out.
    ``stop()`` enqueues a sentinel; the server joins pump workers first,
    so every delivery batch precedes the sentinel and none is dropped.
    """

    def __init__(self, server):
        super().__init__(name="serve-delivery", daemon=True)
        self.server = server
        self.q: queue.Queue = queue.Queue()

    def put(self, pool, qids):
        self.q.put(("lanes", pool, list(qids)))

    def put_cached(self, rid, entry):
        """Queue one result-cache hit: same delivery lane, same
        ``result()``/``poll()`` wake-up path as a lane-computed answer —
        a cached response is distinguishable only by its stats."""
        self.q.put(("cached", rid, entry))

    def stop(self):
        self.q.put(None)

    def run(self):
        srv = self.server
        while True:
            item = self.q.get()
            if item is None:
                return
            tag, a, b = item
            with srv._lock:
                if tag == "cached":
                    srv._finish_cached(a, b, srv.clock())
                else:
                    srv._deliver(a, b, srv.clock())
