"""Serving front ends (DESIGN.md §4): LM decode + multi-tenant graph FPP.

``engine.py`` (§4.1) serves LM decode via continuous batching;
``graph_server.py`` (§4.2) serves mixed graph-query traffic over the
streaming megastep with concurrent admission/pump/delivery lanes
(``dispatch.py``), warm AOT-compiled megasteps (``compile_cache.py``),
and a byte-budgeted LRU of completed result planes for hot-source reuse
(``result_cache.py``).
"""
from repro.serve.compile_cache import (MegastepCache,  # noqa
                                       build_warm_megastep, session_uid,
                                       warm_key)
from repro.serve.engine import (ContinuousBatcher, Request,  # noqa
                                make_decode_step, make_prefill_step)
from repro.serve.graph_server import (GraphRequest, GraphResponse,  # noqa
                                      GraphServer, default_autoscaler)
from repro.serve.result_cache import (CacheEntry, ResultCache,  # noqa
                                      result_key)
