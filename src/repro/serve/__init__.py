from repro.serve.engine import (ContinuousBatcher, Request,  # noqa
                                make_decode_step, make_prefill_step)
