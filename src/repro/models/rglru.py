"""RG-LRU recurrent block (recurrentgemma-2b / Griffin).

Temporal mix for the "recurrent" layers of the 1:2 hybrid pattern:

    r_t = sigmoid(w_a ⊙ x_t + b_a)          (recurrence gate)
    i_t = sigmoid(w_x ⊙ x_t + b_x)          (input gate)
    a_t = exp(c * r_t * log(sigmoid(Λ)))    (per-channel decay, c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) (i_t ⊙ x_t)

Simplification vs the paper's block-diagonal gate matrices: gates here are
per-channel (diagonal) — this keeps the recurrence strictly channel-local,
so the "inner" dim shards over the "model" mesh axis with zero communication
inside the scan (DESIGN.md §2 records the change).  Like ssm.py the train
path is an associative_scan; decode is one O(1) step, which is why
recurrentgemma runs the long_500k cell (window attention bounds the KV).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, HybridConfig
from repro.models.layers import _normal

_C = 8.0


class LRUState(NamedTuple):
    conv: jax.Array   # [..., B, conv_width-1, W]
    h: jax.Array      # [..., B, W] (float32)


def lru_width(cfg: ArchConfig) -> int:
    h = cfg.hybrid or HybridConfig()
    return h.lru_width or cfg.d_model


def init_rglru(key, cfg: ArchConfig, dtype, conv_width=4):
    d, w = cfg.d_model, lru_width(cfg)
    ks = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(d)
    # Λ init so a ∈ [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[3], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.sqrt(u) / (1 - jnp.sqrt(u)))  # logit of sqrt(u)
    p = {"in_x": _normal(ks[0], (d, w), dtype, s),
         "in_gate": _normal(ks[1], (d, w), dtype, s),
         "conv_w": _normal(ks[2], (conv_width, w), dtype, 1.0 / np.sqrt(w)),
         "conv_b": jnp.zeros((w,), dtype),
         "w_a": jnp.zeros((w,), jnp.float32),
         "b_a": jnp.zeros((w,), jnp.float32),
         "w_x": jnp.zeros((w,), jnp.float32),
         "b_x": jnp.zeros((w,), jnp.float32),
         "lam": lam,
         "out": _normal(ks[4], (w, d), dtype, 1.0 / np.sqrt(w))}
    a = {"in_x": ("embed", "inner"), "in_gate": ("embed", "inner"),
         "conv_w": ("conv", "inner"), "conv_b": ("inner",),
         "w_a": ("inner",), "b_a": ("inner",), "w_x": ("inner",),
         "b_x": ("inner",), "lam": ("inner",), "out": ("inner", "embed")}
    return p, a


def _gates(p, xc):
    """xc: [B,S,W] (post-conv) -> (log_a, bx) float32."""
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(p["w_a"] * xf + p["b_a"])
    i = jax.nn.sigmoid(p["w_x"] * xf + p["b_x"])
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"])       # [B,S,W]
    a2 = jnp.exp(2.0 * log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * i * xf
    return log_a, bx


SCAN_CHUNK = 1024


def apply_rglru(p, x, state: LRUState | None = None,
                chunk: int = SCAN_CHUNK):
    """x: [B,S,D] -> (y [B,S,D], new_state).  Long sequences run as a
    static python loop of seeded chunks (see ssm.apply_ssm)."""
    S = x.shape[1]
    if chunk and S > chunk and S % chunk == 0:
        ys = []
        for i in range(S // chunk):
            y, state = _apply_rglru_core(p, x[:, i * chunk:(i + 1) * chunk],
                                         state)
            ys.append(y)
        return jnp.concatenate(ys, axis=1), state
    return _apply_rglru_core(p, x, state)


def _apply_rglru_core(p, x, state: LRUState | None = None):
    from repro.models.ssm import _causal_conv
    xw = jnp.einsum("bsd,dw->bsw", x, p["in_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dw->bsw", x, p["in_gate"].astype(x.dtype))
    conv_state = state.conv if state is not None else None
    xc, conv_state = _causal_conv(xw, p["conv_w"], p["conv_b"], conv_state)
    log_a, bx = _gates(p, xc)
    a = jnp.exp(log_a)
    b = bx
    if state is not None:
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([state.h[:, None], b], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    ha, hb = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = hb if state is None else hb[:, 1:]              # [B,S,W] f32
    y = h * jax.nn.gelu(gate.astype(jnp.float32))
    out = jnp.einsum("bsw,wd->bsd", y.astype(x.dtype),
                     p["out"].astype(x.dtype))
    return out, LRUState(conv=conv_state, h=h[:, -1])


def decode_rglru(p, x, state: LRUState):
    """One-token step.  x: [B,1,D]."""
    from repro.models.ssm import _causal_conv
    xw = jnp.einsum("bsd,dw->bsw", x, p["in_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dw->bsw", x, p["in_gate"].astype(x.dtype))
    xc, conv_state = _causal_conv(xw, p["conv_w"], p["conv_b"], state.conv)
    log_a, bx = _gates(p, xc)
    h = state.h * jnp.exp(log_a[:, 0]) + bx[:, 0]       # [B,W]
    y = h[:, None] * jax.nn.gelu(gate.astype(jnp.float32))
    out = jnp.einsum("bsw,wd->bsd", y.astype(x.dtype),
                     p["out"].astype(x.dtype))
    return out, LRUState(conv=conv_state, h=h)


def init_lru_state(cfg: ArchConfig, batch, dtype, n=None, conv_width=4):
    w = lru_width(cfg)
    L = (n,) if n else ()
    return LRUState(conv=jnp.zeros(L + (batch, conv_width - 1, w), dtype),
                    h=jnp.zeros(L + (batch, w), jnp.float32))


def lru_state_specs(cfg: ArchConfig, batch, dtype, n=None, conv_width=4):
    w = lru_width(cfg)
    L = (n,) if n else ()
    return LRUState(
        conv=jax.ShapeDtypeStruct(L + (batch, conv_width - 1, w), dtype),
        h=jax.ShapeDtypeStruct(L + (batch, w), jnp.float32))
