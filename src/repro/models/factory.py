"""Unified model API over the 10-arch zoo.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions of
(params, batch/state): ``loss`` (train), ``prefill``, ``decode`` (serve).
``input_specs`` produces ShapeDtypeStruct stand-ins for every model input of
an (arch x shape) cell — weak-type-correct, shardable, no device allocation —
which is what launch/dryrun.py lowers against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models import encdec as encdec_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.attention import KVCache
from repro.models.sharding import AxisRules
from repro.models.transformer import DecodeState


def cross_entropy(logits, labels, mask):
    """logits: [B,S,V] f32; labels: [B,S] int32; mask: [B,S]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll) / denom


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    aux_weight: float = 0.01

    # -- init ---------------------------------------------------------------
    def init(self, key):
        if self.cfg.family == "encdec":
            return encdec_lib.init_encdec(key, self.cfg)
        return tfm.init_params(key, self.cfg)

    # -- train --------------------------------------------------------------
    def logits(self, params, batch, rules: AxisRules = None, remat=True):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_lib.forward(params, cfg, batch["tokens"],
                                      batch["frames"], rules, remat=remat)
        if cfg.family == "vlm":
            lg, aux = tfm.forward(
                params, cfg, batch["tokens"], rules=rules,
                prefix_embeds=batch["image_embeds"],
                prefix_len=cfg.num_image_tokens, remat=remat)
            return lg[:, cfg.num_image_tokens:], aux
        return tfm.forward(params, cfg, batch["tokens"], rules=rules,
                           remat=remat)

    def loss(self, params, batch, rules: AxisRules = None, remat=True):
        logits, aux = self.logits(params, batch, rules, remat)
        ce = cross_entropy(logits, batch["labels"], batch["loss_mask"])
        loss = ce + self.aux_weight * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}

    # -- serve --------------------------------------------------------------
    def prefill(self, params, batch, *, max_len=None, rules=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_lib.prefill(params, cfg, batch["tokens"],
                                      batch["frames"], max_len=max_len,
                                      rules=rules)
        if cfg.family == "vlm":
            return tfm.prefill(params, cfg, batch["tokens"],
                               max_len=max_len, rules=rules,
                               prefix_embeds=batch["image_embeds"],
                               prefix_len=cfg.num_image_tokens)
        return tfm.prefill(params, cfg, batch["tokens"], max_len=max_len,
                           rules=rules)

    def decode(self, params, tokens, state, *, mesh=None, rules=None):
        if self.cfg.family == "encdec":
            return encdec_lib.decode_step(params, self.cfg, tokens, state,
                                          mesh=mesh, rules=rules)
        return tfm.decode_step(params, self.cfg, tokens, state, mesh=mesh,
                               rules=rules)

    # -- spec builders (dry-run) ---------------------------------------------
    def n_attn_layers(self) -> int:
        if self.cfg.family == "hybrid":
            return self.cfg.n_layers // 3
        if self.cfg.family == "ssm":
            return 0
        return self.cfg.n_layers

    def decode_state_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = cfg.cdtype
        if cfg.family == "encdec":
            return encdec_lib.state_specs(cfg, batch, max_len, dt)
        kv = ssm = lru = None
        if cfg.family == "ssm":
            ssm = ssm_lib.ssm_state_specs(cfg, batch, dt, cfg.n_layers)
        elif cfg.family == "hybrid":
            n_rec = cfg.n_layers - self.n_attn_layers()
            lru = rglru_lib.lru_state_specs(cfg, batch, dt, n_rec)
            cache_len = min(max_len, cfg.hybrid.window)
            kv = KVCache.specs(self.n_attn_layers(), batch, cache_len,
                               cfg.n_kv_heads, cfg.head_dim_, dt)
        else:
            kv = KVCache.specs(cfg.n_layers, batch, max_len,
                               cfg.n_kv_heads, cfg.head_dim_, dt)
        return DecodeState(kv=kv, ssm=ssm, lru=lru)

    def decode_state_init(self, batch: int, max_len: int, *, filled=0):
        """Concrete zero state (tests / serving loop)."""
        specs = self.decode_state_specs(batch, max_len)
        length = jnp.full((batch,), filled, jnp.int32)

        def zero(s):
            return jnp.zeros(s.shape, s.dtype)
        st = jax.tree.map(zero, specs)
        if self.cfg.family == "encdec":
            return st._replace(self_kv=st.self_kv._replace(length=length))
        if st.kv is not None:
            st = st._replace(kv=st.kv._replace(length=length))
        return st


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, per the dry-run contract)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training/prefill batches for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    cdt = cfg.cdtype
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "encdec":
        base = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "frames": jax.ShapeDtypeStruct(
                    (B, encdec_lib.N_FRAMES, cfg.d_model), cdt)}
    elif cfg.family == "vlm":
        S_text = S - cfg.num_image_tokens
        base = {"tokens": jax.ShapeDtypeStruct((B, S_text), i32),
                "image_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.num_image_tokens, cfg.d_model), cdt)}
    else:
        base = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "train":
        lbl = base["tokens"].shape
        base["labels"] = jax.ShapeDtypeStruct(lbl, i32)
        base["loss_mask"] = jax.ShapeDtypeStruct(lbl, f32)
    return base


def batch_logical_axes(cfg: ArchConfig, shape: ShapeConfig):
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        out[k] = ("batch",) + ("null",) * (len(v.shape) - 1)
    return out


def state_logical_axes(model: Model, specs) -> Any:
    """Logical axes tree matching decode_state_specs output."""
    cfg = model.cfg
    shard_kv_seq = cfg.family not in ("hybrid",)  # window cache stays local

    def kv_axes(kvspec):
        seq = "seq_kv" if shard_kv_seq else "null"
        return KVCache(k=("layers", "batch", seq, "null", "null"),
                       v=("layers", "batch", seq, "null", "null"),
                       length=("batch",))
    if cfg.family == "encdec":
        return encdec_lib.EncDecState(
            self_kv=kv_axes(specs.self_kv),
            cross_k=("layers", "batch", "null", "null", "null"),
            cross_v=("layers", "batch", "null", "null", "null"))
    kv = ssm = lru = None
    if specs.kv is not None:
        kv = kv_axes(specs.kv)
    if specs.ssm is not None:
        ssm = ssm_lib.SSMState(conv=("layers", "batch", "null", "inner"),
                               h=("layers", "batch", "inner", "null"))
    if specs.lru is not None:
        lru = rglru_lib.LRUState(conv=("layers", "batch", "null", "inner"),
                                 h=("layers", "batch", "inner"))
    return DecodeState(kv=kv, ssm=ssm, lru=lru)
