"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a STUB per the assignment spec: ``input_specs``
provides precomputed frame embeddings [B, n_frames, d_model] (n_frames =
1500 for 30 s of audio at 50 Hz post-conv).  Positions are sinusoidal for
both stacks (adaptation: whisper's decoder uses a learned table; a learned
table cannot cover the assigned 32k decode shape, recorded in DESIGN.md).

Decode state = growing self-attention KV + static cross-attention KV
(encoder memory is projected once at prefill).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.attention import KVCache
from repro.models.layers import _normal
from repro.models.sharding import constrain

N_FRAMES = 1500       # whisper: 30 s @ 50 Hz post-conv
N_FRAMES_PAD = 1536   # padded to a multiple of 16 so the encoder sequence
#                       shards over the "model" axis (1500 % 16 != 0 would
#                       silently drop the constraint); padded positions are
#                       masked out of both self- and cross-attention.


class EncDecState(NamedTuple):
    self_kv: KVCache
    cross_k: jax.Array    # [L, B, F, Hkv, hd]
    cross_v: jax.Array


def sinusoidal(positions, d):
    """positions: [...] -> [..., d] float32."""
    half = d // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_cross_attention(key, d, n_heads, n_kv, head_dim, dtype):
    p, a = attn.init_attention(key, d, n_heads, n_kv, head_dim, dtype)
    return p, a


def init_encdec(key, cfg: ArchConfig):
    d, dt = cfg.d_model, cfg.pdtype
    n_enc = cfg.n_enc_layers or cfg.n_layers
    keys = jax.random.split(key, 4)
    vocab_p = L.pad_vocab(cfg.vocab)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(keys[0], vocab_p, d, dt,
                                              cfg.tie_embeddings)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        lp, la = {}, {}
        lp["ln1"], la["ln1"] = L.init_norm(dt, d, cfg.norm)
        lp["attn"], la["attn"] = attn.init_attention(
            k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_, dt)
        lp["ln2"], la["ln2"] = L.init_norm(dt, d, cfg.norm)
        lp["mlp"], la["mlp"] = L.init_mlp(k2, d, cfg.d_ff, dt,
                                          cfg.gated_mlp)
        return lp, la

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        lp, la = enc_layer(k)
        lp["ln_x"], la["ln_x"] = L.init_norm(dt, d, cfg.norm)
        lp["xattn"], la["xattn"] = init_cross_attention(
            k3, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_, dt)
        return lp, la

    eps, eas = zip(*[enc_layer(k) for k in jax.random.split(keys[1], n_enc)])
    p["encoder"], a["encoder"] = (L.stack_layers(list(eps)),
                                  L.add_layer_axis(eas[0]))
    dps, das = zip(*[dec_layer(k)
                     for k in jax.random.split(keys[2], cfg.n_layers)])
    p["decoder"], a["decoder"] = (L.stack_layers(list(dps)),
                                  L.add_layer_axis(das[0]))
    p["enc_norm"], a["enc_norm"] = L.init_norm(dt, d, cfg.norm)
    p["final_norm"], a["final_norm"] = L.init_norm(dt, d, cfg.norm)
    return p, a


def _self_block(lp, cfg, x, positions, rules, causal, kv_mask=None):
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    q, k, v = attn.qkv_proj(lp["attn"], h, positions, 0.0)
    if rules is not None:
        q = constrain(q, rules, ("batch", "seq", "act_heads", None))
    o = attn.attend(q, k, v, positions, positions, causal=causal,
                    kv_mask=kv_mask)
    return x + attn.out_proj(lp["attn"], o), (k, v)


def _cross_block(lp, cfg, x, memory, rules, kv_mask=None):
    h = L.apply_norm(lp["ln_x"], x, cfg.norm)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", memory,
                   lp["xattn"]["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory,
                   lp["xattn"]["wv"].astype(h.dtype))
    o = attn.attend(q, k, v, jnp.arange(h.shape[1]),
                    jnp.arange(memory.shape[1]), causal=False,
                    kv_mask=kv_mask)
    return x + attn.out_proj(lp["xattn"], o), (k, v)


def pad_frames(frames):
    """[B,F,D] -> ([B,F_pad,D], mask [B,F_pad])."""
    B, F, D = frames.shape
    pad = N_FRAMES_PAD - F
    if pad > 0:
        frames = jnp.pad(frames, [(0, 0), (0, pad), (0, 0)])
    mask = jnp.arange(frames.shape[1])[None, :] < F
    return frames, jnp.broadcast_to(mask, (B, frames.shape[1]))


def _mlp_block(lp, cfg, x):
    h = L.apply_norm(lp["ln2"], x, cfg.norm)
    return x + L.apply_mlp(lp["mlp"], h, cfg.act)


def encode(params, cfg: ArchConfig, frames, rules=None, remat=True):
    """frames: [B,F,D] stub embeddings -> (memory [B,F_pad,D],
    mask [B,F_pad])."""
    x, mask = pad_frames(frames.astype(cfg.cdtype))
    x = x + sinusoidal(jnp.arange(x.shape[1]),
                       cfg.d_model).astype(x.dtype)[None]
    pos = jnp.arange(x.shape[1])

    def body(x, lp):
        x, _ = _self_block(lp, cfg, x, pos, rules, causal=False,
                           kv_mask=mask)
        x = _mlp_block(lp, cfg, x)
        return x, None
    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm), mask


def forward(params, cfg: ArchConfig, tokens, frames, rules=None,
            remat=True):
    """Train forward.  tokens: [B,S]; frames: [B,F,D]."""
    memory, enc_mask = encode(params, cfg, frames, rules, remat)
    x = L.embed(params["embed"], tokens, cfg.cdtype, rules)
    S = x.shape[1]
    x = x + sinusoidal(jnp.arange(S), cfg.d_model).astype(x.dtype)[None]
    pos = jnp.arange(S)

    def body(x, lp):
        x, _ = _self_block(lp, cfg, x, pos, rules, causal=True)
        x, _ = _cross_block(lp, cfg, x, memory, rules, kv_mask=enc_mask)
        x = _mlp_block(lp, cfg, x)
        return x, None
    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x.astype(jnp.float32), cfg.vocab)
    return logits, jnp.float32(0.0)


def prefill(params, cfg: ArchConfig, tokens, frames, *, max_len=None,
            rules=None):
    memory, enc_mask = encode(params, cfg, frames, rules)
    x = L.embed(params["embed"], tokens, cfg.cdtype, rules)
    B, S = tokens.shape
    max_len = max_len or S
    x = x + sinusoidal(jnp.arange(S), cfg.d_model).astype(x.dtype)[None]
    pos = jnp.arange(S)

    def pad_kv(k):
        return k if S >= max_len else jnp.pad(
            k, [(0, 0), (0, max_len - S), (0, 0), (0, 0)])

    def body(x, lp):
        x, kv = _self_block(lp, cfg, x, pos, rules, causal=True)
        x, xkv = _cross_block(lp, cfg, x, memory, rules, kv_mask=enc_mask)
        x = _mlp_block(lp, cfg, x)
        return x, (pad_kv(kv[0]), pad_kv(kv[1]), xkv[0], xkv[1])
    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["decoder"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    last = L.unembed(params["embed"], x[:, -1].astype(jnp.float32),
                     cfg.vocab)
    length = jnp.full((B,), S, jnp.int32)
    return last, EncDecState(self_kv=KVCache(k=ks, v=vs, length=length),
                             cross_k=xks, cross_v=xvs)


def decode_step(params, cfg: ArchConfig, tokens, state: EncDecState, *,
                mesh=None, rules=None):
    """tokens: [B,1] -> (logits [B,V], state)."""
    x = L.embed(params["embed"], tokens, cfg.cdtype, rules)
    length = state.self_kv.length
    x = x + sinusoidal(length[:, None], cfg.d_model).astype(x.dtype)

    def _idx(tree, i):
        return jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, i, 0,
                                                   keepdims=False), tree)

    def body(i, carry):
        # in-place stacked-cache update (see transformer.decode_step)
        x, ks, vs = carry
        lp = _idx(params["decoder"], i)
        kc, vc = _idx(ks, i), _idx(vs, i)
        xk, xv = _idx(state.cross_k, i), _idx(state.cross_v, i)
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        q, k, v = attn.qkv_proj(lp["attn"], h, length[:, None], 0.0)
        kc, vc = attn.cache_update_local(kc, vc, k, v, length)
        if mesh is not None and "model" in mesh.axis_names:
            o = attn.decode_attend_partitioned(q[:, 0], kc, vc, length + 1,
                                               mesh)
        else:
            o = attn.decode_attend_local(q[:, 0], kc, vc,
                                         jnp.arange(kc.shape[1]),
                                         length + 1)
        x = x + attn.out_proj(lp["attn"], o[:, None])
        # cross attention against the static memory projections
        h = L.apply_norm(lp["ln_x"], x, cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", h,
                       lp["xattn"]["wq"].astype(h.dtype))
        # whisper audio windows are fixed-length: exactly N_FRAMES of the
        # padded cross cache are valid
        o = attn.decode_attend_local(
            q[:, 0], xk, xv, jnp.arange(xk.shape[1]),
            jnp.full((x.shape[0],), min(N_FRAMES, xk.shape[1]), jnp.int32))
        x = x + attn.out_proj(lp["xattn"], o[:, None])
        x = _mlp_block(lp, cfg, x)
        ks = jax.lax.dynamic_update_index_in_dim(ks, kc, i, 0)
        vs = jax.lax.dynamic_update_index_in_dim(vs, vc, i, 0)
        return (x, ks, vs)

    x, ks, vs = jax.lax.fori_loop(
        0, cfg.n_layers, body, (x, state.self_kv.k, state.self_kv.v))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x[:, 0].astype(jnp.float32),
                       cfg.vocab)
    new = EncDecState(self_kv=KVCache(k=ks, v=vs, length=length + 1),
                      cross_k=state.cross_k, cross_v=state.cross_v)
    return logits, new


def state_specs(cfg: ArchConfig, batch, max_len, dtype,
                n_frames=N_FRAMES_PAD):
    L_ = cfg.n_layers
    kv = KVCache.specs(L_, batch, max_len, cfg.n_kv_heads, cfg.head_dim_,
                       dtype)
    xs = jax.ShapeDtypeStruct(
        (L_, batch, n_frames, cfg.n_kv_heads, cfg.head_dim_), dtype)
    return EncDecState(self_kv=kv, cross_k=xs, cross_v=xs)
