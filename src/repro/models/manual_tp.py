"""Manual (shard_map) tensor-parallel blocks — the §Perf hillclimb fix.

GSPMD's auto-partitioner mishandles 2-D-sharded weight gradients under our
layouts: it either materializes full-size f32 dW per chip (~10 x 1.3 GB
live buffers, all-reduce over "model") or — with the gather-in constraint —
computes dW fully replicated (+2.3x layer FLOPs).  Both measured in
EXPERIMENTS.md §Perf.

These blocks pin the Megatron partitioning by construction: the "model"
axis is *manual* (shard_map), so

    fwd:  h_loc = x @ wi_loc          (F sharded; no comm)
          y     = psum(h_loc @ wo_loc, "model")
    bwd:  dW_loc = x^T @ dh_loc        local [d, F/TP] — never full-size

while "data"/"pod" stay auto: FSDP gathers/reduce-scatters over "data" are
still inserted by GSPMD around the local weights.  Enabled per-arch via
rules["manual_tp"] when the head/ff dims divide the model axis; the auto
path remains the fallback (and the measured baseline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_lib
from repro.models.layers import _act, apply_rope
from repro.models.sharding import shard_map_compat


def _tp(rules):
    mesh = rules.mesh
    if "model" not in mesh.axis_names:
        return 1
    return mesh.devices.shape[mesh.axis_names.index("model")]


def mlp_eligible(cfg, rules) -> bool:
    tp = _tp(rules)
    return tp > 1 and cfg.d_ff % tp == 0


def attn_eligible(cfg, rules) -> bool:
    tp = _tp(rules)
    if tp <= 1 or cfg.n_heads % tp:
        return False
    h_loc = cfg.n_heads // tp
    g = cfg.n_heads // cfg.n_kv_heads
    # per-shard q heads must align with whole kv-head groups
    return (cfg.n_kv_heads % tp == 0) or \
        (tp % cfg.n_kv_heads == 0 and g % h_loc == 0)


def manual_mlp(lp, x, cfg, rules):
    """x: [B,S,D] -> [B,S,D].  F manually sharded over "model"."""
    mesh = rules.mesh
    gated = "wg" in lp

    cdt = x.dtype

    def local(wi, wo, wg, x32):
        # x crosses the boundary in f32 so its cotangent psum (inserted by
        # the shard_map transpose for a replicated input) is f32 — a bf16
        # all-reduce hard-aborts XLA:CPU's AllReducePromotion pass
        x = x32.astype(cdt)
        h = jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
        h = _act(h, cfg.act)
        if gated:
            h = h * jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype))
        y = jnp.einsum("bsf,fd->bsd", h, wo.astype(x.dtype))
        # psum in f32: better numerics, and XLA:CPU's AllReducePromotion
        # pass crashes on bf16 all-reduce (hard abort)
        return jax.lax.psum(y.astype(jnp.float32), "model").astype(x.dtype)

    return shard_map_compat(
        local, mesh=mesh,
        # auto axes ("data"/"pod") may not appear in specs: the batch dim's
        # FSDP/DP sharding passes through shard_map untouched
        in_specs=(P(None, "model"), P("model", None), P(None, "model"),
                  P(None, None, None)),
        out_specs=P(None, None, None),
        axis_names={"model"})(
            lp["wi"], lp["wo"], lp.get("wg", lp["wi"]),
            x.astype(jnp.float32))


def manual_attention(lp, x, positions, cfg, rules, *, window=None,
                     prefix_len=None):
    """x: [B,S,D] -> attention output [B,S,D] (pre-residual).

    Q heads manually sharded over "model"; KV heads sharded when divisible,
    otherwise computed from replicated KV weights and sliced to the one
    whole kv-group this shard's q heads belong to.
    """
    mesh = rules.mesh
    tp = _tp(rules)
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    h_loc = H // tp
    kv_sharded = Hkv % tp == 0
    kv_loc = Hkv // tp if kv_sharded else max(1, h_loc * Hkv // H)
    has_bias = "bq" in lp

    cdt = x.dtype
    kv_hd_sharded = (not kv_sharded) and hd % tp == 0

    def local(wq, wk, wv, wo, bq, bk, bv, x32):
        x = x32.astype(cdt)   # f32 boundary: see manual_mlp
        idx = jax.lax.axis_index("model")
        q = jnp.einsum("bsd,dhk->bshk", x, wq.astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", x, wk.astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, wv.astype(x.dtype))
        if kv_hd_sharded:
            # kv projections computed sharded over head_dim, then the
            # (small) result gathered: avoids computing k/v fully
            # replicated on every shard (+0.8e12 FLOPs/layer measured).
            # f32 wire: the gather's transpose is a reduce-scatter, and a
            # bf16 reduce-scatter aborts XLA:CPU (AllReducePromotion bug)
            k = jax.lax.all_gather(k.astype(jnp.float32), "model",
                                   axis=3, tiled=True).astype(x.dtype)
            v = jax.lax.all_gather(v.astype(jnp.float32), "model",
                                   axis=3, tiled=True).astype(x.dtype)
        if has_bias:
            q = q + bq.astype(x.dtype)
            k = k + bk.astype(x.dtype)
            v = v + bv.astype(x.dtype)
        if not kv_sharded and Hkv > kv_loc:
            # slice the kv group(s) serving this shard's q heads
            start = (idx * h_loc * Hkv) // H
            k = jax.lax.dynamic_slice_in_dim(k, start, kv_loc, axis=2)
            v = jax.lax.dynamic_slice_in_dim(v, start, kv_loc, axis=2)
        if cfg.rope_theta:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        o = attn_lib.attend(q, k, v, positions, positions, causal=True,
                            window=window, prefix_len=prefix_len)
        y = jnp.einsum("bshk,hkd->bsd", o, wo.astype(x.dtype))
        return jax.lax.psum(y.astype(jnp.float32), "model").astype(x.dtype)

    zeros = jnp.zeros((1,), x.dtype)
    if kv_sharded:
        kvspec, kvb = P(None, "model", None), P("model", None)
    elif kv_hd_sharded:
        kvspec, kvb = P(None, None, "model"), P(None, None)
    else:
        kvspec, kvb = P(None, None, None), P(None, None)
    return shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(None, "model", None), kvspec, kvspec,
                  P("model", None, None),
                  P("model", None) if has_bias else P(None),
                  kvb if has_bias else P(None),
                  kvb if has_bias else P(None),
                  P(None, None, None)),
        out_specs=P(None, None, None),
        axis_names={"model"})(
            lp["wq"], lp["wk"], lp["wv"], lp["wo"],
            lp.get("bq", zeros), lp.get("bk", zeros), lp.get("bv", zeros),
            x.astype(jnp.float32))
