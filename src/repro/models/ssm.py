"""Mamba-1 selective SSM block (falcon-mamba-7b).

Train path uses ``jax.lax.associative_scan`` over time — an unrolled
log-depth DAG rather than a while loop, so (a) XLA parallelizes it and
(b) ``cost_analysis`` FLOPs are exact (while-loop bodies are counted once;
see launch/roofline.py).  Decode is a single O(1) recurrence step — the
whole 500k context lives in a [B, d_inner, state] state tensor, which is
why falcon-mamba runs the long_500k cell.

Channel parallelism: d_inner ("inner") is sharded over the "model" mesh
axis; the recurrence is per-channel independent, so the scan itself needs
no communication — only the in/out projections do (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import _normal


class SSMState(NamedTuple):
    conv: jax.Array   # [L?, B, conv_width-1, d_inner] recent inputs
    h: jax.Array      # [L?, B, d_inner, state]


def dims(cfg: ArchConfig):
    s = cfg.ssm or SSMConfig()
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or cfg.d_model // 16
    return s, d_inner, dt_rank


def init_ssm(key, cfg: ArchConfig, dtype):
    s, din, dtr = dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    sd = 1.0 / np.sqrt(d)
    si = 1.0 / np.sqrt(din)
    # S4D-real init for A: A = -(1..state) per channel
    a0 = jnp.tile(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32)[None],
                  (din, 1))
    p = {"in_proj": _normal(ks[0], (d, 2 * din), dtype, sd),
         "conv_w": _normal(ks[1], (s.conv_width, din), dtype, si),
         "conv_b": jnp.zeros((din,), dtype),
         "x_proj": _normal(ks[2], (din, dtr + 2 * s.state_dim), dtype, si),
         "dt_proj": _normal(ks[3], (dtr, din), dtype, 1.0 / np.sqrt(dtr)),
         "dt_bias": jnp.full((din,), -4.6, dtype),   # softplus^-1(0.01)
         "A_log": jnp.log(a0),
         "D": jnp.ones((din,), jnp.float32),
         "out_proj": _normal(ks[5], (din, d), dtype, si)}
    a = {"in_proj": ("embed", "inner"), "conv_w": ("conv", "inner"),
         "conv_b": ("inner",), "x_proj": ("inner", "null"),
         "dt_proj": ("dt", "inner"), "dt_bias": ("inner",),
         "A_log": ("inner", "state"), "D": ("inner",),
         "out_proj": ("inner", "embed")}
    return p, a


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: [B,S,din]; w: [width,din].

    state: optional [B,width-1,din] of inputs *before* x (decode);
    returns (y [B,S,din], new_state [B,width-1,din]).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([state, x], axis=1)          # [B,W-1+S,din]
    y = b.astype(x.dtype)[None, None]
    for i in range(width):
        y = y + w[i].astype(x.dtype) * \
            jax.lax.dynamic_slice_in_dim(ext, i, x.shape[1], axis=1)
    return y, ext[:, -(width - 1):]


def _ssm_inputs(p, xc, cfg: ArchConfig):
    """Shared projections: xc [B,S,din] -> (dA [B,S,din,N] as exp arg,
    Bx [B,S,din,N], C [B,S,N], dt [B,S,din])."""
    s, din, dtr = dims(cfg)
    xf = xc.astype(jnp.float32)
    proj = jnp.einsum("bsd,dk->bsk", xf, p["x_proj"].astype(jnp.float32))
    dt, B, C = jnp.split(proj, [dtr, dtr + s.state_dim], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt, p["dt_proj"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # [din,N]
    dA = dt[..., None] * A[None, None]                  # [B,S,din,N]
    Bx = dt[..., None] * B[:, :, None, :] * xf[..., None]
    return dA, Bx, C, xf


SCAN_CHUNK = 512  # bound the [B,chunk,din,N] associative-scan working set


def apply_ssm(p, x, cfg: ArchConfig, state: SSMState | None = None,
              chunk: int = SCAN_CHUNK):
    """Full-sequence selective scan.  x: [B,S,D] -> [B,S,D].

    If ``state`` is given its ``h``/``conv`` seed the recurrence; long
    sequences run as a *python* loop of seeded chunks (static unroll: no
    while loop, so probe cost_analysis stays trip-count-exact, and XLA's
    liveness keeps only one chunk's scan tensors alive — the unchunked
    falcon-mamba train cell peaked at 27 GB/chip, EXPERIMENTS.md §Perf).
    """
    S = x.shape[1]
    if chunk and S > chunk and S % chunk == 0:
        ys = []
        for i in range(S // chunk):
            y, state = _apply_ssm_core(p, x[:, i * chunk:(i + 1) * chunk],
                                       cfg, state)
            ys.append(y)
        return jnp.concatenate(ys, axis=1), state
    return _apply_ssm_core(p, x, cfg, state)


def _apply_ssm_core(p, x, cfg: ArchConfig, state: SSMState | None = None):
    s, din, dtr = dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = state.conv if state is not None else None
    xc, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dA, Bx, C, xf = _ssm_inputs(p, xc, cfg)

    a = jnp.exp(dA)                                     # [B,S,din,N]
    b = Bx
    if state is not None:
        # seed: h_0 enters as an extra leading element
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([state.h[:, None], b], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    ha, hb = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = hb if state is None else hb[:, 1:]              # [B,S,din,N]
    y = jnp.einsum("bsdn,bsn->bsd", h, C)               # C readout
    y = y + p["D"].astype(jnp.float32)[None, None] * \
        xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsd,de->bse", y.astype(x.dtype),
                     p["out_proj"].astype(x.dtype))
    new_state = SSMState(conv=conv_state, h=h[:, -1])
    return out, new_state


def decode_ssm(p, x, cfg: ArchConfig, state: SSMState):
    """One-token step.  x: [B,1,D]; state: per-layer slice."""
    s, din, dtr = dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"], state.conv)
    xc = jax.nn.silu(xc)
    dA, Bx, C, xf = _ssm_inputs(p, xc, cfg)
    h = state.h * jnp.exp(dA[:, 0]) + Bx[:, 0]          # [B,din,N]
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0])[:, None]
    y = y + p["D"].astype(jnp.float32)[None, None] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsd,de->bse", y.astype(x.dtype),
                     p["out_proj"].astype(x.dtype))
    return out, SSMState(conv=conv_state, h=h)


def init_ssm_state(cfg: ArchConfig, batch, dtype, n_layers=None):
    s, din, _ = dims(cfg)
    L = (n_layers,) if n_layers else ()
    return SSMState(
        conv=jnp.zeros(L + (batch, s.conv_width - 1, din), dtype),
        h=jnp.zeros(L + (batch, din, s.state_dim), jnp.float32))


def ssm_state_specs(cfg: ArchConfig, batch, dtype, n_layers=None):
    s, din, _ = dims(cfg)
    L = (n_layers,) if n_layers else ()
    return SSMState(
        conv=jax.ShapeDtypeStruct(L + (batch, s.conv_width - 1, din), dtype),
        h=jax.ShapeDtypeStruct(L + (batch, din, s.state_dim), jnp.float32))
