from repro.models.factory import Model, build_model, input_specs  # noqa

__all__ = ["Model", "build_model", "input_specs"]
