"""Decoder-only LM assembly for dense / MoE / SSM / hybrid families.

Layer stacks are homogeneous scans (``jax.lax.scan`` over stacked params):
one traced body per kind keeps compile time flat in depth (88-layer
mistral-large compiles the same program as a 2-layer smoke config).  The
hybrid family (recurrentgemma's 1:2 RG-LRU:attention pattern) scans over
*groups* of (rec, rec, attn) with an unrolled recurrent tail when
n_layers % 3 != 0.

Decode state is a ``DecodeState`` of per-kind stacked caches; global
attention uses the partitioned-KV FPP decode of models/attention.py when a
mesh is supplied (the paper's technique at the serving layer), window
attention (recurrentgemma) keeps a ring cache of ``window`` slots.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import KVCache
from repro.models.sharding import AxisRules, constrain


class DecodeState(NamedTuple):
    kv: Optional[KVCache]                     # [n_attn_layers, ...]
    ssm: Optional[ssm_lib.SSMState]           # [n_ssm_layers, ...]
    lru: Optional[rglru_lib.LRUState]         # [n_rec_layers, ...]


def layer_plan(cfg: ArchConfig) -> list:
    if cfg.family == "moe":
        return ["moe"] * cfg.n_layers
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern  # ("recurrent", "recurrent", "attention")
        kinds = {"recurrent": "rec", "attention": "attn"}
        return [kinds[pat[i % len(pat)]] for i in range(cfg.n_layers)]
    return ["attn"] * cfg.n_layers


# ---------------------------------------------------------------------------
# per-layer init


def init_layer(key, cfg: ArchConfig, kind: str):
    ks = jax.random.split(key, 4)
    d, dt = cfg.d_model, cfg.pdtype
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.init_norm(dt, d, cfg.norm)
    if kind in ("attn", "moe"):
        p["attn"], a["attn"] = attn.init_attention(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_, dt,
            cfg.qkv_bias)
    elif kind == "rec":
        p["rec"], a["rec"] = rglru_lib.init_rglru(ks[0], cfg, dt)
    elif kind == "ssm":
        p["ssm"], a["ssm"] = ssm_lib.init_ssm(ks[0], cfg, dt)
        return p, a                       # mamba block has no separate MLP
    p["ln2"], a["ln2"] = L.init_norm(dt, d, cfg.norm)
    if kind == "moe":
        p["moe"], a["moe"] = moe_lib.init_moe(ks[1], d, cfg.moe, dt,
                                              cfg.gated_mlp, cfg.act)
    else:
        p["mlp"], a["mlp"] = L.init_mlp(ks[1], d, cfg.d_ff, dt,
                                        cfg.gated_mlp)
    return p, a


def _stacked_init(key, cfg, kind, n):
    ks = jax.random.split(key, n)
    ps, axs = zip(*[init_layer(k, cfg, kind) for k in ks])
    return L.stack_layers(list(ps)), L.add_layer_axis(axs[0])


def init_params(key, cfg: ArchConfig):
    """Returns (params, axes).  Stacks: dense/moe/ssm -> params['stack'];
    hybrid -> params['groups'] (+ params['tail'])."""
    k_emb, k_stack, k_tail = jax.random.split(key, 3)
    vocab_p = L.pad_vocab(cfg.vocab)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(
        k_emb, vocab_p, cfg.d_model, cfg.pdtype, cfg.tie_embeddings)
    plan = layer_plan(cfg)
    if cfg.family == "hybrid":
        ng = cfg.n_layers // 3
        gks = jax.random.split(k_stack, ng)

        def group_init(k):
            k1, k2, k3 = jax.random.split(k, 3)
            gp, ga = {}, {}
            gp["rec1"], ga["rec1"] = init_layer(k1, cfg, "rec")
            gp["rec2"], ga["rec2"] = init_layer(k2, cfg, "rec")
            gp["attn"], ga["attn"] = init_layer(k3, cfg, "attn")
            return gp, ga

        gps, gas = zip(*[group_init(k) for k in gks])
        p["groups"] = L.stack_layers(list(gps))
        a["groups"] = L.add_layer_axis(gas[0])
        n_tail = cfg.n_layers % 3
        if n_tail:
            p["tail"], a["tail"] = _stacked_init(k_tail, cfg, "rec", n_tail)
    else:
        p["stack"], a["stack"] = _stacked_init(
            k_stack, cfg, plan[0], cfg.n_layers)
    p["final_norm"], a["final_norm"] = L.init_norm(
        cfg.pdtype, cfg.d_model, cfg.norm)
    return p, a


# ---------------------------------------------------------------------------
# layer application (full-sequence: train & prefill)


def _gather_in(h, rules):
    """Megatron-SP block entry: activations re-enter each matmul block
    replicated over "model" (the boundary keeps them S-sharded).

    Opt-in via rules["gather_in"]: it removes the f32 full-size dW live
    buffers GSPMD otherwise allocates (-3.3 GB on mistral-large) but makes
    GSPMD compute those dW fully replicated (+2.3x layer FLOPs) — both
    measured in EXPERIMENTS.md §Perf.  The manual-TP layer path
    (models/manual_tp.py) supersedes both trade-offs."""
    if rules is not None and rules.rules.get("gather_in"):
        return constrain(h, rules, ("batch", None, None))
    return h


def _manual_tp_on(rules) -> bool:
    return rules is not None and bool(rules.rules.get("manual_tp"))


def _apply_attn_layer(lp, cfg, x, positions, rules, *, window=None,
                      prefix_len=None, return_kv=False, lp_raw=None):
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    if _manual_tp_on(rules) and not return_kv:
        from repro.models import manual_tp
        if manual_tp.attn_eligible(cfg, rules):
            # raw (f32) weights: casting must happen INSIDE the manual
            # region so weight-grad reduces stay f32 (a bf16 all-reduce
            # hard-aborts XLA:CPU; see models/manual_tp.py)
            wts = (lp_raw or lp)["attn"]
            y = manual_tp.manual_attention(wts, h, positions, cfg,
                                           rules, window=window,
                                           prefix_len=prefix_len)
            return x + y, None
    h = _gather_in(h, rules)
    q, k, v = attn.qkv_proj(lp["attn"], h, positions, cfg.rope_theta)
    if rules is not None:
        q = constrain(q, rules, ("batch", "seq", "act_heads", None))
        k = constrain(k, rules, ("batch", None, None, None))
        v = constrain(v, rules, ("batch", None, None, None))
    o = attn.attend(q, k, v, positions, positions, causal=True,
                    window=window, prefix_len=prefix_len)
    x = x + attn.out_proj(lp["attn"], o)
    return (x, (k, v)) if return_kv else (x, None)


def _apply_mlp(lp, cfg, x, rules, lp_raw=None):
    h = L.apply_norm(lp["ln2"], x, cfg.norm)
    if "moe" in lp:
        h = _gather_in(h, rules)
        y, aux = moe_lib.apply_moe(lp["moe"], h, cfg.moe, cfg.act)
        return x + y, aux
    if _manual_tp_on(rules):
        from repro.models import manual_tp
        if manual_tp.mlp_eligible(cfg, rules):
            wts = (lp_raw or lp)["mlp"]
            return x + manual_tp.manual_mlp(wts, h, cfg, rules), 0.0
    h = _gather_in(h, rules)
    return x + L.apply_mlp(lp["mlp"], h, cfg.act), 0.0


# numerics-sensitive leaves that stay f32 through the recurrences
_KEEP_F32 = {"A_log", "D", "lam", "w_a", "b_a", "w_x", "b_x", "dt_bias"}


def cast_layer_params(lp, cdtype):
    """Cast matmul weights to compute dtype *while still sharded*: the
    FSDP all-gather then moves bf16, not f32 — half the gather bytes and
    half the gathered-weight temp (EXPERIMENTS.md §Perf)."""
    def cast(path, t):
        name = str(getattr(path[-1], "key", ""))
        if name in _KEEP_F32 or t.dtype != jnp.float32:
            return t
        return t.astype(cdtype)
    return jax.tree_util.tree_map_with_path(cast, lp)


def _apply_layer_full(lp, cfg, kind, x, positions, rules, *,
                      prefix_len=None, state=None, return_kv=False):
    """One layer, full sequence.  Returns (x, aux, kv, new_state)."""
    lp_raw = lp
    lp = cast_layer_params(lp, cfg.cdtype)
    if kind == "ssm":
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, new_state = ssm_lib.apply_ssm(lp["ssm"], h, cfg, state)
        return x + y, 0.0, None, new_state
    if kind == "rec":
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, new_state = rglru_lib.apply_rglru(lp["rec"], h, state)
        x = x + y
        x, aux = _apply_mlp(lp, cfg, x, rules, lp_raw=lp_raw)
        return x, aux, None, new_state
    window = cfg.hybrid.window if cfg.family == "hybrid" else None
    x, kv = _apply_attn_layer(lp, cfg, x, positions, rules, window=window,
                              prefix_len=prefix_len, return_kv=return_kv,
                              lp_raw=lp_raw)
    x, aux = _apply_mlp(lp, cfg, x, rules, lp_raw=lp_raw)
    return x, aux, kv, None


# ---------------------------------------------------------------------------
# forward (train)


def forward(params, cfg: ArchConfig, tokens, *, rules: AxisRules = None,
            prefix_embeds=None, prefix_len=None, remat=True):
    """tokens: [B,S] int32.  prefix_embeds: [B,P,D] (vlm stub frontend)
    prepended to the token embeddings; prefix positions attend
    bidirectionally (prefix-LM mask).  Returns (logits_f32, aux_loss)."""
    x = L.embed(params["embed"], tokens, cfg.cdtype, rules)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    if rules is not None:
        x = constrain(x, rules, ("batch", None, None))

    def boundary(x):
        # layer-boundary activations sequence-sharded over "model"
        # (Megatron-SP): the scan carry — which remat saves per layer — is
        # 1/TP the size; GSPMD re-gathers/reduce-scatters inside the layer.
        return constrain(x, rules, ("batch", "act_seq", None)) \
            if rules is not None else x

    def body(kind):
        def f(carry, lp):
            x, aux = carry
            x, a, _, _ = _apply_layer_full(lp, cfg, kind, x, positions,
                                           rules, prefix_len=prefix_len)
            return (boundary(x), aux + a), None
        return jax.checkpoint(f) if remat else f

    aux = jnp.float32(0.0)
    if cfg.family == "hybrid":
        def gbody(carry, gp):
            x, aux = carry
            x, a1, _, _ = _apply_layer_full(gp["rec1"], cfg, "rec", x,
                                            positions, rules)
            x, a2, _, _ = _apply_layer_full(gp["rec2"], cfg, "rec", x,
                                            positions, rules)
            x, a3, _, _ = _apply_layer_full(gp["attn"], cfg, "attn", x,
                                            positions, rules)
            return (boundary(x), aux + a1 + a2 + a3), None
        gbody = jax.checkpoint(gbody) if remat else gbody
        (x, aux), _ = jax.lax.scan(gbody, (x, aux), params["groups"])
        if "tail" in params:
            (x, aux), _ = jax.lax.scan(body("rec"), (x, aux),
                                       params["tail"])
    else:
        kind = layer_plan(cfg)[0]
        (x, aux), _ = jax.lax.scan(body(kind), (x, aux), params["stack"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x.astype(jnp.float32), cfg.vocab)
    if rules is not None:
        logits = constrain(logits, rules, ("batch", None, "act_vocab"))
    return logits, aux


# ---------------------------------------------------------------------------
# prefill: forward + build decode state

PREFILL_CHUNK = 4096


def prefill(params, cfg: ArchConfig, tokens, *, max_len=None,
            rules: AxisRules = None, prefix_embeds=None, prefix_len=None,
            chunk: int = PREFILL_CHUNK):
    """Returns (last_logits [B,V], DecodeState with length = S).

    Global-attention families process long prompts in chunks of
    ``chunk`` tokens (a static python loop): each chunk attends against
    the cache filled so far + itself, bounding activation memory to one
    chunk (32k single-shot prefill peaked at 29-70 GB/chip;
    EXPERIMENTS.md §Perf).
    """
    if cfg.family in ("dense", "moe", "vlm"):
        S_tot = tokens.shape[1] + (prefix_embeds.shape[1]
                                   if prefix_embeds is not None else 0)
        if S_tot > chunk and S_tot % chunk == 0 and \
                (max_len or S_tot) >= S_tot:
            return _prefill_chunked(params, cfg, tokens,
                                    max_len=max_len or S_tot,
                                    rules=rules,
                                    prefix_embeds=prefix_embeds,
                                    prefix_len=prefix_len, chunk=chunk)
    return _prefill_whole(params, cfg, tokens, max_len=max_len,
                          rules=rules, prefix_embeds=prefix_embeds,
                          prefix_len=prefix_len)


def _prefill_chunked(params, cfg: ArchConfig, tokens, *, max_len, rules,
                     prefix_embeds, prefix_len, chunk):
    x_all = L.embed(params["embed"], tokens, cfg.cdtype, rules)
    if prefix_embeds is not None:
        x_all = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x_all],
                                axis=1)
    B, S_tot, _ = x_all.shape
    Lr = cfg.n_layers
    kc = jnp.zeros((Lr, B, max_len, cfg.n_kv_heads, cfg.head_dim_),
                   cfg.cdtype)
    vc = jnp.zeros_like(kc)
    kind = layer_plan(cfg)[0]
    last_x = None
    for ci in range(S_tot // chunk):
        off = ci * chunk
        x = x_all[:, off:off + chunk]
        q_pos = off + jnp.arange(chunk)
        kv_pos = jnp.arange(off + chunk)

        def body(i, carry):
            x, kc, vc = carry
            lp = cast_layer_params(_idx(params["stack"], i), cfg.cdtype)
            h = L.apply_norm(lp["ln1"], x, cfg.norm)
            q, k, v = attn.qkv_proj(lp["attn"], h, q_pos, cfg.rope_theta)
            # write this chunk's kv at [i, :, off:off+chunk]
            kc = jax.lax.dynamic_update_slice(
                kc, k[None], (i, 0, off, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v[None], (i, 0, off, 0, 0))
            # attend against the statically-sliced filled cache prefix
            k_ctx = jax.lax.dynamic_slice(
                kc, (i, 0, 0, 0, 0),
                (1, B, off + chunk, cfg.n_kv_heads, cfg.head_dim_))[0]
            v_ctx = jax.lax.dynamic_slice(
                vc, (i, 0, 0, 0, 0),
                (1, B, off + chunk, cfg.n_kv_heads, cfg.head_dim_))[0]
            if rules is not None:
                q = constrain(q, rules, ("batch", "seq", "act_heads",
                                         None))
            o = attn.attend(q, k_ctx, v_ctx, q_pos, kv_pos, causal=True,
                            prefix_len=prefix_len)
            x = x + attn.out_proj(lp["attn"], o)
            x, _ = _apply_mlp(lp, cfg, x, rules)
            return (x, kc, vc)

        x, kc, vc = jax.lax.fori_loop(0, Lr, body, (x, kc, vc))
        last_x = x
    x = L.apply_norm(params["final_norm"], last_x, cfg.norm)
    last = L.unembed(params["embed"], x[:, -1].astype(jnp.float32),
                     cfg.vocab)
    length = jnp.full((B,), S_tot, jnp.int32)
    return last, DecodeState(kv=KVCache(k=kc, v=vc, length=length),
                             ssm=None, lru=None)


def _prefill_whole(params, cfg: ArchConfig, tokens, *, max_len=None,
                   rules: AxisRules = None, prefix_embeds=None,
                   prefix_len=None):
    """Returns (last_logits [B,V], DecodeState with length = S)."""
    x = L.embed(params["embed"], tokens, cfg.cdtype, rules)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)
    B, S, _ = x.shape
    max_len = max_len or S
    positions = jnp.arange(S)
    plan = layer_plan(cfg)
    window = cfg.hybrid.window if cfg.family == "hybrid" else None
    cache_len = min(max_len, window) if window else max_len

    def pad_kv(k):
        # place the last ``cache_len`` positions into the cache; a windowed
        # cache is a ring buffer keyed by absolute position mod window
        if S >= cache_len:
            k = k[:, -cache_len:]
            if window:
                k = jnp.roll(k, S % cache_len, axis=1)
        else:
            k = jnp.pad(k, [(0, 0), (0, cache_len - S), (0, 0), (0, 0)])
        return k

    def attn_body(kind):
        def f(x, lp):
            x, _, kv, _ = _apply_layer_full(lp, cfg, kind, x, positions,
                                            rules, prefix_len=prefix_len,
                                            return_kv=True)
            return x, (pad_kv(kv[0]), pad_kv(kv[1]))
        return f

    def state_body(kind):
        def f(x, lp):
            x, _, _, st = _apply_layer_full(lp, cfg, kind, x, positions,
                                            rules)
            return x, st
        return f

    kv = ssm_st = lru_st = None
    if cfg.family == "hybrid":
        def gbody(x, gp):
            x, _, _, st1 = _apply_layer_full(gp["rec1"], cfg, "rec", x,
                                             positions, rules)
            x, _, _, st2 = _apply_layer_full(gp["rec2"], cfg, "rec", x,
                                             positions, rules)
            x, _, kvp, _ = _apply_layer_full(gp["attn"], cfg, "attn", x,
                                             positions, rules,
                                             return_kv=True)
            sts = jax.tree.map(lambda a, b: jnp.stack([a, b]), st1, st2)
            return x, (sts, (pad_kv(kvp[0]), pad_kv(kvp[1])))
        x, (lru_g, kv_g) = jax.lax.scan(gbody, x, params["groups"])
        # lru_g leaves: [ng, 2, ...] -> [2*ng, ...]
        lru_st = jax.tree.map(
            lambda t: t.reshape((-1,) + t.shape[2:]), lru_g)
        if "tail" in params:
            x, lru_t = jax.lax.scan(state_body("rec"), x, params["tail"])
            lru_st = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), lru_st, lru_t)
        kv = kv_g
    elif cfg.family == "ssm":
        x, ssm_st = jax.lax.scan(state_body("ssm"), x, params["stack"])
    else:
        x, kv = jax.lax.scan(attn_body(plan[0]), x, params["stack"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    last = L.unembed(params["embed"], x[:, -1].astype(jnp.float32),
                     cfg.vocab)
    length = jnp.full((B,), min(S, cache_len) if window else S, jnp.int32)
    kv_cache = None
    if kv is not None:
        kv_cache = KVCache(k=kv[0], v=kv[1], length=length)
    if ssm_st is not None or lru_st is not None:
        length = jnp.full((B,), S, jnp.int32)
    return last, DecodeState(kv=kv_cache, ssm=ssm_st, lru=lru_st)


# ---------------------------------------------------------------------------
# decode (one token)


def _decode_attn_layer(lp, cfg, x, k_cache, v_cache, length, mesh, rules,
                       window=None):
    """x: [B,1,D].  Returns (x, new_k, new_v)."""
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    pos = (jnp.minimum(length, window - 1) if window else length)
    q, k, v = attn.qkv_proj(lp["attn"], h, length[:, None], cfg.rope_theta)
    if window:
        # ring buffer: write slot = length mod window
        slot = length % window
        k_cache, v_cache = attn.cache_update_local(k_cache, v_cache, k, v,
                                                   slot)
        kv_pos = jnp.arange(window)
        # validity: slots < min(length+1, window); window masking by recency
        o = attn.decode_attend_local(
            q[:, 0], k_cache, v_cache, kv_pos,
            jnp.minimum(length + 1, window), window=None)
    else:
        k_cache, v_cache = attn.cache_update_local(k_cache, v_cache, k, v,
                                                   length)
        if mesh is not None and "model" in mesh.axis_names:
            o = attn.decode_attend_partitioned(
                q[:, 0], k_cache, v_cache, length + 1, mesh)
        else:
            kv_pos = jnp.arange(k_cache.shape[1])
            o = attn.decode_attend_local(q[:, 0], k_cache, v_cache, kv_pos,
                                         length + 1)
    x = x + attn.out_proj(lp["attn"], o[:, None])
    return x, k_cache, v_cache


def _idx(tree, i):
    return jax.tree.map(
        lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False),
        tree)


def _upd(tree, sub, i):
    return jax.tree.map(
        lambda t, s: jax.lax.dynamic_update_index_in_dim(t, s, i, 0),
        tree, sub)


def decode_step(params, cfg: ArchConfig, tokens, state: DecodeState, *,
                mesh=None, rules: AxisRules = None):
    """tokens: [B,1].  Returns (logits [B,V] f32, new DecodeState).

    Layer iteration is a fori_loop carrying the stacked caches and
    updating them in place with dynamic_update_slice: with the state
    donated, XLA aliases the carry and the multi-GB KV cache is never
    copied (a lax.scan with cache xs/ys materializes two extra copies —
    measured in EXPERIMENTS.md §Dry-run notes).
    """
    x = L.embed(params["embed"], tokens, cfg.cdtype, rules)
    window = cfg.hybrid.window if cfg.family == "hybrid" else None

    new_kv = new_ssm = new_lru = None
    if cfg.family == "ssm":
        def body(i, carry):
            x, st = carry
            lp = _idx(params["stack"], i)
            h = L.apply_norm(lp["ln1"], x, cfg.norm)
            y, nst = ssm_lib.decode_ssm(lp["ssm"], h, cfg, _idx(st, i))
            return (x + y, _upd(st, nst, i))
        x, new_ssm = jax.lax.fori_loop(0, cfg.n_layers, body,
                                       (x, state.ssm))
    elif cfg.family == "hybrid":
        ng = cfg.n_layers // 3

        def rec_one(lp, x, st):
            h = L.apply_norm(lp["ln1"], x, cfg.norm)
            y, nst = rglru_lib.decode_rglru(lp["rec"], h, st)
            x = x + y
            x, _ = _apply_mlp(lp, cfg, x, rules)
            return x, nst

        def gbody(i, carry):
            x, lru, kc, vc = carry
            gp = _idx(params["groups"], i)
            x, n1 = rec_one(gp["rec1"], x, _idx(lru, 2 * i))
            lru = _upd(lru, n1, 2 * i)
            x, n2 = rec_one(gp["rec2"], x, _idx(lru, 2 * i + 1))
            lru = _upd(lru, n2, 2 * i + 1)
            x, nk, nv = _decode_attn_layer(
                gp["attn"], cfg, x, _idx(kc, i), _idx(vc, i),
                state.kv.length, mesh, rules, window=window)
            x, _ = _apply_mlp(gp["attn"], cfg, x, rules)
            return (x, lru, _upd(kc, nk, i), _upd(vc, nv, i))

        x, lru, kc, vc = jax.lax.fori_loop(
            0, ng, gbody, (x, state.lru, state.kv.k, state.kv.v))
        if "tail" in params:
            def tbody(i, carry):
                x, lru = carry
                lp = _idx(params["tail"], i)
                x, nst = rec_one(lp, x, _idx(lru, 2 * ng + i))
                return (x, _upd(lru, nst, 2 * ng + i))
            x, lru = jax.lax.fori_loop(0, cfg.n_layers % 3, tbody,
                                       (x, lru))
        new_lru, new_kv = lru, (kc, vc)
    else:
        kind = layer_plan(cfg)[0]

        def body(i, carry):
            x, kc, vc = carry
            lp = _idx(params["stack"], i)
            x, nk, nv = _decode_attn_layer(
                lp, cfg, x, _idx(kc, i), _idx(vc, i), state.kv.length,
                mesh, rules, window=window)
            x, _ = _apply_mlp(lp, cfg, x, rules)
            return (x, _upd(kc, nk, i), _upd(vc, nv, i))
        x, kc, vc = jax.lax.fori_loop(
            0, cfg.n_layers, body, (x, state.kv.k, state.kv.v))
        new_kv = (kc, vc)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x[:, 0].astype(jnp.float32),
                       cfg.vocab)
    new_state = DecodeState(
        kv=(KVCache(k=new_kv[0], v=new_kv[1], length=state.kv.length + 1)
            if new_kv is not None else None),
        ssm=new_ssm, lru=new_lru)
    return logits, new_state
