"""Logical-axis sharding system (t5x-style rules).

Every parameter leaf is annotated with a tuple of *logical axis names*
(one per array dim).  A *rule set* maps logical names to mesh axes; the same
model code then runs on any mesh.  Hillclimbing a sharding (EXPERIMENTS.md
§Perf) = editing a rule set, not the model.

Logical axes used by the zoo:
  embed      d_model dim               -> FSDP axis ("data") by default
  vocab      vocabulary                -> "model"
  heads      attention query heads     -> "model" when divisible, else None
  kv_heads   GQA kv heads              -> "model" when divisible, else None
  head_dim   per-head dim              -> None
  mlp        FFN hidden                -> "model"
  experts    MoE expert dim            -> "model" (expert parallelism)
  expert_mlp per-expert FFN hidden     -> None (experts already sharded)
  inner      SSM / RG-LRU channel dim  -> "model" (channel parallelism)
  state      SSM state dim             -> None
  conv       conv kernel width         -> None
  dt         SSM dt-rank               -> None
  layers     stacked-scan layer dim    -> None (never sharded)
  null       never sharded
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names=None):
    """jax.shard_map across jax versions.

    New jax: ``axis_names`` marks the manual axes (others stay auto) and
    vma checking is off.  jax 0.4.x: translate to the experimental API's
    ``auto=`` complement-set and ``check_rep=False``.
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": False}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    # 0.4.x's partial-manual (auto=) partitioner miscompiles this pattern
    # (manual-subgroup check failure), so run full-manual there: axes absent
    # from a spec are treated as replicated — correct, if less sharded.
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


class AxisRules:
    """Mapping logical axis name -> mesh axis (str | tuple | None)."""

    def __init__(self, rules: dict, mesh: Mesh):
        self.rules = dict(rules)
        self.mesh = mesh
        self._sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _mesh_size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            return int(np.prod([self._sizes[a] for a in axis]))
        return self._sizes[axis]

    def spec(self, logical_axes: tuple, shape: Optional[tuple] = None) -> P:
        """PartitionSpec for one leaf.  If ``shape`` is given, any mapping
        that does not divide the dim evenly is dropped (framework guard —
        uneven sharding is never silently requested)."""
        out, used = [], set()
        for i, name in enumerate(logical_axes):
            ax = self.rules.get(name)
            if ax is not None:
                key = tuple(ax) if isinstance(ax, tuple) else (ax,)
                if used & set(key):
                    ax = None          # a mesh axis may appear only once
                elif shape is not None and shape[i] % self._mesh_size(ax):
                    ax = None          # not divisible -> replicate this dim
                else:
                    used |= set(key)
            out.append(ax)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, logical_axes: tuple, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def tree_specs(self, axes_tree, shapes_tree=None):
        """Pytree of PartitionSpec matching a pytree of logical-axes tuples.

        shapes_tree: optional congruent tree of arrays / ShapeDtypeStructs
        (anything with .shape) — enables the divisibility guard.
        """
        is_ax = (lambda x: isinstance(x, tuple) and
                 all(isinstance(e, (str, type(None))) for e in x))
        if shapes_tree is None:
            return jax.tree.map(lambda ax: self.spec(ax), axes_tree,
                                is_leaf=is_ax)
        return jax.tree.map(
            lambda ax, sh: self.spec(ax, getattr(sh, "shape", sh)),
            axes_tree, shapes_tree, is_leaf=is_ax)


# ---------------------------------------------------------------------------
# rule sets.  "data" doubles as the FSDP axis: the d_model ("embed") dim of
# every weight is sharded over it, so param memory scales down with both mesh
# axes (2-D sharding = TP x FSDP, the MaxText default posture).  Multi-pod
# meshes keep params *replicated across pods* (pure DP on the pod axis); the
# gradient all-reduce over "pod" is then the only inter-pod collective, which
# is the right posture for low inter-pod bandwidth.


def default_rules(mesh: Mesh, *, fsdp: bool = True,
                  seq_shard_attn: bool = False) -> AxisRules:
    """TP over "model" + FSDP over "data".

    seq_shard_attn: archs whose head count does not divide the model axis
    (starcoder2 36H, paligemma 8H, whisper 8H, recurrentgemma 10H) shard the
    *sequence* dim of activations over "model" inside attention instead
    (context parallelism); their head dims stay replicated.
    """
    rules = {
        "embed": "data" if fsdp else None,
        "vocab": "model",
        "vocab_embed": None,   # see layers.init_embedding
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "experts": "model",
        "expert_mlp": None,
        "expert_embed": "data" if fsdp else None,
        "inner": "model",
        "state": None,
        "conv": None,
        "dt": None,
        "layers": None,
        "null": None,
        # activation logical axes
        "batch": ("pod", "data") if "pod" in mesh.axis_names else "data",
        "seq": "model" if seq_shard_attn else None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "seq_kv": "model",    # partitioned-KV decode (FPP serving)
        # layer-boundary activations sequence-sharded over "model"
        # (Megatron-SP): shrinks the per-layer remat saves by the TP degree
        "act_seq": "model",
    }
    return AxisRules(rules, mesh)


def replicated_rules(mesh: Mesh) -> AxisRules:
    rules = {k: None for k in (
        "embed vocab vocab_embed heads kv_heads head_dim mlp experts "
        "expert_mlp expert_embed inner state conv dt layers null seq "
        "act_heads act_mlp act_vocab seq_kv act_seq").split()}
    rules["batch"] = ("pod", "data") if "pod" in mesh.axis_names else "data"
    return AxisRules(rules, mesh)


def batch_spec(rules: AxisRules, extra_dims: int = 1) -> P:
    """P for a [batch, ...] input."""
    return P(rules.rules["batch"], *([None] * extra_dims))


def constrain(x, rules: AxisRules, logical_axes: tuple):
    """with_sharding_constraint via logical names (no-op outside jit mesh)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, rules.spec(logical_axes, x.shape)))
    except (ValueError, RuntimeError):
        return x
