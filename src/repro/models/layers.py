"""Shared layers for the LM zoo: norms, RoPE, embeddings, (gated) MLP.

Every ``init_*`` returns ``(params, axes)`` — two pytrees with identical
structure, where each leaf of ``axes`` is a tuple of logical axis names
(see models/sharding.py).  Model code stays sharding-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# init helpers


def _normal(key, shape, dtype, scale):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(key, in_dim, out_shape, dtype, axes):
    """Fan-in scaled init for a [in_dim, *out_shape] weight."""
    shape = (in_dim,) + tuple(out_shape)
    return _normal(key, shape, dtype, 1.0 / np.sqrt(in_dim)), axes


# ---------------------------------------------------------------------------
# norms


def init_norm(dtype, d, kind="rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    a = {"scale": ("embed",)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
        a["bias"] = ("embed",)
    return p, a


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (n * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    n = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = n * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                     # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding


def init_embedding(key, vocab, d, dtype, tie=False):
    k1, k2 = jax.random.split(key)
    p = {"embedding": _normal(k1, (vocab, d), dtype, 1.0)}
    # rows sharded over "model", D replicated: a row-sharded table gathers
    # with local masking + one small all-reduce; a 2-D-sharded table forces
    # GSPMD into involuntary full rematerialization of the gather.
    a = {"embedding": ("vocab", "vocab_embed")}
    if not tie:
        p["unembed"] = _normal(k2, (d, vocab), dtype, 1.0 / np.sqrt(d))
        a["unembed"] = ("embed", "vocab")
    return p, a


def embed(p, tokens, cdtype, rules=None):
    """Token embedding lookup.

    With a vocab-sharded table and a mesh in scope, the lookup runs as an
    explicit shard_map: each shard gathers the rows it owns (local ids,
    masked) and one psum over "model" combines.  GSPMD's generic handling
    of a cross-shard gather is involuntary full rematerialization — it
    replicates the f32 table per microbatch (measured: +12 GB/device on
    mistral-large; EXPERIMENTS.md §Perf).
    """
    table = p["embedding"]
    if rules is not None:
        ax = rules.rules.get("vocab")
        mesh = rules.mesh
        if ax in mesh.axis_names and mesh.devices.shape[
                mesh.axis_names.index(ax)] > 1 \
                and table.shape[0] % rules._mesh_size(ax) == 0:
            return _sharded_embed(table, tokens, rules, ax, cdtype)
    return table.astype(cdtype)[tokens]


def _sharded_embed(table, tokens, rules, ax, cdtype):
    from jax.sharding import PartitionSpec as P

    from repro.models.sharding import shard_map_compat
    mesh = rules.mesh
    n = rules._mesh_size(ax)
    v_loc = table.shape[0] // n
    bspec = rules.rules["batch"]
    if tokens.shape[0] % max(rules._mesh_size(bspec), 1):
        bspec = None    # tiny batches (long_500k: B=1) stay replicated

    def local(tab, tok):
        idx = jax.lax.axis_index(ax)
        loc = tok - idx * v_loc
        ok = (loc >= 0) & (loc < v_loc)
        x = tab.astype(cdtype)[jnp.clip(loc, 0, v_loc - 1)]
        x = x * ok[..., None].astype(cdtype)
        return jax.lax.psum(x, ax)

    return shard_map_compat(local, mesh=mesh,
                            in_specs=(P(ax, None), P(bspec, None)),
                            out_specs=P(bspec, None, None))(table, tokens)


def unembed(p, x, true_vocab=None):
    w = p.get("unembed")
    if w is None:
        w = p["embedding"].T
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    if true_vocab is not None and true_vocab < logits.shape[-1]:
        # padded vocab rows can never win or receive gradient mass
        neg = jnp.asarray(-1e9, logits.dtype)
        mask = jnp.arange(logits.shape[-1]) < true_vocab
        logits = jnp.where(mask, logits, neg)
    return logits


# ---------------------------------------------------------------------------
# MLP (SwiGLU-gated or plain)


def init_mlp(key, d, d_ff, dtype, gated=True):
    ks = jax.random.split(key, 3)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(d_ff)
    if gated:
        p = {"wi": _normal(ks[0], (d, d_ff), dtype, s_in),
             "wg": _normal(ks[1], (d, d_ff), dtype, s_in),
             "wo": _normal(ks[2], (d_ff, d), dtype, s_out)}
        a = {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"),
             "wo": ("mlp", "embed")}
    else:
        p = {"wi": _normal(ks[0], (d, d_ff), dtype, s_in),
             "wo": _normal(ks[2], (d_ff, d), dtype, s_out)}
        a = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return p, a


def _act(x, act):
    return jax.nn.silu(x) if act == "silu" else jax.nn.gelu(x)


def apply_mlp(p, x, act="silu"):
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(x.dtype))
    h = _act(h, act)
    if "wg" in p:
        h = h * jnp.einsum("...d,df->...f", x, p["wg"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# misc


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return int(-(-vocab // multiple) * multiple)


def stack_layers(leaves: list):
    """Stack per-layer param pytrees into a single scanned pytree."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)


def add_layer_axis(axes_tree):
    """Prefix each logical-axes tuple with the scanned 'layers' axis."""
    return jax.tree.map(
        lambda ax: ("layers",) + ax, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))
