"""Mixture-of-Experts FFN (phi3.5-moe: 16e top-2; qwen3-moe: 128e top-8).

Dispatch is *expert-centric consolidation* (DESIGN.md §4.1): tokens are sorted
by owning expert and packed into each expert's contiguous capacity buffer
before the expert matmul — exactly the paper's query-centric consolidation
(§4.2): group ops by owner, so each owner processes a contiguous,
contention-free batch.  Sort-based dispatch keeps memory linear in tokens
(the one-hot [S,E,C] dispatch tensor of GShard would be ~10^8 elements for
qwen3's 128 experts).  Over-capacity tokens are dropped to the residual
stream (standard Switch semantics) via the engine's trash-slot trick.

Experts are sharded over the "model" mesh axis (expert parallelism); GSPMD
lowers the pack/unpack gathers into the dispatch/return all-to-alls.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers import _act, _normal


def init_moe(key, d, cfg: MoEConfig, dtype, gated=True, act="silu"):
    ks = jax.random.split(key, 4)
    E, F = cfg.num_experts, cfg.expert_d_ff
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(F)
    p = {"router": _normal(ks[0], (d, E), dtype, s_in),
         "wi": _normal(ks[1], (E, d, F), dtype, s_in),
         "wo": _normal(ks[3], (E, F, d), dtype, s_out)}
    a = {"router": ("embed", "experts"),
         "wi": ("experts", "expert_embed", "expert_mlp"),
         "wo": ("experts", "expert_mlp", "expert_embed")}
    if gated:
        p["wg"] = _normal(ks[2], (E, d, F), dtype, s_in)
        a["wg"] = ("experts", "expert_embed", "expert_mlp")
    return p, a


def moe_capacity(S: int, cfg: MoEConfig) -> int:
    return max(1, int(np.ceil(S * cfg.top_k / cfg.num_experts
                              * cfg.capacity_factor)))


def apply_moe(p, x, cfg: MoEConfig, act="silu") -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] -> (y [B,S,D], aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = moe_capacity(S, cfg)

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # [B,S,E]
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                  # [B,S,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    def route_one(xb, idxb, gateb):
        """xb: [S,D]; idxb/gateb: [S,K]."""
        eid = idxb.reshape(-1)                       # [S*K] owning expert
        tok = jnp.repeat(jnp.arange(S), K)           # source token per slot
        order = jnp.argsort(eid, stable=True)        # consolidation sort
        eid_s, tok_s = eid[order], tok[order]
        start = jnp.searchsorted(eid_s, jnp.arange(E))          # [E]
        pos = jnp.arange(S * K) - start[eid_s]       # rank within expert
        keep = pos < C
        slot = jnp.where(keep, eid_s * C + pos, E * C)          # trash slot
        buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xb[tok_s])
        xe = buf[:E * C].reshape(E, C, D)
        h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(x.dtype))
        h = _act(h, act)
        if "wg" in p:
            h = h * jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(x.dtype))
        ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))
        ye = jnp.concatenate([ye.reshape(E * C, D),
                              jnp.zeros((1, D), x.dtype)])      # trash = 0
        contrib = ye[slot] * gateb.reshape(-1)[order][:, None].astype(x.dtype)
        return jnp.zeros((S, D), x.dtype).at[tok_s].add(contrib)

    y = jax.vmap(route_one)(x, gate_idx, gate_vals)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    assign = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)       # [B,S,K,E]
    frac = jnp.mean(jnp.sum(assign, axis=2), axis=(0, 1)) / K
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * pmean)
    return y, aux
