"""Attention for the LM zoo.

Three execution paths:

* ``attend``        — chunked online-softmax attention (lax.scan over KV
  chunks).  Used for train/prefill at any sequence length: the [Sq, Skv]
  score matrix never materializes beyond one [Sq, C] chunk.  This is the
  XLA-level twin of the Pallas flash kernel (kernels/flash_attention), which
  replaces it on real TPUs.
* ``decode_attend_partitioned`` — one-token decode against a KV cache whose
  *sequence* dim is sharded over the "model" mesh axis.  Each shard computes
  partial (max, exp-sum, weighted-V) for its resident KV partition and the
  partials combine with a log-sum-exp psum.  This is the paper's buffered
  execution model applied to serving: B independent queries (sequences) ride
  the batch dim, the shared partitioned structure is the KV cache, and the
  boundary-op exchange of Alg. 2 line 16 is the psum (DESIGN.md §4.1).
* ``decode_attend_local`` — same math on an unsharded cache (CPU tests,
  window attention whose cache is a small ring buffer).

GQA throughout: Hkv kv-heads are broadcast over group = H // Hkv query heads.
Head layout in all einsums: h = kv-head, g = group (so h*g = H).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import _normal, apply_rope

NEG = -1e9  # mask value: large-negative (never -inf: exp() stays NaN-free)

# Probe override (launch/probes.py): cost_analysis counts a lax.scan body
# once, so probes compile attention with chunk >= Skv (single unrolled
# chunk) to make score FLOPs trip-count-exact.  None = use caller's chunk.
CHUNK_OVERRIDE = None


# ---------------------------------------------------------------------------
# params


def init_attention(key, d, n_heads, n_kv, head_dim, dtype, qkv_bias=False):
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(n_heads * head_dim)
    p = {"wq": _normal(ks[0], (d, n_heads, head_dim), dtype, s),
         "wk": _normal(ks[1], (d, n_kv, head_dim), dtype, s),
         "wv": _normal(ks[2], (d, n_kv, head_dim), dtype, s),
         "wo": _normal(ks[3], (n_heads, head_dim, d), dtype, so)}
    a = {"wq": ("embed", "heads", "head_dim"),
         "wk": ("embed", "kv_heads", "head_dim"),
         "wv": ("embed", "kv_heads", "head_dim"),
         "wo": ("heads", "head_dim", "embed")}
    if qkv_bias:
        p.update(bq=jnp.zeros((n_heads, head_dim), dtype),
                 bk=jnp.zeros((n_kv, head_dim), dtype),
                 bv=jnp.zeros((n_kv, head_dim), dtype))
        a.update(bq=("heads", "head_dim"), bk=("kv_heads", "head_dim"),
                 bv=("kv_heads", "head_dim"))
    return p, a


def qkv_proj(p, x, positions, rope_theta):
    """x: [B,S,D] -> q [B,S,H,hd], k/v [B,S,Hkv,hd] (RoPE applied)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def out_proj(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


# ---------------------------------------------------------------------------
# chunked online-softmax attention (train / prefill)


def attend(q, k, v, q_pos, kv_pos, *, causal=True,
           window: Optional[int] = None, chunk: int = 1024,
           kv_mask=None, prefix_len: Optional[int] = None) -> jax.Array:
    """q: [B,Sq,H,hd]; k,v: [B,Skv,Hkv,hd]; q_pos: [Sq]; kv_pos: [Skv].

    Returns [B,Sq,H,hd].  Scans over ceil(Skv/chunk) KV chunks carrying the
    online-softmax state; peak score memory is [B,H,Sq,chunk].
    kv_mask: optional [B,Skv] bool validity (e.g. stub-frontend padding).
    prefix_len: positions < prefix_len are attendable by everyone
    (prefix-LM / vlm image prefix).
    """
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = 1.0 / np.sqrt(hd)
    if CHUNK_OVERRIDE is not None:
        chunk = CHUNK_OVERRIDE
    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        padk = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k, v = jnp.pad(k, padk), jnp.pad(v, padk)
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-(10 ** 9))
        if kv_mask is not None:
            kv_mask = jnp.pad(kv_mask, [(0, 0), (0, pad)])
    # [B,Hkv,g,Sq,hd]
    qt = (jnp.transpose(q, (0, 2, 1, 3)).astype(jnp.float32)
          .reshape(B, Hkv, group, Sq, hd))
    kc = k.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 3, 2, 4)
    pc = kv_pos.reshape(n_chunks, chunk)
    mc = (kv_mask.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
          if kv_mask is not None else None)

    def step(carry, xs):
        m, l, acc = carry                        # [B,Hkv,g,Sq](,hd)
        kj, vj, pj, mkj = xs                     # [B,Hkv,C,hd], [C], [B,C]
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qt,
                       kj.astype(jnp.float32)) * scale
        mask = (pj[None, :] <= q_pos[:, None]) if causal else \
            jnp.ones((Sq, chunk), bool)
        if window is not None:
            mask = mask & (pj[None, :] > q_pos[:, None] - window)
        if prefix_len is not None:
            mask = mask | (pj[None, :] < prefix_len)
        mask = mask & (pj >= 0)[None, :]
        cm = mask[None] if mkj is None else (mask[None] & mkj[:, None, :])
        cm = cm[:, None, None]                   # [B?,1,1,Sq,C]
        s = jnp.where(cm, s, NEG)
        mj = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, mj)
        r = jnp.exp(m - m_new)
        p = jnp.where(cm, jnp.exp(s - m_new[..., None]), 0.0)
        l = l * r + jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgqc,bhcd->bhgqd", p, vj.astype(jnp.float32))
        acc = acc * r[..., None] + o
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, group, Sq), NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, group, Sq, hd), jnp.float32)
    xs = (kc, vc, pc, mc)
    # flash-attention backward: recompute per-chunk scores/probabilities
    # instead of saving [*, Sq, chunk] residuals per chunk for the bwd
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0), xs)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode (one new token against a cache)


def _decode_partial(q, k, v, kv_pos, length, window):
    """Partial attention over one KV partition.

    q: [B,H,hd]; k,v: [B,C,Hkv,hd]; kv_pos: [C] absolute slot positions;
    length: [B] cache fill.  Returns (m, l, acc): [B,H], [B,H], [B,H,hd] —
    the partition's "boundary ops".
    """
    B, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    scale = 1.0 / np.sqrt(hd)
    qf = q.reshape(B, Hkv, group, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgd,bchd->bhgc", qf, kf) * scale     # [B,Hkv,g,C]
    valid = kv_pos[None, :] < length[:, None]             # [B,C]
    if window is not None:
        valid = valid & (kv_pos[None, :] >= length[:, None] - window)
    vmask = valid[:, None, None, :]
    s = jnp.where(vmask, s, NEG)
    m = jnp.max(s, axis=-1)
    p = jnp.where(vmask, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgc,bchd->bhgd", p, vf)
    return m.reshape(B, H), l.reshape(B, H), acc.reshape(B, H, hd)


def combine_partials(m, l, acc, axis_name):
    """LSE-combine partial attention over ``axis_name`` (the partition axis).

    This is Alg. 2 line 16 for the serving FPP: each partition emits its
    buffered partial ops; one batched exchange (psum) consolidates them.
    """
    m_g = jax.lax.pmax(m, axis_name)
    r = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * r, axis_name)
    acc_g = jax.lax.psum(acc * r[..., None], axis_name)
    return acc_g / jnp.maximum(l_g[..., None], 1e-30)


def decode_attend_local(q, k, v, kv_pos, length, window=None):
    """Unsharded decode attention.  q: [B,H,hd] -> [B,H,hd]."""
    m, l, acc = _decode_partial(q, k, v, kv_pos, length, window)
    return (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)


def decode_attend_partitioned(q, k, v, length, mesh, *, window=None,
                              seq_axis="model", batch_axes=("pod", "data")):
    """Partitioned-KV FPP decode.

    q: [B,H,hd] (replicated over seq_axis); k,v: [B,S,Hkv,hd] with S sharded
    over ``seq_axis`` and B over ``batch_axes``; length: [B].
    """
    from repro.models.sharding import shard_map_compat

    S = k.shape[1]
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    nshards = mesh.devices.shape[mesh.axis_names.index(seq_axis)]
    s_loc = S // nshards

    def local(q, k, v, length):
        idx = jax.lax.axis_index(seq_axis)
        kv_pos = idx * s_loc + jnp.arange(s_loc)
        m, l, acc = _decode_partial(q, k, v, kv_pos, length, window)
        return combine_partials(m, l, acc, seq_axis).astype(q.dtype)

    return shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, seq_axis, None, None),
                  P(bspec, seq_axis, None, None), P(bspec)),
        out_specs=P(bspec, None, None))(q, k, v, length)


# ---------------------------------------------------------------------------
# KV cache


class KVCache(NamedTuple):
    """Per-layer-stacked cache.  k,v: [L, B, S, Hkv, hd]; length: [B]."""
    k: jax.Array
    v: jax.Array
    length: jax.Array

    @staticmethod
    def init(n_layers, batch, max_len, n_kv, head_dim, dtype,
             length: Optional[jax.Array] = None):
        shape = (n_layers, batch, max_len, n_kv, head_dim)
        return KVCache(
            k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
            length=(length if length is not None
                    else jnp.zeros((batch,), jnp.int32)))

    @staticmethod
    def specs(n_layers, batch, max_len, n_kv, head_dim, dtype):
        s = jax.ShapeDtypeStruct((n_layers, batch, max_len, n_kv, head_dim),
                                 dtype)
        return KVCache(k=s, v=s,
                       length=jax.ShapeDtypeStruct((batch,), jnp.int32))


def cache_update_local(k_cache, v_cache, k_new, v_new, length):
    """Write one token at position ``length`` (per sequence) — unsharded.

    k_cache: [B,S,Hkv,hd]; k_new: [B,1,Hkv,hd]; length: [B].
    """
    S = k_cache.shape[1]
    onehot = (jnp.arange(S)[None, :] == length[:, None])  # [B,S]
    oh = onehot[..., None, None].astype(k_cache.dtype)
    k_cache = k_cache * (1 - oh) + k_new * oh
    v_cache = v_cache * (1 - oh) + v_new * oh
    return k_cache, v_cache
