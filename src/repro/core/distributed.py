"""Distributed FPP runtime — the buffered execution model at pod scale.

Hierarchy (DESIGN.md §2): the paper's LLC<-DRAM boundary appears twice on a
TPU pod — VMEM<-HBM inside a chip (handled by the Pallas kernels / BlockSpecs)
and HBM<-"the pod" across chips.  This module applies the SAME buffered
execution model at the second level:

  * graph partitions are sharded over the ``model`` mesh axis — each device's
    HBM permanently holds its partitions (the "cache-resident" set),
  * queries are sharded over the ``data`` (and ``pod``) axes — FPP queries are
    independent, so query shards never communicate (inter-query parallelism
    with zero synchronization, the paper's t=1 advantage without its cache
    penalty),
  * one superstep = every device visits its locally best-priority partition
    (a BSP relaxation of the paper's global priority order; Lemma A.2's
    yielding bound still applies per visit) and boundary operations are
    exchanged in batches with a single ``all_to_all`` — Algorithm 2 line 16
    *is* the collective.

The superstep loop is a single ``lax.while_loop`` inside ``shard_map`` so the
whole FPP run lowers to one XLA program — this is what the multi-pod dry-run
compiles.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import BlockGraph
from repro.core.yielding import YieldConfig
from repro.kernels.minplus import ops as minplus_ops

INF = jnp.inf

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _experimental_sm
    _shard_map = functools.partial(_experimental_sm, check_rep=False)


@dataclasses.dataclass
class ShardedGraph:
    """BlockGraph re-laid-out for P-way partition sharding.

    Every per-device slab owns ``pl = P/ndev`` consecutive partitions and the
    dense blocks whose *source* partition it owns (it needs them to relax and
    emit); destinations may be remote.
    """
    blocks: np.ndarray     # [ndev, pl, 1+dmax, B, B]; slot 0 = diagonal
    dst_part: np.ndarray   # [ndev, pl, 1+dmax] global dst partition (-1 pad)
    row_nnz: np.ndarray    # [ndev, pl, 1+dmax, B]
    deg: np.ndarray        # [ndev, pl, B]
    edge_budget: np.ndarray  # [ndev, pl]
    ndev: int
    pl: int
    dmax: int
    block_size: int
    num_parts: int

    @staticmethod
    def build(bg: BlockGraph, ndev: int, yc: YieldConfig,
              num_queries: int) -> "ShardedGraph":
        B = bg.block_size
        P_ = bg.num_parts
        pl = -(-P_ // ndev)
        p_pad = pl * ndev
        dmax = bg.nbr_blk.shape[1]
        blocks = np.full((ndev, pl, 1 + dmax, B, B), np.inf, dtype=np.float32)
        dst_part = np.full((ndev, pl, 1 + dmax), -1, dtype=np.int32)
        row_nnz = np.zeros((ndev, pl, 1 + dmax, B), dtype=np.int32)
        deg = np.zeros((ndev, pl, B), dtype=np.int32)
        part_edges = np.zeros(p_pad, dtype=np.int64)
        np.add.at(part_edges, bg.blk_src, bg.row_nnz.sum(axis=1))
        for p in range(P_):
            d, l = divmod(p, pl)
            kd = bg.diag_blk[p]
            blocks[d, l, 0] = bg.blocks[kd]
            dst_part[d, l, 0] = p
            row_nnz[d, l, 0] = bg.row_nnz[kd]
            deg[d, l] = bg.deg[p]
            for s in range(dmax):
                k = bg.nbr_blk[p, s]
                if k >= 0:
                    blocks[d, l, 1 + s] = bg.blocks[k]
                    dst_part[d, l, 1 + s] = bg.nbr_part[p, s]
                    row_nnz[d, l, 1 + s] = bg.row_nnz[k]
        budget = yc.edge_budget(part_edges, num_queries).reshape(ndev, pl)
        return ShardedGraph(blocks, dst_part, row_nnz, deg, budget,
                            ndev, pl, dmax, B, P_)


@dataclasses.dataclass
class DistributedResult:
    values: np.ndarray          # [Q, n]
    supersteps: int
    edges_processed: np.ndarray


def _superstep_minplus(sg_blocks, sg_dst, sg_nnz, sg_budget, dist, buf, edges,
                       *, window, max_rounds, pl, dmax, B, ndev, model_axis):
    """One superstep on one device's shard. dist/buf: [pl, Qs, B]."""
    # --- local priority-based selection (paper §5.2, per-device) ---
    pending_all = jnp.isfinite(buf) & (buf <= dist)
    prio = jnp.min(jnp.where(pending_all, buf, INF), axis=(1, 2))    # [pl]
    p = jnp.argmin(prio)
    has_work = jnp.isfinite(prio[p])

    w_all = sg_blocks[p]                 # [1+dmax, B, B]
    nnz_all = sg_nnz[p]                  # [1+dmax, B]
    w_pp, nnz_pp = w_all[0], nnz_all[0]
    d0, bufrow = dist[p], buf[p]
    pending0 = jnp.isfinite(bufrow) & (bufrow <= d0)
    pending0 = pending0 & has_work       # no-op visit when empty
    d1 = jnp.minimum(d0, jnp.where(pending0, bufrow, INF))
    alpha = jnp.min(jnp.where(pending0, d1, INF), axis=1, keepdims=True)
    budget = sg_budget[p]

    def cond(c):
        d, pending, emit, eq, rounds = c
        active = pending & (d <= alpha + window) & (eq < budget)[:, None]
        return jnp.logical_and(rounds < max_rounds, jnp.any(active))

    def body(c):
        d, pending, emit, eq, rounds = c
        active = pending & (d <= alpha + window) & (eq < budget)[:, None]
        srcs = jnp.where(active, d, INF)
        nd = minplus_ops.minplus(srcs, w_pp)
        eq = eq + jnp.sum(jnp.where(active, nnz_pp[None, :], 0), axis=1)
        emit = emit | active
        pending = pending & ~active
        improved = nd < d
        d = jnp.minimum(d, nd)
        pending = pending | improved
        return d, pending, emit, eq, rounds + 1

    Qs = d1.shape[0]
    eq0 = jnp.zeros(Qs, dtype=jnp.float32)
    d, pending, emit, eq, _ = jax.lax.while_loop(
        cond, body, (d1, pending0, jnp.zeros_like(pending0), eq0,
                     jnp.int32(0)))

    # --- emissions: one [B,B] relax per (padded) out-slot ---
    srcs = jnp.where(emit, d, INF)
    cands = jax.vmap(lambda w: minplus_ops.minplus(srcs, w))(
        w_all[1:])                                        # [dmax, Qs, B]
    dsts = sg_dst[p, 1:]                                  # [dmax]
    eq = eq + jnp.sum(
        jnp.where(emit[None], nnz_all[1:][:, None, :], 0),
        axis=(0, 2)).astype(jnp.float32)

    # route to owner devices over the model axis: payload [ndev, dmax, Qs, B]
    owner = jnp.where(dsts >= 0, dsts // pl, -1)
    payload = jnp.full((ndev, dmax, Qs, B), INF, dtype=d.dtype)
    slot_dst = jnp.full((ndev, dmax), -1, dtype=jnp.int32)

    def route(s, c):
        payload, slot_dst = c
        o = owner[s]
        valid = o >= 0
        oo = jnp.where(valid, o, 0)
        payload = payload.at[oo, s].set(
            jnp.where(valid, cands[s], payload[oo, s]))
        slot_dst = slot_dst.at[oo, s].set(
            jnp.where(valid, dsts[s] % pl, slot_dst[oo, s]))
        return payload, slot_dst

    payload, slot_dst = jax.lax.fori_loop(0, dmax, route,
                                          (payload, slot_dst))
    recv = jax.lax.all_to_all(payload, model_axis, 0, 0, tiled=False)
    recv_dst = jax.lax.all_to_all(slot_dst, model_axis, 0, 0, tiled=False)
    # recv: [ndev, dmax, Qs, B] — contributions from every device

    # keep yielded ops in own buffer, then apply received contributions
    keep_vals = jnp.where(pending, d, INF)
    buf = buf.at[p].set(keep_vals)
    dist = dist.at[p].set(d)
    flat_recv = recv.reshape(ndev * dmax, Qs, B)
    flat_dst = recv_dst.reshape(ndev * dmax)

    def apply_one(i, buf):
        l = flat_dst[i]
        valid = l >= 0
        ll = jnp.where(valid, l, 0)
        new = jnp.minimum(buf[ll], jnp.where(valid, flat_recv[i], INF))
        return buf.at[ll].set(jnp.where(valid, new, buf[ll]))

    buf = jax.lax.fori_loop(0, ndev * dmax, apply_one, buf)
    edges = edges + (eq - eq0)
    return dist, buf, edges


def run_distributed_sssp(bg: BlockGraph, sources: np.ndarray, mesh: Mesh,
                         yield_config: Optional[YieldConfig] = None,
                         max_supersteps: int = 100_000,
                         query_axes=("data",), part_axis: str = "model"):
    """Batched SSSP on a (…, data, model) mesh. Returns DistributedResult.

    sources: [Q] in the reordered id space; Q must divide the query-axes size.
    """
    yc = yield_config or YieldConfig()
    ndev = int(np.prod([mesh.shape[a] for a in (part_axis,)]))
    nq_dev = int(np.prod([mesh.shape[a] for a in query_axes]))
    Q = len(sources)
    assert Q % nq_dev == 0, (Q, nq_dev)
    sg = ShardedGraph.build(bg, ndev, yc, Q)
    B, pl, dmax = sg.block_size, sg.pl, sg.dmax
    window = yc.window()
    max_rounds = yc.max_rounds or B

    # global initial state [P_pad, Q, B]
    p_pad = sg.ndev * pl
    dist0 = np.full((p_pad, Q, B), np.inf, dtype=np.float32)
    buf0 = np.full((p_pad, Q, B), np.inf, dtype=np.float32)
    parts = np.asarray(sources) // B
    locs = np.asarray(sources) % B
    buf0[parts, np.arange(Q), locs] = 0.0
    edges0 = np.zeros((Q,), dtype=np.float32)

    qspec = P(*((None,) + query_axes + (None,)))     # [P_pad, Q, B]
    model_first = P(part_axis)

    def stepper(blocks, dstp, nnz, budget, dist, buf, edges):
        def cond(c):
            dist, buf, edges, done, steps = c
            return jnp.logical_and(~done, steps < max_supersteps)

        def body(c):
            dist, buf, edges, done, steps = c
            dist, buf, edges = _superstep_minplus(
                blocks, dstp, nnz, budget, dist, buf, edges,
                window=window, max_rounds=max_rounds, pl=pl, dmax=dmax,
                B=B, ndev=ndev, model_axis=part_axis)
            local_pending = jnp.any(jnp.isfinite(buf) & (buf <= dist))
            any_pending = local_pending
            for ax in (part_axis,) + tuple(query_axes):
                any_pending = jax.lax.pmax(any_pending.astype(jnp.int32),
                                           ax).astype(bool)
            return dist, buf, edges, ~any_pending, steps + 1

        dist, buf, edges, _, steps = jax.lax.while_loop(
            cond, body, (dist, buf, edges, jnp.bool_(False), jnp.int32(0)))
        return dist, buf, edges, steps

    graph_specs = (P(part_axis), P(part_axis), P(part_axis), P(part_axis))
    fn = jax.jit(_shard_map(
        stepper, mesh=mesh,
        in_specs=graph_specs + (
            P(*((part_axis,) + query_axes + (None,))),   # dist
            P(*((part_axis,) + query_axes + (None,))),   # buf
            P(*query_axes),                               # edges
        ),
        out_specs=(
            P(*((part_axis,) + query_axes + (None,))),
            P(*((part_axis,) + query_axes + (None,))),
            P(*query_axes),
            P(),
        ),
    ))
    dist, buf, edges, steps = fn(
        sg.blocks.reshape(p_pad, 1 + dmax, B, B),
        sg.dst_part.reshape(p_pad, 1 + dmax),
        sg.row_nnz.reshape(p_pad, 1 + dmax, B),
        sg.edge_budget.reshape(p_pad),
        dist0, buf0, edges0)
    n = bg.n
    vals = np.asarray(dist)[:bg.num_parts].transpose(1, 0, 2).reshape(
        Q, -1)[:, :n]
    return DistributedResult(vals, int(np.asarray(steps).max()),
                             np.asarray(edges))


def lower_distributed_sssp(bg: BlockGraph, num_queries: int, mesh: Mesh,
                           yield_config: Optional[YieldConfig] = None,
                           query_axes=("data",), part_axis: str = "model",
                           max_supersteps: int = 1000):
    """AOT lowering entry used by the multi-pod dry-run (no real data)."""
    yc = yield_config or YieldConfig()
    ndev = mesh.shape[part_axis]
    sgB = bg.block_size
    pl = -(-bg.num_parts // ndev)
    p_pad = pl * ndev
    dmax = bg.nbr_blk.shape[1]
    Q = num_queries

    def run(blocks, dstp, nnz, budget, dist, buf, edges):
        def cond(c):
            dist, buf, edges, done, steps = c
            return jnp.logical_and(~done, steps < max_supersteps)

        def body(c):
            dist, buf, edges, done, steps = c
            dist, buf, edges = _superstep_minplus(
                blocks, dstp, nnz, budget, dist, buf, edges,
                window=yc.window(), max_rounds=yc.max_rounds or sgB,
                pl=pl, dmax=dmax, B=sgB, ndev=ndev, model_axis=part_axis)
            local_pending = jnp.any(jnp.isfinite(buf) & (buf <= dist))
            any_pending = local_pending
            for ax in (part_axis,) + tuple(query_axes):
                any_pending = jax.lax.pmax(any_pending.astype(jnp.int32),
                                           ax).astype(bool)
            return dist, buf, edges, ~any_pending, steps + 1

        dist, buf, edges, _, steps = jax.lax.while_loop(
            cond, body, (dist, buf, edges, jnp.bool_(False), jnp.int32(0)))
        return dist, buf, edges, steps

    graph_specs = (P(part_axis), P(part_axis), P(part_axis), P(part_axis))
    state_spec = P(*((part_axis,) + query_axes + (None,)))
    fn = jax.jit(_shard_map(
        run, mesh=mesh,
        in_specs=graph_specs + (state_spec, state_spec, P(*query_axes)),
        out_specs=(state_spec, state_spec, P(*query_axes), P()),
    ))
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((p_pad, 1 + dmax, sgB, sgB), f32),
        jax.ShapeDtypeStruct((p_pad, 1 + dmax), jnp.int32),
        jax.ShapeDtypeStruct((p_pad, 1 + dmax, sgB), jnp.int32),
        jax.ShapeDtypeStruct((p_pad,), f32),
        jax.ShapeDtypeStruct((p_pad, Q, sgB), f32),
        jax.ShapeDtypeStruct((p_pad, Q, sgB), f32),
        jax.ShapeDtypeStruct((Q,), f32),
    )
    return fn.lower(*args)
