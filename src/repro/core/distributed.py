"""Distributed FPP runtime — the buffered execution model at pod scale.

Hierarchy (DESIGN.md §2): the paper's LLC<-DRAM boundary appears twice on a
TPU pod — VMEM<-HBM inside a chip (handled by the Pallas kernels / BlockSpecs)
and HBM<-"the pod" across chips.  This module applies the SAME buffered
execution model at the second level:

  * graph partitions are sharded over the ``model`` mesh axis — each device's
    HBM permanently holds its partitions (the "cache-resident" set),
  * queries are sharded over the ``data`` (and ``pod``) axes — FPP queries are
    independent, so query shards never communicate (inter-query parallelism
    with zero synchronization, the paper's t=1 advantage without its cache
    penalty),
  * one superstep = every device visits its locally best-priority partition
    (a BSP relaxation of the paper's global priority order; Lemma A.2's
    yielding bound still applies per visit) and boundary operations are
    exchanged in batches with a single ``all_to_all`` — Algorithm 2 line 16
    *is* the collective.

The superstep body is the generic skeleton from ``core/visit.py``; this module
only supplies the mesh program around it.  Both the minplus family (SSSP/BFS)
and the push family (PPR) run through the same program — residual
contributions exchange by ``+`` through the same ``all_to_all`` routing that
minplus uses for ``min``, and the run converges when no device holds a
pending op (max-residual ratio below eps for push), a ``pmax`` across the
``model`` + query axes.

The superstep loop is a single ``lax.while_loop`` inside ``shard_map`` so the
whole FPP run lowers to one XLA program — this is what the multi-pod dry-run
compiles.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import visit as _visit
from repro.core.graph import BlockGraph
from repro.core.visit import EDGE_SHIFT, VisitAlgebra
from repro.core.yielding import YieldConfig

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _experimental_sm
    _shard_map = functools.partial(_experimental_sm, check_rep=False)


@dataclasses.dataclass
class ShardedGraph:
    """BlockGraph re-laid-out for P-way partition sharding.

    Every per-device slab owns ``pl = P/ndev`` consecutive partitions and the
    dense blocks whose *source* partition it owns (it needs them to relax and
    emit); destinations may be remote.
    """
    blocks: np.ndarray     # [ndev, pl, 1+dmax, B, B]; slot 0 = diagonal
    dst_part: np.ndarray   # [ndev, pl, 1+dmax] global dst partition (-1 pad)
    row_nnz: np.ndarray    # [ndev, pl, 1+dmax, B]
    deg: np.ndarray        # [ndev, pl, B]
    edge_budget: np.ndarray  # [ndev, pl]
    ndev: int
    pl: int
    dmax: int
    block_size: int
    num_parts: int

    @staticmethod
    def build(bg: BlockGraph, ndev: int, yc: YieldConfig,
              num_queries: int) -> "ShardedGraph":
        B = bg.block_size
        P_ = bg.num_parts
        pl = -(-P_ // ndev)
        p_pad = pl * ndev
        dmax = bg.nbr_blk.shape[1]
        blocks = np.full((ndev, pl, 1 + dmax, B, B), np.inf, dtype=np.float32)
        dst_part = np.full((ndev, pl, 1 + dmax), -1, dtype=np.int32)
        row_nnz = np.zeros((ndev, pl, 1 + dmax, B), dtype=np.int32)
        deg = np.zeros((ndev, pl, B), dtype=np.int32)
        part_edges = np.zeros(p_pad, dtype=np.int64)
        np.add.at(part_edges, bg.blk_src, bg.row_nnz.sum(axis=1))
        for p in range(P_):
            d, l = divmod(p, pl)
            kd = bg.diag_blk[p]
            blocks[d, l, 0] = bg.blocks[kd]
            dst_part[d, l, 0] = p
            row_nnz[d, l, 0] = bg.row_nnz[kd]
            deg[d, l] = bg.deg[p]
            for s in range(dmax):
                k = bg.nbr_blk[p, s]
                if k >= 0:
                    blocks[d, l, 1 + s] = bg.blocks[k]
                    dst_part[d, l, 1 + s] = bg.nbr_part[p, s]
                    row_nnz[d, l, 1 + s] = bg.row_nnz[k]
        budget = yc.edge_budget(part_edges, num_queries).reshape(ndev, pl)
        return ShardedGraph(blocks, dst_part, row_nnz, deg, budget,
                            ndev, pl, dmax, B, P_)


@dataclasses.dataclass
class DistributedResult:
    values: np.ndarray          # [Q, n]
    supersteps: int
    edges_processed: np.ndarray  # [Q] float64, exact
    residual: Optional[np.ndarray] = None   # [Q, n] (push kinds)


# ---------------------------------------------------------------------------
# the one mesh program: while(superstep) under shard_map


def _make_program(algebra: VisitAlgebra, mesh: Mesh, *, pl: int, dmax: int,
                  ndev: int, max_rounds: int, max_supersteps: int,
                  query_axes: Tuple[str, ...], part_axis: str):
    """jit(shard_map(while(superstep))) for one algebra on one mesh.

    Takes/returns value planes stacked as one ``[nplanes, P_pad, Q, B]``
    array so the same in/out specs serve both modes.  Edge counts ride as an
    (hi, lo) int32 pair per query — exact integer accumulation without x64.
    """
    nplanes = algebra.num_planes

    def stepper(blocks, dstp, nnz, deg, budget, vals, buf, ehi, elo):
        planes0 = tuple(vals[i] for i in range(nplanes))

        def cond(c):
            *_, done, steps = c
            return jnp.logical_and(~done, steps < max_supersteps)

        def body(c):
            planes, buf, ehi, elo, done, steps = c
            planes, buf, eq = _visit.superstep(
                blocks, dstp, nnz, deg, budget, planes, buf,
                algebra=algebra, max_rounds=max_rounds, pl=pl, dmax=dmax,
                ndev=ndev, model_axis=part_axis)
            elo = elo + eq
            spill = elo >> EDGE_SHIFT
            ehi = ehi + spill
            elo = elo - (spill << EDGE_SHIFT)
            local_pending = jnp.any(algebra.pending(buf, planes, deg))
            any_pending = local_pending
            for ax in (part_axis,) + tuple(query_axes):
                any_pending = jax.lax.pmax(any_pending.astype(jnp.int32),
                                           ax).astype(bool)
            return planes, buf, ehi, elo, ~any_pending, steps + 1

        planes, buf, ehi, elo, _, steps = jax.lax.while_loop(
            cond, body, (planes0, buf, ehi, elo, jnp.bool_(False),
                         jnp.int32(0)))
        # each device only counted edges of partitions it owns; a query's
        # total is the sum over the partition axis (replicated on return)
        ehi = jax.lax.psum(ehi, part_axis)
        elo = jax.lax.psum(elo, part_axis)
        return jnp.stack(planes), buf, ehi, elo, steps

    graph_specs = (P(part_axis),) * 5
    state_spec = P(*((part_axis,) + tuple(query_axes) + (None,)))
    vals_spec = P(*((None, part_axis) + tuple(query_axes) + (None,)))
    q_spec = P(*query_axes)
    return jax.jit(_shard_map(
        stepper, mesh=mesh,
        in_specs=graph_specs + (vals_spec, state_spec, q_spec, q_spec),
        out_specs=(vals_spec, state_spec, q_spec, q_spec, P()),
    ))


def _check_query_sharding(Q: int, mesh: Mesh, query_axes) -> int:
    nq_dev = int(np.prod([mesh.shape[a] for a in query_axes]))
    if Q % nq_dev != 0:
        raise ValueError(
            f"query batch of Q={Q} cannot shard evenly over query axes "
            f"{tuple(query_axes)} (total size {nq_dev}); pad the sources to "
            f"a multiple of {nq_dev} or re-mesh so the query-axes size "
            f"divides Q")
    return nq_dev


def _run_program(algebra: VisitAlgebra, bg: BlockGraph, sources: np.ndarray,
                 mesh: Mesh, yc: YieldConfig, max_rounds: int,
                 max_supersteps: int, query_axes, part_axis: str,
                 num_queries: Optional[int] = None,
                 init_ops: Optional[np.ndarray] = None):
    """Shared driver: build shards, init state, run, unshift edge counters."""
    ndev = int(mesh.shape[part_axis])
    Q = int(num_queries if num_queries is not None else len(sources))
    _check_query_sharding(Q, mesh, query_axes)
    sg = ShardedGraph.build(bg, ndev, yc, Q)
    B, pl, dmax = sg.block_size, sg.pl, sg.dmax
    p_pad = ndev * pl
    if init_ops is not None:
        io = np.full((p_pad, B), algebra.identity, dtype=np.float32)
        io[:bg.num_parts] = init_ops
        init_ops = io
    planes0, buf0 = _visit.init_dense_state(
        algebra, p_pad, Q, B, np.asarray(sources), trash_row=False,
        init_ops=init_ops)
    fn = _make_program(algebra, mesh, pl=pl, dmax=dmax, ndev=ndev,
                       max_rounds=max_rounds, max_supersteps=max_supersteps,
                       query_axes=tuple(query_axes), part_axis=part_axis)
    vals, buf, ehi, elo, steps = fn(
        sg.blocks.reshape(p_pad, 1 + dmax, B, B),
        sg.dst_part.reshape(p_pad, 1 + dmax),
        sg.row_nnz.reshape(p_pad, 1 + dmax, B),
        sg.deg.reshape(p_pad, B),
        sg.edge_budget.reshape(p_pad),
        np.stack(planes0), buf0,
        np.zeros((Q,), dtype=np.int32), np.zeros((Q,), dtype=np.int32))
    edges = (np.asarray(ehi, dtype=np.float64) * float(1 << EDGE_SHIFT)
             + np.asarray(elo, dtype=np.float64))
    return np.asarray(vals), np.asarray(buf), edges, int(np.asarray(steps))


def _to_values(plane: np.ndarray, num_parts: int, Q: int, n: int):
    return plane[:num_parts].transpose(1, 0, 2).reshape(Q, -1)[:, :n]


def run_distributed_sssp(bg: BlockGraph, sources: np.ndarray, mesh: Mesh,
                         yield_config: Optional[YieldConfig] = None,
                         max_supersteps: int = 100_000,
                         query_axes=("data",), part_axis: str = "model"):
    """Batched SSSP on a (…, data, model) mesh. Returns DistributedResult.

    sources: [Q] in the reordered id space; Q must divide the query-axes size.
    """
    yc = yield_config or YieldConfig()
    algebra = _visit.minplus_algebra(yc.window())
    vals, _, edges, steps = _run_program(
        algebra, bg, sources, mesh, yc,
        max_rounds=yc.max_rounds or bg.block_size,
        max_supersteps=max_supersteps, query_axes=query_axes,
        part_axis=part_axis)
    Q = len(sources)
    return DistributedResult(_to_values(vals[0], bg.num_parts, Q, bg.n),
                             steps, edges)


def run_distributed_cc(bg: BlockGraph, num_queries: int, mesh: Mesh,
                       yield_config: Optional[YieldConfig] = None,
                       max_supersteps: int = 100_000,
                       query_axes=("data",), part_axis: str = "model"):
    """Connected components at pod scale: the minplus superstep program over
    a zero-weight block graph, seeded with every vertex's own label
    (``visit.cc_label_plane``) instead of one-hot sources.  All query lanes
    converge to the same label plane (cc is per-graph); ``num_queries``
    only sets the lane count so the result contract matches other kinds.
    """
    yc = yield_config or YieldConfig()
    # strict pending: over zero weights an equal re-sent label would keep
    # the superstep loop pending forever (see visit.minplus_algebra)
    algebra = _visit.minplus_algebra(yc.window(), strict=True)
    vals, _, edges, steps = _run_program(
        algebra, bg, np.empty(0, dtype=np.int64), mesh, yc,
        max_rounds=yc.max_rounds or bg.block_size,
        max_supersteps=max_supersteps, query_axes=query_axes,
        part_axis=part_axis, num_queries=num_queries,
        init_ops=_visit.cc_label_plane(bg))
    return DistributedResult(
        _to_values(vals[0], bg.num_parts, num_queries, bg.n), steps, edges)


def run_distributed_ppr(bg: BlockGraph, sources: np.ndarray, mesh: Mesh,
                        alpha: float = 0.15, eps: float = 1e-4,
                        yield_config: Optional[YieldConfig] = None,
                        max_supersteps: int = 100_000,
                        query_axes=("data",), part_axis: str = "model"):
    """Batched PPR: the push instantiation of the same superstep program.

    Residual contributions exchange by ``+`` through the same ``all_to_all``
    routing minplus uses; the run converges when every device's max residual
    ratio drops below eps (``pmax`` across the ``model`` + query axes).
    Returns DistributedResult with ``values`` = PPR mass and ``residual`` =
    terminal residual (pending buffered contributions folded in, so
    values + residual conserves probability mass exactly).
    """
    yc = yield_config or YieldConfig()
    algebra = _visit.push_algebra(alpha, eps)
    vals, buf, edges, steps = _run_program(
        algebra, bg, sources, mesh, yc,
        max_rounds=yc.max_rounds or 64,
        max_supersteps=max_supersteps, query_axes=query_axes,
        part_axis=part_axis)
    Q = len(sources)
    pvals = _to_values(vals[0], bg.num_parts, Q, bg.n)
    # un-consolidated buffered contributions are residual mass (engine twin)
    rvals = _to_values(vals[1] + buf, bg.num_parts, Q, bg.n)
    return DistributedResult(pvals, steps, edges, residual=rvals)


def make_walk_mesh_program(mesh: Mesh, block_size: int, length: int,
                           seed: int, walk_axes: Tuple[str, ...]):
    """jit(shard_map(fori(step))) for the rw kind: walkers shard over
    ``walk_axes``, the graph is replicated, and there is NO collective —
    walks are independent, so the pod runtime for rw is pure data
    parallelism over the same per-(source, step) tape every other rw
    runtime replays (core/randomwalk.py).
    """
    from repro.core.randomwalk import stepper_from_arrays

    def body(blocks, diag_blk, nbr_blk, nbr_part,
             pos, steps, part, src, thash, occ):
        step = stepper_from_arrays(blocks, diag_blk, nbr_blk, nbr_part,
                                   block_size, length,
                                   jax.random.PRNGKey(seed))

        def one(_, c):
            pos, steps, part, thash, occ = c
            return step(pos, steps, part, src, thash, occ, steps < length)

        pos, steps, part, thash, occ = jax.lax.fori_loop(
            0, length, one, (pos, steps, part, thash, occ))
        return pos, steps, part, thash, occ

    rep = P()
    wspec = P(tuple(walk_axes))
    occ_spec = P(tuple(walk_axes), None)
    return jax.jit(_shard_map(
        body, mesh=mesh,
        in_specs=(rep, rep, rep, rep,
                  wspec, wspec, wspec, wspec, wspec, occ_spec),
        out_specs=(wspec, wspec, wspec, wspec, occ_spec)))


def run_distributed_walks(bg: BlockGraph, sources: np.ndarray, mesh: Mesh,
                          length: int, seed: int = 0, walk_axes=None):
    """Batched random walks sharded over every mesh axis (graph replicated).

    Walker count is padded up to the axes' size with clones of walker 0
    (same tape id => same trajectory, sliced off on return).  Returns a
    ``core.randomwalk.WalkResult`` bitwise identical to the single-device
    engine loop and the synchronous baseline.
    """
    from repro.core.engine import DeviceGraph
    from repro.core.randomwalk import WalkResult, init_walk_state
    from repro.core.yielding import NO_YIELD
    walk_axes = tuple(walk_axes or mesh.axis_names)
    nshard = int(np.prod([mesh.shape[a] for a in walk_axes]))
    srcs = np.asarray(sources)
    Q = srcs.size
    Qp = -(-max(Q, 1) // nshard) * nshard
    padded = np.concatenate([srcs, np.full(Qp - Q, srcs[0] if Q else 0,
                                           dtype=srcs.dtype)])
    dg = DeviceGraph.build(bg, NO_YIELD, Qp)
    fn = make_walk_mesh_program(mesh, bg.block_size, length, seed, walk_axes)
    pos, steps, part, src, thash, occ = init_walk_state(dg, padded)
    pos, steps, part, thash, occ = fn(dg.blocks, dg.diag_blk, dg.nbr_blk,
                                      dg.nbr_part, pos, steps, part, src,
                                      thash, occ)
    return WalkResult(np.asarray(pos)[:Q], np.asarray(steps)[:Q],
                      np.asarray(thash)[:Q], visits=length,
                      occupancy=np.asarray(occ)[:Q, :bg.n])


def make_distributed_program(bg: BlockGraph, num_queries: int, mesh: Mesh, *,
                             kind: str = "sssp", alpha: float = 0.15,
                             eps: float = 1e-4,
                             yield_config: Optional[YieldConfig] = None,
                             query_axes=("data",), part_axis: str = "model",
                             max_supersteps: int = 1000,
                             length: int = 32, seed: int = 0):
    """The jitted mesh program plus matching abstract arguments.

    Public AOT handle: ``(fn, args)`` where ``args`` are
    ``ShapeDtypeStruct``s, so callers can ``fn.lower(*args)`` without
    building real shards — the multi-pod dry-run compiles it, and the
    fppcheck jaxpr/HLO passes (DESIGN.md §7) trace and budget exactly the
    program ``run_distributed_*`` executes.  ``kind``: "sssp"/"bfs"/"cc"/
    "kreach" use the minplus algebra (cc over zero weights + label init,
    kreach over hop-shifted weights — same program, different operands),
    "ppr" the push algebra, "rw" the collective-free sharded walk program
    (``length``/``seed`` are its tape parameters).
    """
    yc = yield_config or YieldConfig()
    B = bg.block_size
    if kind == "rw":
        fn = make_walk_mesh_program(mesh, B, length, seed,
                                    walk_axes=tuple(mesh.axis_names))
        P_, dmax = bg.num_parts, bg.nbr_blk.shape[1]
        f32, i32 = jnp.float32, jnp.int32
        args = (
            jax.ShapeDtypeStruct(bg.blocks.shape, f32),
            jax.ShapeDtypeStruct((P_,), i32),
            jax.ShapeDtypeStruct((P_, dmax), i32),
            jax.ShapeDtypeStruct((P_, dmax), i32),
            jax.ShapeDtypeStruct((num_queries,), i32),
            jax.ShapeDtypeStruct((num_queries,), i32),
            jax.ShapeDtypeStruct((num_queries,), i32),
            jax.ShapeDtypeStruct((num_queries,), i32),
            jax.ShapeDtypeStruct((num_queries,), jnp.uint32),
            jax.ShapeDtypeStruct((num_queries, P_ * B), f32),
        )
        return fn, args
    if kind == "ppr":
        algebra = _visit.push_algebra(alpha, eps)
        max_rounds = yc.max_rounds or 64
    elif kind in ("sssp", "bfs", "cc", "kreach"):
        algebra = _visit.minplus_algebra(yc.window(), strict=(kind == "cc"))
        max_rounds = yc.max_rounds or bg.block_size
    else:
        raise ValueError(
            f"unknown kind {kind!r}; one of sssp/bfs/ppr/cc/kreach/rw")
    ndev = int(mesh.shape[part_axis])
    pl = -(-bg.num_parts // ndev)
    p_pad = pl * ndev
    dmax = bg.nbr_blk.shape[1]
    Q = num_queries
    fn = _make_program(algebra, mesh, pl=pl, dmax=dmax, ndev=ndev,
                       max_rounds=max_rounds,
                       max_supersteps=max_supersteps,
                       query_axes=tuple(query_axes), part_axis=part_axis)
    f32, i32 = jnp.float32, jnp.int32
    args = (
        jax.ShapeDtypeStruct((p_pad, 1 + dmax, B, B), f32),
        jax.ShapeDtypeStruct((p_pad, 1 + dmax), i32),
        jax.ShapeDtypeStruct((p_pad, 1 + dmax, B), i32),
        jax.ShapeDtypeStruct((p_pad, B), i32),
        jax.ShapeDtypeStruct((p_pad,), f32),
        jax.ShapeDtypeStruct((algebra.num_planes, p_pad, Q, B), f32),
        jax.ShapeDtypeStruct((p_pad, Q, B), f32),
        jax.ShapeDtypeStruct((Q,), i32),
        jax.ShapeDtypeStruct((Q,), i32),
    )
    return fn, args


def lower_distributed_sssp(bg: BlockGraph, num_queries: int, mesh: Mesh,
                           yield_config: Optional[YieldConfig] = None,
                           query_axes=("data",), part_axis: str = "model",
                           max_supersteps: int = 1000):
    """AOT lowering entry used by the multi-pod dry-run (no real data)."""
    fn, args = make_distributed_program(
        bg, num_queries, mesh, kind="sssp", yield_config=yield_config,
        query_axes=query_axes, part_axis=part_axis,
        max_supersteps=max_supersteps)
    return fn.lower(*args)
