"""Dense per-partition operation buffers + consolidation semantics.

The paper buffers ops ``<q, v, val>`` in dynamic per-partition vectors and
consolidates them per query (one thread per query => atomic-free; duplicates
merged; priority order inside each query's ops).  The TPU-dense adaptation
stores, for every partition, the single best pending value per (query, vertex):

    buf[P + 1, Q, B]   min-combine (SSSP/BFS)  identity +inf
                       sum-combine (PPR)       identity 0

Consolidation is therefore *free by construction*: a min/sum write merges
duplicate ops, and no two writers ever race because writes are whole-tensor
functional updates.  Row ``P`` is a trash row used to drop emissions through
padded neighbor slots (see engine.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

MIN_IDENTITY = jnp.inf
SUM_IDENTITY = 0.0


class MinBuffers(NamedTuple):
    buf: jax.Array  # [P+1, Q, B] float32, +inf empty

    @staticmethod
    def init(num_parts: int, num_queries: int, block_size: int) -> "MinBuffers":
        return MinBuffers(jnp.full((num_parts + 1, num_queries, block_size),
                                   MIN_IDENTITY, dtype=jnp.float32))

    def push(self, part_idx: jax.Array, cand: jax.Array) -> "MinBuffers":
        """Consolidating write: keep the best op per (q, v). part_idx may be a
        vector of destinations (padded with P = trash row)."""
        return MinBuffers(self.buf.at[part_idx].min(cand))

    def take(self, p: jax.Array) -> jax.Array:
        return self.buf[p]

    def clear(self, p: jax.Array, keep: jax.Array | None = None,
              keep_vals: jax.Array | None = None) -> "MinBuffers":
        row = (jnp.where(keep, keep_vals, MIN_IDENTITY)
               if keep is not None else
               jnp.full_like(self.buf[p], MIN_IDENTITY))
        return MinBuffers(self.buf.at[p].set(row))


class SumBuffers(NamedTuple):
    buf: jax.Array  # [P+1, Q, B] float32, 0 empty

    @staticmethod
    def init(num_parts: int, num_queries: int, block_size: int) -> "SumBuffers":
        return SumBuffers(jnp.zeros((num_parts + 1, num_queries, block_size),
                                    dtype=jnp.float32))

    def push(self, part_idx: jax.Array, contrib: jax.Array) -> "SumBuffers":
        return SumBuffers(self.buf.at[part_idx].add(contrib))

    def take(self, p: jax.Array) -> jax.Array:
        return self.buf[p]

    def clear(self, p: jax.Array) -> "SumBuffers":
        return SumBuffers(self.buf.at[p].set(jnp.zeros_like(self.buf[p])))
