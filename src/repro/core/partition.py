"""Graph partitioning = vertex reordering + contiguous VMEM-sized ranges.

The paper partitions with METIS (edge-cut minimizing) for road/web graphs and
random equal-size partitions for social graphs (where METIS quality is poor).
On TPU, a partition must be a *contiguous vertex range* so the adjacency block
layout is dense and the BlockSpec index map stays affine.  We therefore express
partitioning as a reordering problem:

  bfs        BFS-clustering order: grow clusters of ``block_size`` vertices by
             BFS from unvisited seeds — a cheap, dependency-free stand-in for
             METIS that minimizes cross-block edges on meshes and many webs.
  degree     hub-first order (paper's Gorder-family related heuristic).
  random     the paper's fallback for social networks.
  natural    identity (whatever order the generator produced).
"""
from __future__ import annotations

from collections import deque
from typing import Tuple

import numpy as np

from repro.core.graph import BlockGraph, CSRGraph


def bfs_cluster_order(g: CSRGraph, block_size: int) -> np.ndarray:
    """perm[v] = new id of v.  Grows BFS clusters so blocks are locality tight."""
    n = g.n
    perm = np.full(n, -1, dtype=np.int64)
    visited = np.zeros(n, dtype=bool)
    nxt = 0
    # seed scan order: by degree descending visits dense cores first which keeps
    # hub neighborhoods together; remaining singletons appended at the end.
    seeds = np.argsort(-g.out_degree(), kind="stable")
    dq: deque[int] = deque()
    for s in seeds:
        if visited[s]:
            continue
        dq.append(int(s))
        visited[s] = True
        while dq:
            u = dq.popleft()
            perm[u] = nxt
            nxt += 1
            for e in range(g.indptr[u], g.indptr[u + 1]):
                v = int(g.indices[e])
                if not visited[v]:
                    visited[v] = True
                    dq.append(v)
    if nxt != n:
        raise RuntimeError(
            f"BFS order covered {nxt} of {n} vertices — graph traversal "
            f"missed a component; CSR structure is inconsistent")
    return perm


def degree_order(g: CSRGraph) -> np.ndarray:
    order = np.argsort(-g.out_degree(), kind="stable")
    perm = np.empty(g.n, dtype=np.int64)
    perm[order] = np.arange(g.n)
    return perm


def random_order(g: CSRGraph, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(g.n).astype(np.int64)


def partition(g: CSRGraph, block_size: int, method: str = "bfs",
              seed: int = 0) -> Tuple[BlockGraph, np.ndarray]:
    """Returns (block graph, perm) with ``perm[old_id] = new_id``."""
    if method == "bfs":
        perm = bfs_cluster_order(g, block_size)
    elif method == "degree":
        perm = degree_order(g)
    elif method == "random":
        perm = random_order(g, seed)
    elif method == "natural":
        perm = np.arange(g.n, dtype=np.int64)
    else:
        raise ValueError(f"unknown partition method {method!r}")
    gp = g.permute(perm) if method != "natural" else g
    return BlockGraph.from_csr(gp, block_size), perm


def edge_cut_fraction(bg: BlockGraph) -> float:
    """Fraction of edges crossing partition boundaries (lower = better)."""
    diag = bg.row_nnz[bg.diag_blk].sum()
    total = bg.row_nnz.sum()
    return float(1.0 - diag / max(1, total))
