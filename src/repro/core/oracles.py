"""Golden sequential references — the paper's work-efficiency yardsticks.

Dijkstra (binary heap) for SSSP, deque BFS, Andersen-Chung-Lang push for PPR,
and an explicit-stack DFS (host-only; see DESIGN.md §2 — DFS has no
data-parallel TPU mapping).  Each oracle also reports ``edges_processed`` so
benchmarks can compute the paper's work ratios (Fig. 10 / Appendix A).
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Dict, Tuple

import numpy as np

from repro.core.graph import CSRGraph


def dijkstra(g: CSRGraph, src: int) -> Tuple[np.ndarray, int]:
    dist = np.full(g.n, np.inf, dtype=np.float64)
    dist[src] = 0.0
    done = np.zeros(g.n, dtype=bool)
    heap = [(0.0, src)]
    edges = 0
    while heap:
        d, u = heapq.heappop(heap)
        if done[u]:
            continue
        done[u] = True
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            nd = d + float(g.weights[e])
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist.astype(np.float32), edges


def bfs(g: CSRGraph, src: int) -> Tuple[np.ndarray, int]:
    dist = np.full(g.n, -1, dtype=np.int32)
    dist[src] = 0
    dq = deque([src])
    edges = 0
    while dq:
        u = dq.popleft()
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                dq.append(v)
    return dist, edges


def bfs_sigma(g: CSRGraph, src: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """BFS distances + shortest-path counts (for Brandes BC)."""
    dist = np.full(g.n, -1, dtype=np.int32)
    sigma = np.zeros(g.n, dtype=np.float64)
    dist[src] = 0
    sigma[src] = 1.0
    dq = deque([src])
    edges = 0
    while dq:
        u = dq.popleft()
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                dq.append(v)
            if dist[v] == dist[u] + 1:
                sigma[v] += sigma[u]
    return dist, sigma, edges


def ppr_push(g: CSRGraph, src: int, alpha: float = 0.15,
             eps: float = 1e-4) -> Tuple[np.ndarray, np.ndarray, int]:
    """Sequential ACL push (the paper reuses Shun et al. [54]'s version).

    Invariant maintained: p + alpha-smoothed residual approximates the PPR
    vector; terminates when all residuals r[u] < eps * deg(u).
    """
    deg = np.maximum(g.out_degree(), 1).astype(np.float64)
    p = np.zeros(g.n, dtype=np.float64)
    r = np.zeros(g.n, dtype=np.float64)
    r[src] = 1.0
    edges = 0
    queue = deque([src])
    inq = np.zeros(g.n, dtype=bool)
    inq[src] = True
    while queue:
        u = queue.popleft()
        inq[u] = False
        ru = r[u]
        if ru < eps * deg[u]:
            continue
        p[u] += alpha * ru
        push = (1.0 - alpha) * ru / deg[u]
        r[u] = 0.0
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            r[v] += push
            if r[v] >= eps * deg[v] and not inq[v]:
                inq[v] = True
                queue.append(v)
    return p.astype(np.float32), r.astype(np.float32), edges


def connected_components(g: CSRGraph) -> np.ndarray:
    """Union-find component labels; label = min vertex id in the component.

    The differential anchor for the ``cc`` kind: min-label propagation over
    a symmetrized graph must converge to exactly these labels.
    """
    parent = np.arange(g.n, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:           # path compression
            parent[x], x = root, int(parent[x])
        return root

    src, dst, _ = g.edges()
    for u, v in zip(src, dst):
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(int(v)) for v in range(g.n)], dtype=np.int64)


def label_prop(g: CSRGraph) -> Tuple[np.ndarray, int]:
    """Synchronous min-label propagation to fixpoint (labels, rounds).

    The sequential twin of the visit-algebra ``cc`` kind: every vertex
    starts labeled with its own id and repeatedly takes the min over its
    in-labels; on symmetrized graphs the fixpoint equals union-find.
    """
    labels = np.arange(g.n, dtype=np.int64)
    src, dst, _ = g.edges()
    rounds = 0
    while True:
        nxt = labels.copy()
        np.minimum.at(nxt, dst, labels[src])
        rounds += 1
        if (nxt == labels).all():
            return labels, rounds
        labels = nxt


def kreach_stride(n: int, weights_max: float) -> float:
    """The hop-packing stride S shared by every ``kreach`` backend and the
    oracle: the smallest power of two exceeding twice the largest possible
    path weight, so ``packed = hops * S + dist`` decodes exactly in f32
    (``hops * S`` is representable and ``dist < S / 2`` can never carry)."""
    hi = 2.0 * max(1.0, float(n)) * max(1.0, float(weights_max))
    s = 2.0
    while s <= hi:
        s *= 2.0
    return s


def decode_kreach(packed: np.ndarray, stride: float, k: int):
    """Unpack the lexicographic (hops, dist) plane: ``values`` is the dist
    of the hop-minimal path where ``hops <= k`` (else +inf), ``hops`` the
    hop count (+inf unreachable).  Shared by the engine finalize, the
    distributed/baseline decodes, and the oracle — the decode is part of
    the kind's contract, so it lives in exactly one place."""
    p64 = np.asarray(packed, np.float64)
    finite = np.isfinite(p64)
    hops = np.floor(np.where(finite, p64, 0.0) / float(stride))
    dist = p64 - hops * float(stride)
    values = np.where(finite & (hops <= k), dist, np.inf).astype(np.float32)
    hops = np.where(finite, hops, np.inf).astype(np.float32)
    return values, hops


def kreach(g: CSRGraph, src: int, k: int,
           stride: float | None = None) -> Tuple[np.ndarray, np.ndarray, int]:
    """Sequential weighted k-reach: Dijkstra over the hop-shifted weights
    ``w' = f32(w + S)`` with f32 accumulation — expression-identical to the
    relaxations the block backends run, so parity is bitwise, not approximate.
    Returns (values, hops, edges) per :func:`decode_kreach`."""
    if stride is None:
        stride = kreach_stride(g.n, float(g.weights.max()) if g.m else 1.0)
    s32 = np.float32(stride)
    dist = np.full(g.n, np.inf, dtype=np.float32)
    dist[src] = np.float32(0.0)
    done = np.zeros(g.n, dtype=bool)
    heap = [(np.float32(0.0), src)]
    edges = 0
    while heap:
        d, u = heapq.heappop(heap)
        if done[u]:
            continue
        done[u] = True
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            nd = np.float32(d + np.float32(np.float32(g.weights[e]) + s32))
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    values, hops = decode_kreach(dist, stride, k)
    return values, hops, edges


def random_walk(bg, src: int, length: int, seed: int = 0) -> np.ndarray:
    """Sequential replay of one walker's tape over the block layout.

    The randomness contract of the ``rw`` kind: at (source ``src``, step
    ``t``) the walker draws ``u = uniform(fold_in(fold_in(key(seed), src),
    t))`` and takes the ``min(floor(u * deg), deg - 1)``-th finite entry of
    its block-layout adjacency row (diagonal columns first, then the
    ``nbr_blk`` slots in order).  The trajectory is a pure function of
    (graph, seed, source, length) — independent of lane placement,
    chunking, or backend — so every runtime must reproduce it bitwise.
    Returns the visited positions (start included, <= length + 1 entries —
    a walk parked on a sink ends there, matching the runtimes' occupancy
    planes which count each visited position exactly once).
    """
    import jax

    base = jax.random.fold_in(jax.random.PRNGKey(seed), int(src))
    B = bg.block_size
    pos = int(src)
    out = [pos]
    for t in range(length):
        p, l = pos // B, pos % B
        row = np.concatenate(
            [bg.blocks[bg.diag_blk[p]][l]]
            + [np.where(bg.nbr_part[p, j] >= 0,
                        bg.blocks[bg.nbr_blk[p, j]][l], np.inf)
               for j in range(bg.nbr_part.shape[1])])
        finite = np.isfinite(row)
        deg = int(finite.sum())
        if deg == 0:
            break
        u = np.float32(jax.random.uniform(jax.random.fold_in(base, t)))
        # f32 product, exactly as the device stepper computes it
        idx = min(int(np.floor(u * np.float32(deg))), deg - 1)
        col = int(np.flatnonzero(finite)[idx])
        slot, local = col // B, col % B
        dest_part = p if slot == 0 else int(bg.nbr_part[p, slot - 1])
        pos = dest_part * B + local
        out.append(pos)
    return np.asarray(out, dtype=np.int64)


def dfs_order(g: CSRGraph, src: int) -> np.ndarray:
    """Preorder DFS labels (-1 unreachable). Host-only reference."""
    label = np.full(g.n, -1, dtype=np.int32)
    stack = [src]
    nxt = 0
    while stack:
        u = stack.pop()
        if label[u] >= 0:
            continue
        label[u] = nxt
        nxt += 1
        for e in range(g.indptr[u + 1] - 1, g.indptr[u] - 1, -1):
            v = int(g.indices[e])
            if label[v] < 0:
                stack.append(v)
    return label


def batch(fn, g: CSRGraph, sources) -> Dict[int, tuple]:
    return {int(s): fn(g, int(s)) for s in sources}
