"""Golden sequential references — the paper's work-efficiency yardsticks.

Dijkstra (binary heap) for SSSP, deque BFS, Andersen-Chung-Lang push for PPR,
and an explicit-stack DFS (host-only; see DESIGN.md §2 — DFS has no
data-parallel TPU mapping).  Each oracle also reports ``edges_processed`` so
benchmarks can compute the paper's work ratios (Fig. 10 / Appendix A).
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Dict, Tuple

import numpy as np

from repro.core.graph import CSRGraph


def dijkstra(g: CSRGraph, src: int) -> Tuple[np.ndarray, int]:
    dist = np.full(g.n, np.inf, dtype=np.float64)
    dist[src] = 0.0
    done = np.zeros(g.n, dtype=bool)
    heap = [(0.0, src)]
    edges = 0
    while heap:
        d, u = heapq.heappop(heap)
        if done[u]:
            continue
        done[u] = True
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            nd = d + float(g.weights[e])
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist.astype(np.float32), edges


def bfs(g: CSRGraph, src: int) -> Tuple[np.ndarray, int]:
    dist = np.full(g.n, -1, dtype=np.int32)
    dist[src] = 0
    dq = deque([src])
    edges = 0
    while dq:
        u = dq.popleft()
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                dq.append(v)
    return dist, edges


def bfs_sigma(g: CSRGraph, src: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """BFS distances + shortest-path counts (for Brandes BC)."""
    dist = np.full(g.n, -1, dtype=np.int32)
    sigma = np.zeros(g.n, dtype=np.float64)
    dist[src] = 0
    sigma[src] = 1.0
    dq = deque([src])
    edges = 0
    while dq:
        u = dq.popleft()
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                dq.append(v)
            if dist[v] == dist[u] + 1:
                sigma[v] += sigma[u]
    return dist, sigma, edges


def ppr_push(g: CSRGraph, src: int, alpha: float = 0.15,
             eps: float = 1e-4) -> Tuple[np.ndarray, np.ndarray, int]:
    """Sequential ACL push (the paper reuses Shun et al. [54]'s version).

    Invariant maintained: p + alpha-smoothed residual approximates the PPR
    vector; terminates when all residuals r[u] < eps * deg(u).
    """
    deg = np.maximum(g.out_degree(), 1).astype(np.float64)
    p = np.zeros(g.n, dtype=np.float64)
    r = np.zeros(g.n, dtype=np.float64)
    r[src] = 1.0
    edges = 0
    queue = deque([src])
    inq = np.zeros(g.n, dtype=bool)
    inq[src] = True
    while queue:
        u = queue.popleft()
        inq[u] = False
        ru = r[u]
        if ru < eps * deg[u]:
            continue
        p[u] += alpha * ru
        push = (1.0 - alpha) * ru / deg[u]
        r[u] = 0.0
        for e in range(g.indptr[u], g.indptr[u + 1]):
            v = int(g.indices[e])
            edges += 1
            r[v] += push
            if r[v] >= eps * deg[v] and not inq[v]:
                inq[v] = True
                queue.append(v)
    return p.astype(np.float32), r.astype(np.float32), edges


def dfs_order(g: CSRGraph, src: int) -> np.ndarray:
    """Preorder DFS labels (-1 unreachable). Host-only reference."""
    label = np.full(g.n, -1, dtype=np.int32)
    stack = [src]
    nxt = 0
    while stack:
        u = stack.pop()
        if label[u] >= 0:
            continue
        label[u] = nxt
        nxt += 1
        for e in range(g.indptr[u + 1] - 1, g.indptr[u] - 1, -1):
            v = int(g.indices[e])
            if label[v] < 0:
                stack.append(v)
    return label


def batch(fn, g: CSRGraph, sources) -> Dict[int, tuple]:
    return {int(s): fn(g, int(s)) for s in sources}
