"""The visit algebra — one Algorithm-2 skeleton for every runtime and mode.

The paper's Algorithm 2 is a single shape regardless of query family:

    apply buffered ops   (consolidate into the resident partition's state)
    relax locally        (until converged, yielded, or out of budget)
    emit boundary ops    (one contribution per neighbor partition)

The repo used to hand-write that skeleton three times (minplus visit, push
visit, distributed minplus superstep) and the copies drifted — the push family
never reached the pod runtime.  This module factors the *mode-specific*
operators into a :class:`VisitAlgebra` and keeps exactly three generic drivers:

  :func:`make_visit`     the single-device visit kernel (one visit per dispatch)
  :func:`make_megastep`  K visits per host dispatch: partition selection is an
                         on-device argmin/argmax over the ``[P]`` metadata
                         planes and the visit body runs in a ``lax.while_loop``
                         (DESIGN.md §2.3) — the engine's hot loop
  :func:`superstep`      the per-device superstep body (``shard_map`` runtime)

Both are instantiated twice — :func:`minplus_algebra` (SSSP/BFS/BC/LL: buffer
combines by ``min``, relax is a tropical matmul) and :func:`push_algebra`
(PPR/NCP: buffer combines by ``+``, relax is a masked residual push).  Any
future mode (weighted PPR variants, reachability, k-hop sketches) lands in
*both* runtimes by defining one more operator set here (DESIGN.md §2.1).

Edge accounting is integral on device (int32 per visit — a visit touches far
fewer than 2^31 edges per query) and accumulated on host in float64, so counts
stay exact past float32's 2^24 integer ceiling on paper-scale graphs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.minplus import ops as minplus_ops

INF = jnp.inf
_BIG_STAMP = np.iinfo(np.int32).max - 1
_INT32_MAX = np.iinfo(np.int32).max

#: distributed edge counters carry (hi, lo) int32 lanes; lo spills into hi in
#: units of 2**_EDGE_SHIFT so totals stay exact up to ~2^51 edges per query.
EDGE_SHIFT = 20


# ---------------------------------------------------------------------------
# algebra: the mode-specific operators of Algorithm 2


class MinplusCarry(NamedTuple):
    d: jax.Array        # [Q, B] tentative values
    pending: jax.Array  # [Q, B] ops not yet relaxed this visit
    emit: jax.Array     # [Q, B] rows relaxed this visit (emission sources)
    alpha: jax.Array    # [Q, 1] best applied value (Δ-window anchor)


class PushCarry(NamedTuple):
    p: jax.Array        # [Q, B] PPR mass
    r: jax.Array        # [Q, B] residual (buffered ops consolidated in)
    acc: jax.Array      # [Q, B] accumulated pushed mass (emission payload)


@dataclasses.dataclass(frozen=True)
class VisitAlgebra:
    """Mode-specific operators; everything else is the shared skeleton.

    Conventions: ``planes`` is a tuple of ``[..., Q, B]`` value planes —
    ``(dist,)`` for minplus, ``(p, r)`` for push.  ``deg`` broadcasts on the
    last axis (``[B]`` per-partition row or ``[P, B]`` full), so ``pending``
    works on both a single resident partition and a whole device shard.
    """
    name: str
    identity: float                  # empty-buffer cell (+inf / 0)
    source_value: float              # buffered op injected per query source
    plane_init: Tuple[float, ...]    # initial plane fill values
    combine: Callable                # consolidate ops: (buf, contrib) -> buf
    begin: Callable                  # (planes_row, buf_row, deg_row) -> carry
    active: Callable                 # (carry, deg_row, eq, budget) -> [Q, B]
    step: Callable                   # (carry, active, w_pp, deg_row) -> carry
    emit_payload: Callable           # (carry) -> [Q, B] boundary payload
    emit_mask: Callable              # (carry) -> [Q, B] rows that cost edges
    contrib: Callable                # (payload, w_pj) -> [Q, B] neighbor ops
    scatter: Callable                # (buf, idx [S], cands [S, Q, B]) -> buf;
    #                                  segment-combine: duplicate idx entries
    #                                  fold by ``combine`` (min / add)
    pending: Callable                # (buf, planes, deg) -> bool [..., Q, B]
    prio_of: Callable                # (buf_row, planes_row, deg_row)
    #                                  -> (f32 priority, i32 op count)
    finish: Callable                 # (carry, deg_row) -> (planes_row', keep)
    #: the scalar hyperparameters the operators closed over, as static data —
    #: the fused Pallas visit kernel rebuilds the inner-round math from these
    #: (kernel bodies can't call back into the closure-captured XLA ops).
    params: Tuple[Tuple[str, float], ...] = ()

    @property
    def num_planes(self) -> int:
        return len(self.plane_init)

    def param(self, name: str) -> float:
        return dict(self.params)[name]


def minplus_algebra(window: float, relax: Optional[Callable] = None,
                    strict: bool = False) -> VisitAlgebra:
    """SSSP/BFS family: ops combine by ``min``, relax is min-plus matmul.

    ``strict=True`` makes an op pend only when it *strictly* improves the
    plane value (``buf < d`` instead of ``buf <= d``).  Positive-weight
    kinds terminate either way — a cycle re-sends values strictly above
    the plane, so equal re-sends never happen — but the zero-weight cc
    instantiation livelocks under ``<=``: two partitions forever re-emit
    each other's already-applied labels (equal, hence pending, hence
    re-emitted).  Strictness drops an op that cannot change anything,
    which is exact for an idempotent min fixpoint.
    """
    relax = relax or minplus_ops.minplus
    lt = jnp.less if strict else jnp.less_equal

    def pending(buf, planes, deg):
        (d,) = planes
        return jnp.isfinite(buf) & lt(buf, d)

    def prio_of(buf_row, planes_row, deg_row):
        pend = pending(buf_row, planes_row, deg_row)
        return (jnp.min(jnp.where(pend, buf_row, INF)),
                jnp.sum(pend, dtype=jnp.int32))

    def begin(planes_row, buf_row, deg_row):
        (d0,) = planes_row
        pending0 = jnp.isfinite(buf_row) & lt(buf_row, d0)
        d1 = jnp.minimum(d0, jnp.where(pending0, buf_row, INF))
        alpha = jnp.min(jnp.where(pending0, d1, INF), axis=1, keepdims=True)
        return MinplusCarry(d=d1, pending=pending0,
                            emit=jnp.zeros_like(pending0), alpha=alpha)

    def active(carry, deg_row, eq, budget):
        return (carry.pending & (carry.d <= carry.alpha + window)
                & (eq.astype(jnp.float32) < budget)[:, None])

    def step(carry, act, w_pp, deg_row):
        srcs = jnp.where(act, carry.d, INF)
        nd = relax(srcs, w_pp)
        improved = nd < carry.d
        return MinplusCarry(d=jnp.minimum(carry.d, nd),
                            pending=(carry.pending & ~act) | improved,
                            emit=carry.emit | act, alpha=carry.alpha)

    def finish(carry, deg_row):
        keep = jnp.where(carry.pending, carry.d, INF)
        return (carry.d,), keep

    return VisitAlgebra(
        name="minplus", identity=float(np.inf), source_value=0.0,
        plane_init=(float(np.inf),), combine=jnp.minimum,
        begin=begin, active=active, step=step,
        emit_payload=lambda carry: jnp.where(carry.emit, carry.d, INF),
        emit_mask=lambda carry: carry.emit,
        contrib=relax,
        scatter=lambda buf, idx, cands: buf.at[idx].min(cands),
        pending=pending, prio_of=prio_of, finish=finish,
        params=(("window", float(window)),
                ("strict", 1.0 if strict else 0.0)))


def push_algebra(alpha: float, eps: float,
                 spread: Optional[Callable] = None) -> VisitAlgebra:
    """PPR family: residual contributions combine by ``+``, relax is a masked
    ACL push round, priority is the most negative residual ratio."""
    spread = spread or minplus_ops.masked_matmul

    def _thresh(deg):
        return eps * jnp.maximum(deg, 1).astype(jnp.float32)

    def pending(buf, planes, deg):
        _, r = planes
        return (((r + buf) >= _thresh(deg)[..., None, :])
                & (deg > 0)[..., None, :])

    def prio_of(buf_row, planes_row, deg_row):
        _, r = planes_row
        ratio = (r + buf_row) / _thresh(deg_row)[None, :]
        has_edges = (deg_row > 0)[None, :]
        ready = (ratio >= 1.0) & has_edges
        prio = jnp.where(jnp.any(ready),
                         -jnp.max(jnp.where(has_edges, ratio, -INF)), INF)
        return prio, jnp.sum(ready, dtype=jnp.int32)

    def begin(planes_row, buf_row, deg_row):
        p0, r0 = planes_row
        return PushCarry(p=p0, r=r0 + buf_row, acc=jnp.zeros_like(r0))

    def active(carry, deg_row, eq, budget):
        return ((carry.r >= _thresh(deg_row)[None, :])
                & (deg_row > 0)[None, :]
                & (eq.astype(jnp.float32) < budget)[:, None])

    def step(carry, act, w_pp, deg_row):
        degc = jnp.maximum(deg_row, 1).astype(jnp.float32)
        af = act.astype(carry.r.dtype)
        pushed = (1.0 - alpha) * carry.r * af / degc[None, :]
        return PushCarry(p=carry.p + alpha * carry.r * af,
                         r=carry.r * (1.0 - af) + spread(pushed, w_pp),
                         acc=carry.acc + pushed)

    def finish(carry, deg_row):
        return (carry.p, carry.r), jnp.zeros_like(carry.r)

    return VisitAlgebra(
        name="push", identity=0.0, source_value=1.0, plane_init=(0.0, 0.0),
        combine=lambda buf, contrib: buf + contrib,
        begin=begin, active=active, step=step,
        emit_payload=lambda carry: carry.acc,
        emit_mask=lambda carry: carry.acc > 0,
        contrib=spread,
        scatter=lambda buf, idx, cands: buf.at[idx].add(cands),
        pending=pending, prio_of=prio_of, finish=finish,
        params=(("alpha", float(alpha)), ("eps", float(eps))))


# ---------------------------------------------------------------------------
# shared state container + initialization


class VisitState(NamedTuple):
    """Engine-side buffered state; the algebra defines what the planes mean."""
    planes: Tuple[jax.Array, ...]  # mode value planes, each [P, Q, B]
    buf: jax.Array                 # [P+1, Q, B] pending ops (row P = trash)
    prio: jax.Array                # [P] best pending priority (+inf empty)
    ops_count: jax.Array           # [P] pending op count
    stamp: jax.Array               # [P] visit counter when buf became non-empty


def init_dense_state(algebra: VisitAlgebra, num_parts: int, num_queries: int,
                     block_size: int, sources: np.ndarray,
                     trash_row: bool = True,
                     init_ops: Optional[np.ndarray] = None):
    """Host-side (planes, buf) with one source op buffered per query lane.

    ``sources``: [k] reordered vertex ids, k <= num_queries — lane ``i`` gets
    ``sources[i]``; remaining lanes start empty (streaming admission fills
    them later by the exact same buffered-op injection).

    ``init_ops``: optional ``[P, B]`` plane of buffered ops broadcast to
    every query lane before source injection — the every-vertex-is-a-source
    kinds (cc label propagation seeds each vertex with its own label) start
    from this instead of a one-hot source.  Cells holding
    ``algebra.identity`` stay empty, so partition padding is expressed by
    the caller writing identity there.
    """
    P, Q, B = num_parts, num_queries, block_size
    planes = tuple(np.full((P, Q, B), v, dtype=np.float32)
                   for v in algebra.plane_init)
    buf = np.full((P + (1 if trash_row else 0), Q, B), algebra.identity,
                  dtype=np.float32)
    if init_ops is not None:
        buf[:P] = np.broadcast_to(
            np.asarray(init_ops, dtype=np.float32)[:, None, :], (P, Q, B))
    sources = np.asarray(sources)
    if sources.size:
        parts, locs = np.divmod(sources, B)
        buf[parts, np.arange(sources.size), locs] = algebra.source_value
    return planes, buf


def cc_label_plane(bg) -> np.ndarray:
    """[P, B] initial cc label ops: every real vertex seeds its own reordered
    id as an f32 minplus op; padding slots hold the identity (+inf).  Shared
    by every cc backend so the propagated fixpoint is the same plane bitwise
    (integer-valued f32 mins, exact below 2^24 vertices)."""
    P, B = bg.num_parts, bg.block_size
    ids = np.arange(P * B, dtype=np.float32).reshape(P, B)
    return np.where(np.asarray(bg.vmask), ids, np.float32(np.inf))


def state_meta(algebra: VisitAlgebra, planes, buf, deg, counter: int = 0):
    """(prio, ops_count, stamp) for every partition, from the algebra's own
    priority operator — the single source of scheduling truth."""
    P = deg.shape[0]
    prio, ops = jax.vmap(algebra.prio_of)(buf[:P], planes, deg)
    stamp = jnp.where(jnp.isfinite(prio), jnp.int32(counter),
                      jnp.int32(_BIG_STAMP))
    return prio, ops, stamp


def init_engine_state(algebra: VisitAlgebra, dg, sources: np.ndarray,
                      num_queries: Optional[int] = None,
                      init_ops: Optional[np.ndarray] = None) -> VisitState:
    """Device state for the host-scheduled engine (trash buffer row included)."""
    Q = int(num_queries if num_queries is not None else len(sources))
    planes_np, buf_np = init_dense_state(
        algebra, dg.num_parts, Q, dg.block_size, sources, trash_row=True,
        init_ops=init_ops)
    planes = tuple(jnp.asarray(x) for x in planes_np)
    buf = jnp.asarray(buf_np)
    prio, ops, stamp = state_meta(algebra, planes, buf, dg.deg)
    return VisitState(planes, buf, prio, ops, stamp)


# ---------------------------------------------------------------------------
# generic visit kernel (single-device engine)


def _make_visit_body(dg, algebra: VisitAlgebra, max_rounds: int) -> Callable:
    """The unjitted visit body (Alg. 2 lines 6-16): apply + relax until
    yield, then emit one combined contribution per neighbor partition.

    ``visit(state, p, counter) -> (state', (rounds, eq))`` where ``eq`` is
    this visit's per-query edge count (int32 [Q], exact).  :func:`make_visit`
    jits it for per-visit host dispatch; :func:`make_megastep` runs it inside
    a device-resident ``lax.while_loop``.
    """
    P = dg.num_parts

    def visit(state: VisitState, p: jax.Array, counter: jax.Array):
        kd = dg.diag_blk[p]
        w_pp, nnz_pp, deg_p = dg.blocks[kd], dg.row_nnz[kd], dg.deg[p]
        planes_row = tuple(x[p] for x in state.planes)
        buf_row = state.buf[p]
        carry0 = algebra.begin(planes_row, buf_row, deg_p)
        budget = dg.edge_budget[p]

        def cond(c):
            carry, eq, rounds = c
            return jnp.logical_and(
                rounds < max_rounds,
                jnp.any(algebra.active(carry, deg_p, eq, budget)))

        def body(c):
            carry, eq, rounds = c
            act = algebra.active(carry, deg_p, eq, budget)
            eq = eq + jnp.sum(jnp.where(act, nnz_pp[None, :], 0), axis=1,
                              dtype=jnp.int32)
            return algebra.step(carry, act, w_pp, deg_p), eq, rounds + 1

        eq0 = jnp.zeros(buf_row.shape[0], dtype=jnp.int32)
        carry, eq, rounds = jax.lax.while_loop(
            cond, body, (carry0, eq0, jnp.int32(0)))

        # ---- emission to neighbor partitions (Alg. 2 line 16): ONE batched
        # contrib over all neighbor blocks (vmap) + a single segment-combine
        # scatter, instead of a serial dmax-step fori_loop ----
        payload = algebra.emit_payload(carry)
        emask = algebra.emit_mask(carry)
        parts = dg.nbr_part[p]                         # [dmax] (-1 pad)
        valid = parts >= 0
        blk0 = jnp.where(valid, dg.nbr_blk[p], 0)
        j0 = jnp.where(valid, parts, 0)                # clamped gather index
        jj = jnp.where(valid, parts, P)                # trash row for padding
        cands = jax.vmap(lambda w: algebra.contrib(payload, w))(
            dg.blocks[blk0])                           # [dmax, Q, B]
        cands = jnp.where(valid[:, None, None], cands, algebra.identity)
        nnz_sl = jnp.where(valid[:, None], dg.row_nnz[blk0], 0)  # [dmax, B]
        eq = eq + jnp.sum(jnp.where(emask[None], nnz_sl[:, None, :], 0),
                          axis=(0, 2), dtype=jnp.int32)
        was_empty = ~jnp.isfinite(state.prio)          # [P], pre-emission
        buf = algebra.scatter(state.buf, jj, cands)
        # metadata refresh gathers AFTER the full scatter, so duplicate
        # destinations all observe the combined row (order-independent)
        planes_j = tuple(x[j0] for x in state.planes)
        newprio, newops = jax.vmap(algebra.prio_of)(buf[j0], planes_j,
                                                    dg.deg[j0])
        prio = state.prio.at[jj].set(newprio, mode="drop")
        ops_count = state.ops_count.at[jj].set(newops, mode="drop")
        stamp = state.stamp.at[jj].set(
            jnp.where(was_empty[j0] & jnp.isfinite(newprio), counter,
                      state.stamp[j0]), mode="drop")

        # ---- write back own planes, keep yielded ops, refresh priority ----
        new_rows, keep_row = algebra.finish(carry, deg_p)
        buf = buf.at[p].set(keep_row)
        own_prio, own_ops = algebra.prio_of(keep_row, new_rows, deg_p)
        prio = prio.at[p].set(own_prio)
        ops_count = ops_count.at[p].set(own_ops)
        stamp = stamp.at[p].set(jnp.where(jnp.isfinite(own_prio), counter,
                                          jnp.int32(_BIG_STAMP)))
        planes = tuple(x.at[p].set(nr)
                       for x, nr in zip(state.planes, new_rows))
        return VisitState(planes, buf, prio, ops_count, stamp), (rounds, eq)

    return visit


def make_visit(dg, algebra: VisitAlgebra, max_rounds: int) -> Callable:
    """The one visit kernel, jitted for per-visit host dispatch.

    ``visit(state, p, counter) -> (state', (rounds, eq))``.
    """
    return jax.jit(_make_visit_body(dg, algebra, max_rounds))


# ---------------------------------------------------------------------------
# device-resident scheduling: the K-visit megastep (DESIGN.md §2.3)


def device_select(policy: str, prio: jax.Array, stamp: jax.Array,
                  ops_count: jax.Array, key: jax.Array) -> jax.Array:
    """On-device mirror of ``PartitionScheduler.select`` (the host oracle).

    Returns the selected partition index (i32 scalar); the caller guarantees
    at least one finite-priority partition (the megastep's while-cond).  The
    deterministic policies reproduce the host argmin/argmax bit-for-bit,
    including first-index tie-breaking; ``random`` draws a uniform per
    partition from the carried threefry ``key`` and argmaxes it over the
    non-empty set — a uniform choice, seeded and replayable on device (the
    host scheduler's numpy ``Generator`` stream differs, but scheduling
    never changes results, paper §5.1).
    """
    if policy == "priority":
        return jnp.argmin(prio)
    nonempty = jnp.isfinite(prio)
    if policy == "fifo":
        return jnp.argmin(jnp.where(nonempty, stamp, jnp.int32(_INT32_MAX)))
    if policy == "max_ops":
        return jnp.argmax(jnp.where(nonempty, ops_count, jnp.int32(-1)))
    if policy == "random":
        u = jax.random.uniform(key, prio.shape)
        return jnp.argmax(jnp.where(nonempty, u, -1.0))
    raise ValueError(f"unknown scheduling policy {policy!r}")


class MegastepStats(NamedTuple):
    """Per-chunk device accumulators, harvested once per host dispatch."""
    visits: jax.Array        # i32 scalar: visits executed this chunk (<= K)
    rounds: jax.Array        # i32 scalar: total relaxation rounds
    eq_hi: jax.Array         # [Q] i32: per-query edge count, high lane
    eq_lo: jax.Array         # [Q] i32: low lane (< 2**EDGE_SHIFT)
    visit_counts: jax.Array  # [P] i32: visits per partition (traffic model)
    order: jax.Array         # [K] i32 visit-order ring (-1 = unused slot)
    lane_pending: jax.Array  # [Q] bool: query lane still has a pending op
    #                          anywhere (streaming harvest, same dispatch)
    key: jax.Array           # threefry key to carry into the next chunk


def make_megastep(dg, algebra: VisitAlgebra, max_rounds: int,
                  policy: str = "priority", K: int = 64,
                  harvest_mask: bool = False, fused: bool = False,
                  frontier_mode: str = "dense") -> Callable:
    """Device-resident scheduling loop: up to K visits per host dispatch.

    Wraps the visit body in a ``lax.while_loop`` whose scheduler decision is
    an on-device argmin/argmax over the ``[P]`` prio/stamp/ops planes the
    visit kernel already maintains (``random`` draws from a threefry key
    carried in the loop), so the host is consulted once per K visits instead
    of once per visit.  Per-visit stats accumulate on device
    (:class:`MegastepStats`) and are harvested once per chunk; the edge
    counters carry an exact ``(hi, lo)`` int32 pair per query (lo spills
    into hi in 2**EDGE_SHIFT units, the distributed-runtime idiom).

    Returns ``megastep(state, counter, limit, key) -> (state', stats)``:
    ``counter`` is the global visit counter at chunk start (stamps continue
    across chunks), ``limit`` dynamically caps this chunk at
    ``min(limit, K)`` visits (exact ``max_visits`` semantics without a
    recompile), and the loop exits early when no partition holds a pending
    op — ``stats.visits < limit`` is the host's termination signal.

    ``harvest_mask=True`` additionally reduces the per-query pending-lane
    mask from the chunk-end state into ``stats.lane_pending`` — the
    streaming executor's harvest rides the same dispatch.  Plain engine
    runs never read it, so they skip the [P, Q, B] reduction (the field is
    an empty placeholder).

    ``fused=True`` swaps the visit body for the fused Pallas kernel
    (``kernels/fused_visit``): the resident partition's planes, buffer
    row, and scheduler metadata stay in VMEM for the whole visit, with
    ``kernels/frontier`` (consolidation) and ``kernels/ppr_push`` (push
    rounds) as the in-kernel tile ops.  Bit-identical to the XLA body for
    minplus and deterministic push (see ``kernels/fused_visit/fused.py``
    for the parity argument; ``tests/test_fused_visit.py`` pins it).
    ``frontier_mode="sparse"`` (minplus only) makes the in-kernel relax
    skip all-inf source chunks — identical bits, less work on the thin
    late-round frontiers (DESIGN.md §2.4).
    """
    from repro.core.scheduler import POLICIES
    if policy not in POLICIES:
        raise ValueError(f"unknown scheduling policy {policy!r}; "
                         f"one of {POLICIES}")
    if K < 1:
        raise ValueError(f"megastep chunk size K must be >= 1, got {K}")
    P = dg.num_parts
    if fused:
        # the dispatch-table wiring of the three visit kernels (the
        # pallas.reachability pass keys off these imports)
        from repro.kernels.frontier.ops import frontier_tile
        from repro.kernels.fused_visit.fused import (META_OPS, META_PRIO,
                                                     META_STAMP)
        from repro.kernels.fused_visit.ops import make_fused_visit
        from repro.kernels.ppr_push.ops import push_tile
        fv = make_fused_visit(dg, algebra, max_rounds,
                              frontier=frontier_tile, push=push_tile,
                              frontier_mode=frontier_mode)

        # the while_loop carries the kernel's packed layout for the whole
        # K-visit chunk: pack once on entry, unpack once on exit, and read
        # the scheduler metadata straight out of the packed planes.
        def visit(pk, p, counter):
            pk, rounds, eq = fv.visit(pk, p, counter)
            return pk, (rounds, eq)

        def enter(state: VisitState):
            return fv.pack(state.planes, state.buf, state.prio,
                           state.ops_count, state.stamp)

        def leave(pk) -> VisitState:
            return VisitState(*fv.unpack(pk))

        def meta(pk):
            prio = jax.lax.bitcast_convert_type(pk.meta[:P, META_PRIO],
                                                jnp.float32)
            return prio, pk.meta[:P, META_STAMP], pk.meta[:P, META_OPS]
    else:
        if frontier_mode != "dense":
            raise ValueError(
                "frontier_mode is a fused-kernel switch; the XLA megastep "
                "always runs the dense frontier math")
        visit = _make_visit_body(dg, algebra, max_rounds)
        enter = leave = lambda st: st

        def meta(st: VisitState):
            return st.prio, st.stamp, st.ops_count

    @jax.jit
    def megastep(state: VisitState, counter: jax.Array, limit: jax.Array,
                 key: jax.Array):
        limit_k = jnp.minimum(jnp.int32(limit), jnp.int32(K))

        def cond(c):
            st, k = c[0], c[1]
            return jnp.logical_and(k < limit_k,
                                   jnp.any(jnp.isfinite(meta(st)[0])))

        def body(c):
            st, k, rounds, hi, lo, counts, order, key = c
            if policy == "random":          # trace-time: only the random
                key, sub = jax.random.split(key)  # policy consumes entropy
            else:
                sub = key
            prio, stamp, ops_count = meta(st)
            p = device_select(policy, prio, stamp, ops_count, sub)
            st, (r, eq) = visit(st, p, counter + k)
            lo = lo + eq
            spill = lo >> EDGE_SHIFT
            hi = hi + spill
            lo = lo - (spill << EDGE_SHIFT)
            counts = counts.at[p].add(1)
            order = order.at[k].set(p.astype(jnp.int32))
            return st, k + 1, rounds + r, hi, lo, counts, order, key

        Q = state.buf.shape[1]
        init = (enter(state), jnp.int32(0), jnp.int32(0),
                jnp.zeros(Q, jnp.int32), jnp.zeros(Q, jnp.int32),
                jnp.zeros(P, jnp.int32), jnp.full((K,), -1, jnp.int32), key)
        st, k, rounds, hi, lo, counts, order, key = jax.lax.while_loop(
            cond, body, init)
        st = leave(st)
        lane_pending = (jnp.any(
            algebra.pending(st.buf[:P], st.planes, dg.deg), axis=(0, 2))
            if harvest_mask else jnp.zeros((0,), dtype=bool))
        return st, MegastepStats(visits=k, rounds=rounds, eq_hi=hi, eq_lo=lo,
                                 visit_counts=counts, order=order,
                                 lane_pending=lane_pending, key=key)

    return megastep


def harvest_edges(eq_hi: np.ndarray, eq_lo: np.ndarray) -> np.ndarray:
    """Fold a harvested (hi, lo) int32 pair into exact float64 edge counts."""
    return (np.asarray(eq_hi, dtype=np.float64) * float(1 << EDGE_SHIFT)
            + np.asarray(eq_lo, dtype=np.float64))


# ---------------------------------------------------------------------------
# generic superstep (shard_map pod runtime)


def superstep(blocks, dstp, nnz, deg, budget, planes, buf, *,
              algebra: VisitAlgebra, max_rounds: int, pl: int, dmax: int,
              ndev: int, model_axis: str):
    """One superstep on one device's shard: visit the locally best-priority
    partition, then exchange boundary ops with a single ``all_to_all``.

    planes/buf: [pl, Qs, B].  Returns (planes', buf', eq int32 [Qs]).
    """
    prio, _ = jax.vmap(algebra.prio_of)(buf, planes, deg)
    p = jnp.argmin(prio)                  # all-INF => a harmless no-op visit

    w_all, nnz_all, deg_p = blocks[p], nnz[p], deg[p]
    w_pp, nnz_pp = w_all[0], nnz_all[0]
    planes_row = tuple(x[p] for x in planes)
    buf_row = buf[p]
    carry0 = algebra.begin(planes_row, buf_row, deg_p)
    budget_p = budget[p]
    Qs, B = buf_row.shape

    def cond(c):
        carry, eq, rounds = c
        return jnp.logical_and(
            rounds < max_rounds,
            jnp.any(algebra.active(carry, deg_p, eq, budget_p)))

    def body(c):
        carry, eq, rounds = c
        act = algebra.active(carry, deg_p, eq, budget_p)
        eq = eq + jnp.sum(jnp.where(act, nnz_pp[None, :], 0), axis=1,
                          dtype=jnp.int32)
        return algebra.step(carry, act, w_pp, deg_p), eq, rounds + 1

    eq0 = jnp.zeros(Qs, dtype=jnp.int32)
    carry, eq, _ = jax.lax.while_loop(cond, body, (carry0, eq0, jnp.int32(0)))

    # --- emissions: one contribution per (padded) out-slot ---
    payload = algebra.emit_payload(carry)
    emask = algebra.emit_mask(carry)
    cands = jax.vmap(lambda w: algebra.contrib(payload, w))(w_all[1:])
    dsts = dstp[p, 1:]                                    # [dmax]
    eq = eq + jnp.sum(jnp.where(emask[None], nnz_all[1:][:, None, :], 0),
                      axis=(0, 2), dtype=jnp.int32)

    # route to owner devices over the model axis: payload [ndev, dmax, Qs, B]
    owner = jnp.where(dsts >= 0, dsts // pl, -1)
    pay = jnp.full((ndev, dmax, Qs, B), algebra.identity, dtype=buf_row.dtype)
    slot_dst = jnp.full((ndev, dmax), -1, dtype=jnp.int32)

    def route(s, c):
        pay, slot_dst = c
        o = owner[s]
        valid = o >= 0
        oo = jnp.where(valid, o, 0)
        pay = pay.at[oo, s].set(jnp.where(valid, cands[s], pay[oo, s]))
        slot_dst = slot_dst.at[oo, s].set(
            jnp.where(valid, dsts[s] % pl, slot_dst[oo, s]))
        return pay, slot_dst

    pay, slot_dst = jax.lax.fori_loop(0, dmax, route, (pay, slot_dst))
    recv = jax.lax.all_to_all(pay, model_axis, 0, 0, tiled=False)
    recv_dst = jax.lax.all_to_all(slot_dst, model_axis, 0, 0, tiled=False)

    # --- write back own planes / yielded ops, apply received contributions --
    new_rows, keep_row = algebra.finish(carry, deg_p)
    buf = buf.at[p].set(keep_row)
    planes = tuple(x.at[p].set(nr) for x, nr in zip(planes, new_rows))
    flat_recv = recv.reshape(ndev * dmax, Qs, B)
    flat_dst = recv_dst.reshape(ndev * dmax)

    def apply_one(i, b):
        l = flat_dst[i]
        valid = l >= 0
        ll = jnp.where(valid, l, 0)
        new = algebra.combine(
            b[ll], jnp.where(valid, flat_recv[i], algebra.identity))
        return b.at[ll].set(jnp.where(valid, new, b[ll]))

    buf = jax.lax.fori_loop(0, ndev * dmax, apply_one, buf)
    return planes, buf, eq
