"""Buffered random walks (the paper's RW query type, §3 / Fig. 15).

Walkers are FPP queries; the buffered execution model applies directly: each
partition buffers the walkers currently inside it, a visit steps *all* resident
walkers repeatedly within the VMEM-resident block until they exit the partition
(or finish), then exiting walkers are handed to their new partitions in a
batch.  Temporal locality is maximal — the paper reports RW among the best
scaling query types (Fig. 15).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DeviceGraph
from repro.core.graph import BlockGraph
from repro.core.yielding import NO_YIELD

NEG_INF = -jnp.inf


@dataclasses.dataclass
class WalkResult:
    positions: np.ndarray      # [Q] final vertex (original padded id space)
    steps: np.ndarray          # [Q]
    trajectory_hash: np.ndarray  # [Q] order-sensitive hash (for testing)
    visits: int


def run_random_walks(bg: BlockGraph, sources: np.ndarray, length: int,
                     seed: int = 0, max_rounds_per_visit: int = 64) -> WalkResult:
    """Walk ``length`` steps from each source. Walkers at sink vertices stop."""
    dg = DeviceGraph.build(bg, NO_YIELD, len(sources))
    P, B, Q = dg.num_parts, dg.block_size, len(sources)
    key0 = jax.random.PRNGKey(seed)

    @jax.jit
    def visit(pos, steps, part, thash, key, p):
        """Steps all walkers whose ``part == p`` until they leave p/finish."""

        def cond(c):
            pos, steps, part, thash, key, rounds = c
            here = (part == p) & (steps < length)
            return jnp.logical_and(rounds < max_rounds_per_visit,
                                   jnp.any(here))

        def body(c):
            pos, steps, part, thash, key, rounds = c
            here = (part == p) & (steps < length)
            loc = pos % B
            # adjacency row of each walker: diagonal block + out blocks
            diag_rows = dg.blocks[dg.diag_blk[p], loc]          # [Q, B]
            out_blks = dg.nbr_blk[p]                            # [Dmax]
            out_rows = dg.blocks[jnp.maximum(out_blks, 0)][:, loc, :]
            out_rows = jnp.where((out_blks >= 0)[:, None, None],
                                 out_rows.transpose(0, 1, 2), jnp.inf)
            rows = jnp.concatenate(
                [diag_rows[None], out_rows], axis=0)            # [D+1, Q, B]
            rows = rows.transpose(1, 0, 2).reshape(Q, -1)       # [Q, (D+1)B]
            finite = jnp.isfinite(rows)
            key, sub = jax.random.split(key)
            gumbel = jax.random.gumbel(sub, rows.shape)
            score = jnp.where(finite, gumbel, NEG_INF)
            choice = jnp.argmax(score, axis=1)                  # [Q]
            has_nbr = jnp.any(finite, axis=1)
            slot = choice // B
            new_loc = choice % B
            dest_parts = jnp.concatenate(
                [jnp.array([p], dtype=jnp.int32),
                 jnp.where(dg.nbr_part[p] >= 0, dg.nbr_part[p], p)])
            new_part = dest_parts[slot]
            new_pos = new_part * B + new_loc
            move = here & has_nbr
            # sinks finish their walk in place
            steps = jnp.where(here & ~has_nbr, length, steps)
            pos = jnp.where(move, new_pos, pos)
            part = jnp.where(move, new_part, part)
            steps = jnp.where(move, steps + 1, steps)
            thash = jnp.where(move,
                              thash * jnp.uint32(1000003)
                              + new_pos.astype(jnp.uint32), thash)
            return pos, steps, part, thash, key, rounds + 1

        pos, steps, part, thash, key, _ = jax.lax.while_loop(
            cond, body, (pos, steps, part, thash, key, jnp.int32(0)))
        return pos, steps, part, thash, key

    srcs = np.asarray(sources)
    pos = jnp.asarray(srcs.astype(np.int32))
    part = jnp.asarray((srcs // B).astype(np.int32))
    steps = jnp.zeros(Q, dtype=jnp.int32)
    thash = jnp.asarray(srcs.astype(np.uint32))
    key = key0
    visits = 0
    while True:
        part_np, steps_np = np.asarray(part), np.asarray(steps)
        live = steps_np < length
        if not live.any():
            break
        # max-ops scheduling: partition with most resident walkers (the cache
        # greedy choice is the right one for walks: no redundant work exists)
        counts = np.bincount(part_np[live], minlength=P)
        p = int(np.argmax(counts))
        pos, steps, part, thash, key = visit(pos, steps, part, thash, key,
                                             jnp.int32(p))
        visits += 1
        if visits > Q * length + P:  # safety; unreachable in practice
            break
    return WalkResult(np.asarray(pos), np.asarray(steps), np.asarray(thash),
                      visits)
