"""Buffered random walks (the paper's RW query type, §3 / Fig. 15).

Walkers are FPP queries; the buffered execution model applies directly: each
partition buffers the walkers currently inside it, a visit steps *all* resident
walkers repeatedly within the VMEM-resident block until they exit the partition
(or finish), then exiting walkers are handed to their new partitions in a
batch.  Temporal locality is maximal — the paper reports RW among the best
scaling query types (Fig. 15).

Randomness contract (the ``rw`` kind's portability invariant, pinned by
``oracles.random_walk``): walker ``src`` at step ``t`` draws

    u = uniform(fold_in(fold_in(PRNGKey(seed), src), t))

and takes the ``min(floor(u * deg), deg - 1)``-th finite entry of its
block-layout adjacency row (diagonal columns first, then the ``nbr_blk``
slots in order).  Because the tape is indexed by (source, step) — not by
visit order, lane placement, or key-split history — the trajectory is a pure
function of (graph, seed, source, length), so the partition-resident engine
loop, the synchronous baselines round, the sharded distributed stepper, and
the serving lanes all reproduce identical walks bitwise.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DeviceGraph
from repro.core.graph import BlockGraph
from repro.core.yielding import NO_YIELD


@dataclasses.dataclass
class WalkResult:
    positions: np.ndarray      # [Q] final vertex (reordered padded id space)
    steps: np.ndarray          # [Q]
    trajectory_hash: np.ndarray  # [Q] order-sensitive hash (for testing)
    visits: int
    occupancy: Optional[np.ndarray] = None  # [Q, n] f32 visit counts
    #                                         (start + each step's position)


def stepper_from_arrays(blocks, diag_blk, nbr_blk, nbr_part,
                        block_size: int, length: int, key0) -> Callable:
    """The one-step transition shared by every rw runtime, built from bare
    graph arrays so the distributed runtime can reconstruct it inside a
    ``shard_map`` body from replicated operands.

    ``step(pos, steps, part, src, thash, occ, mask) -> (pos', steps', part',
    thash', occ')`` advances every walker in ``mask`` by one tape entry
    (walkers on sinks park with ``steps = length``).  All arrays are [Q]
    except ``occ`` [Q, P * B]; ``src`` is the walker's tape id (its source
    vertex, reordered space), constant for the walk's lifetime.
    """
    B = block_size

    def step(pos, steps, part, src, thash, occ, mask):
        Q = pos.shape[0]
        loc = pos % B
        diag = blocks[diag_blk[part], loc]                   # [Q, B]
        nbrb = nbr_blk[part]                                 # [Q, D]
        nbrp = nbr_part[part]                                # [Q, D]
        out = blocks[jnp.maximum(nbrb, 0), loc[:, None]]     # [Q, D, B]
        out = jnp.where((nbrb >= 0)[:, :, None], out, jnp.inf)
        rows = jnp.concatenate([diag[:, None], out], axis=1).reshape(Q, -1)
        finite = jnp.isfinite(rows)
        deg = jnp.sum(finite, axis=1, dtype=jnp.int32)
        keys = jax.vmap(lambda s, t: jax.random.fold_in(
            jax.random.fold_in(key0, s), t))(src, steps)
        u = jax.vmap(jax.random.uniform)(keys)               # [Q] in [0, 1)
        idx = jnp.clip(jnp.floor(u * deg.astype(jnp.float32)).astype(
            jnp.int32), 0, jnp.maximum(deg - 1, 0))
        # pick the (idx+1)-th finite column: first position where the
        # running finite count hits idx+1 and the cell itself is finite
        cum = jnp.cumsum(finite, axis=1)
        choice = jnp.argmax((cum == (idx + 1)[:, None]) & finite, axis=1)
        slot, new_loc = choice // B, choice % B
        dest_parts = jnp.concatenate(
            [part[:, None], jnp.where(nbrp >= 0, nbrp, 0)], axis=1)
        new_part = jnp.take_along_axis(dest_parts, slot[:, None], axis=1)[:, 0]
        new_pos = new_part * B + new_loc
        has_nbr = deg > 0
        move = mask & has_nbr
        steps = jnp.where(mask & ~has_nbr, jnp.int32(length), steps)
        pos = jnp.where(move, new_pos, pos)
        part = jnp.where(move, new_part, part)
        steps = jnp.where(move, steps + 1, steps)
        thash = jnp.where(move,
                          thash * jnp.uint32(1000003)
                          + new_pos.astype(jnp.uint32), thash)
        occ = occ.at[jnp.arange(Q), new_pos].add(move.astype(occ.dtype))
        return pos, steps, part, thash, occ

    return step


def make_walk_stepper(dg: DeviceGraph, length: int, seed: int) -> Callable:
    """:func:`stepper_from_arrays` over a staged :class:`DeviceGraph`."""
    return stepper_from_arrays(dg.blocks, dg.diag_blk, dg.nbr_blk,
                               dg.nbr_part, dg.block_size, length,
                               jax.random.PRNGKey(seed))


def make_walk_visit(dg: DeviceGraph, length: int, seed: int,
                    max_rounds: int = 64) -> Callable:
    """The jitted rw visit: steps all walkers resident in partition ``p``
    until they leave it, finish, or hit ``max_rounds`` — the rw analogue of
    the engine's buffered visit (occupancy plane instead of value planes).

    ``visit(pos, steps, part, src, thash, occ, p) -> same state``.
    """
    step = make_walk_stepper(dg, length, seed)

    @jax.jit
    def visit(pos, steps, part, src, thash, occ, p):
        def cond(c):
            pos, steps, part, thash, occ, rounds = c
            here = (part == p) & (steps < length)
            return jnp.logical_and(rounds < max_rounds, jnp.any(here))

        def body(c):
            pos, steps, part, thash, occ, rounds = c
            here = (part == p) & (steps < length)
            pos, steps, part, thash, occ = step(pos, steps, part, src,
                                               thash, occ, here)
            return pos, steps, part, thash, occ, rounds + 1

        pos, steps, part, thash, occ, _ = jax.lax.while_loop(
            cond, body, (pos, steps, part, thash, occ, jnp.int32(0)))
        return pos, steps, part, thash, occ

    return visit


def init_walk_state(dg: DeviceGraph, sources: np.ndarray):
    """(pos, steps, part, src, thash, occ) device state; occupancy starts
    with the source position counted once per lane."""
    srcs = np.asarray(sources, dtype=np.int32)
    Q = srcs.size
    occ = np.zeros((Q, dg.num_parts * dg.block_size), dtype=np.float32)
    occ[np.arange(Q), srcs] = 1.0
    return (jnp.asarray(srcs), jnp.zeros(Q, dtype=jnp.int32),
            jnp.asarray(srcs // dg.block_size), jnp.asarray(srcs),
            jnp.asarray(srcs.astype(np.uint32)), jnp.asarray(occ))


def run_random_walks(bg: BlockGraph, sources: np.ndarray, length: int,
                     seed: int = 0, max_rounds_per_visit: int = 64) -> WalkResult:
    """Walk ``length`` steps from each source. Walkers at sink vertices stop."""
    dg = DeviceGraph.build(bg, NO_YIELD, len(sources))
    P, Q = dg.num_parts, len(sources)
    visit = make_walk_visit(dg, length, seed, max_rounds=max_rounds_per_visit)
    pos, steps, part, src, thash, occ = init_walk_state(dg, sources)
    visits = 0
    while True:
        part_np, steps_np = np.asarray(part), np.asarray(steps)
        live = steps_np < length
        if not live.any():
            break
        # max-ops scheduling: partition with most resident walkers (the cache
        # greedy choice is the right one for walks: no redundant work exists)
        counts = np.bincount(part_np[live], minlength=P)
        p = int(np.argmax(counts))
        pos, steps, part, thash, occ = visit(pos, steps, part, src, thash,
                                             occ, jnp.int32(p))
        visits += 1
        if visits > Q * length + P:  # safety; unreachable in practice
            break
    return WalkResult(np.asarray(pos), np.asarray(steps), np.asarray(thash),
                      visits, occupancy=np.asarray(occ)[:, :bg.n])
