"""Inter-partition scheduling (paper §5.2).

The scheduler selects which partition to make cache/VMEM-resident next:

  priority   partition holding the globally best-priority pending op
             (shortest tentative distance / highest PPR residual) — the paper's
             headline policy, several-x faster than the rest (Table 4A)
  fifo       order buffers first became non-empty (paper's default fallback)
  random     arbitrary non-empty buffer (Table 4A baseline)
  max_ops    most pending ops first — cache-reuse-greedy; the paper shows it is
             counterproductive (more redundant work than random)

Scores are produced on device by the engine.  In the hot path selection is
on-device too (``core/visit.device_select``, inside the K-visit megastep);
this host implementation is the *oracle* the device policies are tested
against (tests/test_megastep.py) and what the legacy per-visit loop and the
streaming ``step()`` path still call — |P| is small (<< |V|), exactly the
paper's STL priority-queue argument.

The same selector arbitrates one level up: ``serve/graph_server.py``
(DESIGN.md §4.2) treats its per-(graph, kind) lane pools as "partitions" —
pool priority is the best queued/in-flight *request* priority, the stamp is
the round a pool first became non-empty, ops is its backlog — so request
priorities plumb through the identical policy set that orders partition
visits.  Serving wants priority ties broken toward the *oldest* pool
(otherwise a low pool index wins every tie and a same-priority pool can
wait arbitrarily); ``prefer_older_ties=True`` opts into that host-only
refinement without perturbing the device-oracle contract below.
"""
from __future__ import annotations

import numpy as np

POLICIES = ("priority", "fifo", "random", "max_ops")


class PartitionScheduler:
    def __init__(self, policy: str, num_parts: int, seed: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"unknown scheduling policy {policy!r}")
        self.policy = policy
        self.num_parts = num_parts
        self._rng = np.random.default_rng(seed)

    def select(self, prio: np.ndarray, stamp: np.ndarray,
               ops_count: np.ndarray, *,
               prefer_older_ties: bool = False) -> int | None:
        """prio: [P] float32, lower=more urgent, +inf empty.  stamp: [P]
        *int32* visit counter at which the buffer last became non-empty
        (empty rows carry the int32-max-1 sentinel from core/visit.py, so
        the fifo masking below is belt-and-braces, not a dtype rescue —
        the docstring used to claim int64, which the device state never
        was).  ops_count: [P] pending op count.  Returns the partition id,
        or None when every buffer is drained (run complete).

        Deterministic policies here and in ``core/visit.device_select``
        must agree bit-for-bit, first-index ties included; ``random`` is
        numpy-Generator-driven here and threefry-driven on device (both
        uniform over non-empty partitions, streams differ).

        ``prefer_older_ties`` (default off, so the device contract above is
        untouched) refines the ``priority`` policy only: among rows tied at
        the best priority, pick the smallest stamp — the serving tie-break
        GraphServer uses for pool arbitration (DESIGN.md §4.2)."""
        nonempty = np.isfinite(prio)
        if not nonempty.any():
            return None
        if self.policy == "priority":
            if prefer_older_ties:
                ties = prio == prio[int(np.argmin(prio))]
                masked = np.where(ties, stamp, np.iinfo(np.int64).max)
                return int(np.argmin(masked))
            return int(np.argmin(prio))
        if self.policy == "fifo":
            masked = np.where(nonempty, stamp, np.iinfo(np.int32).max)
            return int(np.argmin(masked))
        if self.policy == "max_ops":
            masked = np.where(nonempty, ops_count, -1)
            return int(np.argmax(masked))
        # random
        choices = np.flatnonzero(nonempty)
        return int(self._rng.choice(choices))
