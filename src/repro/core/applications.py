"""FPP-based applications from the paper: BC, NCP, LL (§1, §6.1).

Per the paper, the FPP phase (the batched graph queries) dominates (>90%) and
runs on the buffered engine; the per-application gather phases (Brandes
accumulation, conductance sweeps, label assembly) are host-side numpy.

The query phase goes through the unified ``FPPSession`` front door
(fpp/session.py, DESIGN.md §3); the gather phases are exposed standalone
(``bc_accumulate``, ``ncp_profile``) so the session's application methods and
these legacy entry points share one implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.graph import CSRGraph
from repro.core.yielding import YieldConfig


def _session(g: CSRGraph, block_size: int, method: str,
             schedule: str, yield_config: Optional[YieldConfig],
             num_queries: int):
    from repro.fpp.session import FPPSession   # lazy: avoid import cycle
    return FPPSession(g).plan(num_queries=num_queries, block_size=block_size,
                              method=method, schedule=schedule,
                              yield_config=yield_config)


# ---------------------------------------------------------------------------
# Betweenness centrality (Brandes with sampled sources, Eppstein-style approx)


def _sigma_delta(g: CSRGraph, dist: np.ndarray):
    """Vectorized-by-level Brandes counting for one source's BFS ``dist``
    (int levels, -1 unreachable). Returns (sigma, delta)."""
    src, dst, _ = g.edges()
    sigma = np.zeros(g.n, dtype=np.float64)
    delta = np.zeros(g.n, dtype=np.float64)
    if (dist >= 0).sum() == 0:
        return sigma, delta
    root = int(np.flatnonzero(dist == 0)[0])
    sigma[root] = 1.0
    maxlev = int(dist.max())
    tree = (dist[src] >= 0) & (dist[dst] == dist[src] + 1)
    tsrc, tdst = src[tree], dst[tree]
    lev_of_edge = dist[tdst]  # level of the deeper endpoint
    for lev in range(1, maxlev + 1):
        sel = lev_of_edge == lev
        np.add.at(sigma, tdst[sel], sigma[tsrc[sel]])
    for lev in range(maxlev, 0, -1):
        sel = lev_of_edge == lev
        contrib = (sigma[tsrc[sel]] / np.maximum(sigma[tdst[sel]], 1.0)
                   * (1.0 + delta[tdst[sel]]))
        np.add.at(delta, tsrc[sel], contrib)
    return sigma, delta


def bc_accumulate(g: CSRGraph, sources: np.ndarray,
                  levels: np.ndarray) -> np.ndarray:
    """Brandes gather phase over per-source BFS levels (original ids).

    ``levels``: float [Q, n], +inf (or any non-finite) = unreachable.
    """
    bc = np.zeros(g.n, dtype=np.float64)
    for qi, s in enumerate(np.asarray(sources)):
        lev = levels[qi]
        lev = np.where(np.isfinite(lev), lev, -1).astype(np.int32)
        _, delta = _sigma_delta(g, lev)
        delta[s] = 0.0
        bc += delta
    return bc


def betweenness_centrality(g: CSRGraph, sources: np.ndarray,
                           block_size: int = 256, method: str = "bfs",
                           yield_config: Optional[YieldConfig] = None,
                           schedule: str = "priority"):
    """Approximate BC by |sources| sampled BFS roots (paper: 100 random)."""
    sess = _session(g, block_size, method, schedule, yield_config,
                    len(np.asarray(sources)))
    bc, res = sess.bc(np.asarray(sources))
    return bc, res


# ---------------------------------------------------------------------------
# Landmark labeling


@dataclasses.dataclass
class LandmarkLabels:
    landmarks: np.ndarray   # [L]
    dists: np.ndarray       # [L, n] distances from each landmark

    def query(self, u, v) -> np.ndarray:
        """Upper-bound distance estimate via best landmark (paper's LL use)."""
        return np.min(self.dists[:, u] + self.dists[:, v], axis=0)


def landmark_labeling(g: CSRGraph, landmarks: np.ndarray,
                      block_size: int = 256, method: str = "bfs",
                      yield_config: Optional[YieldConfig] = None,
                      schedule: str = "priority"):
    """Batch-of-SSSPs labeling (paper follows Akiba et al.: 16..1024 SSSPs)."""
    sess = _session(g, block_size, method, schedule, yield_config,
                    len(np.asarray(landmarks)))
    return sess.landmarks(np.asarray(landmarks))


# ---------------------------------------------------------------------------
# Network community profile (via many PPRs + sweep cuts)


def sweep_conductance(g: CSRGraph, p: np.ndarray):
    """Sweep cut over one PPR vector. Returns (sizes, conductances) along the
    sweep prefix order (deg-normalized, ACL standard)."""
    deg = g.out_degree().astype(np.float64)
    support = np.flatnonzero(p > 0)
    if support.size < 2:
        return np.array([], dtype=np.int64), np.array([])
    score = p[support] / np.maximum(deg[support], 1.0)
    order = support[np.argsort(-score, kind="stable")]
    rank = np.full(g.n, np.iinfo(np.int64).max, dtype=np.int64)
    rank[order] = np.arange(order.size)
    vol = np.cumsum(deg[order])
    src, dst, _ = g.edges()
    both = (rank[src] < order.size) & (rank[dst] < order.size)
    eranks = np.maximum(rank[src[both]], rank[dst[both]])
    internal = np.bincount(eranks, minlength=order.size).astype(np.float64)
    cut = vol - np.cumsum(internal)
    m2 = float(deg.sum())
    denom = np.minimum(vol, m2 - vol)
    keep = denom > 0
    cond = np.full(order.size, np.inf)
    cond[keep] = cut[keep] / denom[keep]
    sizes = np.arange(1, order.size + 1)
    return sizes, cond


def ncp_profile(g: CSRGraph, pvals: np.ndarray,
                max_size: Optional[int] = None) -> np.ndarray:
    """Min conductance per log2 cluster-size bin over PPR vectors [Q, n]."""
    max_size = max_size or g.n
    nbins = int(np.ceil(np.log2(max_size))) + 1
    best = np.full(nbins, np.inf)
    for qi in range(pvals.shape[0]):
        sizes, cond = sweep_conductance(g, pvals[qi])
        if sizes.size == 0:
            continue
        bins = np.minimum(np.log2(sizes).astype(np.int64), nbins - 1)
        np.minimum.at(best, bins, cond)
    return best


def ncp(g: CSRGraph, seeds: np.ndarray, alpha: float = 0.15,
        eps: float = 1e-4, block_size: int = 256, method: str = "bfs",
        yield_config: Optional[YieldConfig] = None,
        schedule: str = "priority", max_size: Optional[int] = None):
    """Network community profile: min conductance per cluster size (log bins).

    Paper setting: PPRs seeded from 0.01% random vertices (we take ``seeds``)."""
    sess = _session(g, block_size, method, schedule, yield_config,
                    len(np.asarray(seeds)))
    return sess.ncp(np.asarray(seeds), alpha=alpha, eps=eps,
                    max_size=max_size)
