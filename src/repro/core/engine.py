"""The buffered execution engine — Algorithm 2 of the paper, TPU-native.

One *visit* makes a partition resident (HBM->VMEM via the Pallas kernels on
real hardware; a [B, B] block on CPU) and drains its buffered operations for
all Q queries at once:

  minplus mode (SSSP / BFS / BC / LL):
    d <- min(d, buf)                      # apply + consolidate buffered ops
    repeat (until converged / yield):
      active = pending & Δ-window & edge-budget
      d <- min(d, minplus(d|active, W_pp))  # vectorized local relaxation
    emit: buf[j] <- min(buf[j], minplus(d|emitted, W_pj)) for each neighbor j

  push mode (PPR / NCP):
    r <- r + buf                          # residual contributions consolidate by +
    repeat: p += a*r|active; spread = ((1-a)*r/deg)|active @ Adj
    emit: buf[j] += push_acc @ Adj_pj

Everything a CPU thread did with a priority queue is done here by masking:
the Δ-window mask *is* the per-query priority order (only best-value ops
relax), and the dense min/sum buffer *is* query-centric consolidation
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import BlockGraph
from repro.core.scheduler import PartitionScheduler
from repro.core.yielding import YieldConfig
from repro.kernels.minplus import ops as minplus_ops

INF = jnp.inf
_BIG_STAMP = np.iinfo(np.int32).max - 1


# ---------------------------------------------------------------------------
# state containers


class MinplusState(NamedTuple):
    dist: jax.Array       # [P, Q, B] tentative values (partition-major)
    buf: jax.Array        # [P+1, Q, B] pending ops (+inf empty; row P = trash)
    prio: jax.Array       # [P] best pending value per partition (+inf empty)
    ops_count: jax.Array  # [P] pending op count
    stamp: jax.Array      # [P] visit counter when buffer became non-empty
    edges: jax.Array      # [Q] edges processed per query (work accounting)


class PushState(NamedTuple):
    p: jax.Array          # [P, Q, B] PPR mass
    r: jax.Array          # [P, Q, B] residual
    buf: jax.Array        # [P+1, Q, B] pending residual contributions (0 empty)
    prio: jax.Array       # [P] -max(residual ratio) (+inf when below eps)
    ops_count: jax.Array
    stamp: jax.Array
    edges: jax.Array


class VisitStats(NamedTuple):
    visits: int
    rounds: int
    blocks_loaded: int
    modeled_bytes: float  # modeled HBM->VMEM traffic (cache-miss analogue)


# ---------------------------------------------------------------------------
# device-side graph bundle


@dataclasses.dataclass
class DeviceGraph:
    """BlockGraph arrays staged onto device once (the in-memory graph)."""
    blocks: jax.Array     # [nblk, B, B] f32, +inf absent
    row_nnz: jax.Array    # [nblk, B] i32
    nbr_blk: jax.Array    # [P, Dmax] i32 (-1 pad)
    nbr_part: jax.Array   # [P, Dmax] i32 (-1 pad)
    diag_blk: jax.Array   # [P] i32
    deg: jax.Array        # [P, B] i32
    vmask: jax.Array      # [P, B] bool
    edge_budget: jax.Array  # [P] f32 per-query edge budget per visit
    num_parts: int
    block_size: int
    dmax: int

    @staticmethod
    def build(bg: BlockGraph, yc: YieldConfig, num_queries: int) -> "DeviceGraph":
        part_edges = np.zeros(bg.num_parts, dtype=np.int64)
        np.add.at(part_edges, bg.blk_src, bg.row_nnz.sum(axis=1))
        return DeviceGraph(
            blocks=jnp.asarray(bg.blocks),
            row_nnz=jnp.asarray(bg.row_nnz),
            nbr_blk=jnp.asarray(bg.nbr_blk),
            nbr_part=jnp.asarray(bg.nbr_part),
            diag_blk=jnp.asarray(bg.diag_blk),
            deg=jnp.asarray(bg.deg),
            vmask=jnp.asarray(bg.vmask),
            edge_budget=jnp.asarray(yc.edge_budget(part_edges, num_queries)),
            num_parts=bg.num_parts,
            block_size=bg.block_size,
            dmax=bg.nbr_blk.shape[1],
        )


# ---------------------------------------------------------------------------
# minplus visit (SSSP / BFS family)


def _pending_row_prio(buf_row: jax.Array, dist_row: jax.Array):
    """Pending = buffered op that can still improve (<=: yielded ops re-enter)."""
    pending = jnp.isfinite(buf_row) & (buf_row <= dist_row)
    vals = jnp.where(pending, buf_row, INF)
    return pending, vals


def make_minplus_visit(dg: DeviceGraph, window: float, max_rounds: int,
                       relax: Callable = None) -> Callable:
    relax = relax or minplus_ops.minplus
    P, B = dg.num_parts, dg.block_size

    @jax.jit
    def visit(state: MinplusState, p: jax.Array, counter: jax.Array):
        w_pp = dg.blocks[dg.diag_blk[p]]
        nnz_pp = dg.row_nnz[dg.diag_blk[p]]          # [B]
        d0 = state.dist[p]                           # [Q, B]
        bufrow = state.buf[p]
        pending0, vals0 = _pending_row_prio(bufrow, d0)
        d1 = jnp.minimum(d0, jnp.where(pending0, bufrow, INF))
        alpha = jnp.min(jnp.where(pending0, d1, INF), axis=1, keepdims=True)
        budget = dg.edge_budget[p]                   # per-query edges this visit

        def cond(c):
            d, pending, emit, eq, rounds = c
            active = pending & (d <= alpha + window) & (eq < budget)[:, None]
            return jnp.logical_and(rounds < max_rounds, jnp.any(active))

        def body(c):
            d, pending, emit, eq, rounds = c
            active = pending & (d <= alpha + window) & (eq < budget)[:, None]
            srcs = jnp.where(active, d, INF)
            nd = relax(srcs, w_pp)
            eq = eq + jnp.sum(jnp.where(active, nnz_pp[None, :], 0), axis=1)
            emit = emit | active
            pending = pending & ~active
            improved = nd < d
            d = jnp.minimum(d, nd)
            pending = pending | improved
            return d, pending, emit, eq, rounds + 1

        eq0 = jnp.zeros(d1.shape[0], dtype=jnp.float32)
        emit0 = jnp.zeros_like(pending0)
        d, pending, emit, eq, rounds = jax.lax.while_loop(
            cond, body, (d1, pending0, emit0, eq0, jnp.int32(0)))

        # ---- emission to neighbor partitions (Alg. 2 line 16, batched) ----
        srcs = jnp.where(emit, d, INF)

        def emit_one(slot, carry):
            buf, prio, ops, stamp, eq = carry
            blk = dg.nbr_blk[p, slot]
            j = dg.nbr_part[p, slot]
            valid = j >= 0
            jj = jnp.where(valid, j, P)              # trash row for padding
            w_pj = dg.blocks[jnp.where(valid, blk, 0)]
            cand = jnp.where(valid, relax(srcs, w_pj), INF)
            eq = eq + jnp.where(
                valid,
                jnp.sum(jnp.where(emit, dg.row_nnz[jnp.where(valid, blk, 0)][None, :], 0),
                        axis=1).astype(jnp.float32),
                0.0)
            dj = state.dist[jnp.where(valid, j, 0)]
            new_row = jnp.minimum(buf[jj], cand)
            buf = buf.at[jj].set(new_row)
            pj, vj = _pending_row_prio(new_row, dj)
            newprio = jnp.min(vj)
            newops = jnp.sum(pj)
            was_empty = ~jnp.isfinite(prio[jj % P])
            prio = prio.at[jj].set(jnp.where(valid, newprio, prio[jj % P]),
                                   mode="drop")
            ops = ops.at[jj].set(jnp.where(valid, newops, ops[jj % P]),
                                 mode="drop")
            stamp = stamp.at[jj].set(
                jnp.where(valid & was_empty & jnp.isfinite(newprio),
                          counter, stamp[jj % P]), mode="drop")
            return buf, prio, ops, stamp, eq

        carry = (state.buf, state.prio, state.ops_count, state.stamp, eq)
        buf, prio, ops_count, stamp, eq = jax.lax.fori_loop(
            0, dg.dmax, emit_one, carry)

        # ---- store yielded/pending ops back into own buffer ----
        keep_vals = jnp.where(pending, d, INF)
        buf = buf.at[p].set(keep_vals)
        own_prio = jnp.min(keep_vals)
        prio = prio.at[p].set(own_prio)
        ops_count = ops_count.at[p].set(jnp.sum(pending))
        stamp = stamp.at[p].set(jnp.where(jnp.isfinite(own_prio), counter,
                                          jnp.int32(_BIG_STAMP)))
        dist = state.dist.at[p].set(d)
        edges = state.edges + (eq - eq0)
        return MinplusState(dist, buf, prio, ops_count, stamp, edges), rounds

    return visit


def init_minplus_state(dg: DeviceGraph, sources: np.ndarray) -> MinplusState:
    """sources: [Q] vertex ids in the *reordered* id space."""
    P, B = dg.num_parts, dg.block_size
    Q = int(len(sources))
    dist = jnp.full((P, Q, B), INF, dtype=jnp.float32)
    buf = jnp.full((P + 1, Q, B), INF, dtype=jnp.float32)
    parts = np.asarray(sources) // B
    locs = np.asarray(sources) % B
    buf = buf.at[parts, np.arange(Q), locs].set(0.0)
    prio = jnp.full((P,), INF, dtype=jnp.float32)
    prio = prio.at[parts].min(0.0)
    ops_count = jnp.zeros((P,), dtype=jnp.int32)
    ops_count = ops_count.at[parts].add(1)
    stamp = jnp.full((P,), _BIG_STAMP, dtype=jnp.int32)
    stamp = stamp.at[parts].set(0)
    edges = jnp.zeros((Q,), dtype=jnp.float32)
    return MinplusState(dist, buf, prio, ops_count, stamp, edges)


# ---------------------------------------------------------------------------
# push visit (PPR family)


def make_push_visit(dg: DeviceGraph, alpha: float, eps: float, max_rounds: int,
                    spread: Callable = None) -> Callable:
    spread = spread or minplus_ops.masked_matmul
    P, B = dg.num_parts, dg.block_size

    @jax.jit
    def visit(state: PushState, pid: jax.Array, counter: jax.Array):
        w_pp = dg.blocks[dg.diag_blk[pid]]
        nnz_pp = dg.row_nnz[dg.diag_blk[pid]]
        degc = jnp.maximum(dg.deg[pid], 1).astype(jnp.float32)   # [B]
        thresh = eps * degc
        pr0 = state.p[pid]
        r0 = state.r[pid] + state.buf[pid]
        budget = dg.edge_budget[pid]
        has_edges = (dg.deg[pid] > 0)

        def cond(c):
            pr, r, acc, eq, rounds = c
            active = (r >= thresh[None, :]) & has_edges[None, :] \
                & (eq < budget)[:, None]
            return jnp.logical_and(rounds < max_rounds, jnp.any(active))

        def body(c):
            pr, r, acc, eq, rounds = c
            active = (r >= thresh[None, :]) & has_edges[None, :] \
                & (eq < budget)[:, None]
            af = active.astype(r.dtype)
            pr = pr + alpha * r * af
            push = (1.0 - alpha) * r * af / degc[None, :]
            eq = eq + jnp.sum(jnp.where(active, nnz_pp[None, :], 0), axis=1)
            s = spread(push, w_pp)
            r = r * (1.0 - af) + s
            acc = acc + push
            return pr, r, acc, eq, rounds + 1

        acc0 = jnp.zeros_like(r0)
        eq0 = jnp.zeros(r0.shape[0], dtype=jnp.float32)
        pr, r, acc, eq, rounds = jax.lax.while_loop(
            cond, body, (pr0, r0, acc0, eq0, jnp.int32(0)))

        def emit_one(slot, carry):
            buf, prio, ops, stamp, eq = carry
            blk = dg.nbr_blk[pid, slot]
            j = dg.nbr_part[pid, slot]
            valid = j >= 0
            jj = jnp.where(valid, j, P)
            w_pj = dg.blocks[jnp.where(valid, blk, 0)]
            contrib = jnp.where(valid, spread(acc, w_pj), 0.0)
            eq = eq + jnp.where(
                valid,
                jnp.sum((acc > 0)
                        * dg.row_nnz[jnp.where(valid, blk, 0)][None, :],
                        axis=1).astype(jnp.float32),
                0.0)
            new_row = buf[jj] + contrib
            buf = buf.at[jj].set(new_row)
            # neighbor priority: -max residual ratio of (r + buf)
            rj = state.r[jnp.where(valid, j, 0)] + new_row
            degj = jnp.maximum(dg.deg[jnp.where(valid, j, 0)], 1)
            ratio = rj / (eps * degj.astype(jnp.float32)[None, :])
            ready = ratio >= 1.0
            newprio = jnp.where(jnp.any(ready), -jnp.max(ratio), INF)
            was_empty = ~jnp.isfinite(prio[jj % P])
            prio = prio.at[jj].set(jnp.where(valid, newprio, prio[jj % P]),
                                   mode="drop")
            ops = ops.at[jj].set(jnp.where(valid, jnp.sum(ready),
                                           ops[jj % P]), mode="drop")
            stamp = stamp.at[jj].set(
                jnp.where(valid & was_empty & jnp.isfinite(newprio),
                          counter, stamp[jj % P]), mode="drop")
            return buf, prio, ops, stamp, eq

        carry = (state.buf, state.prio, state.ops_count, state.stamp, eq)
        buf, prio, ops_count, stamp, eq = jax.lax.fori_loop(
            0, dg.dmax, emit_one, carry)

        buf = buf.at[pid].set(jnp.zeros_like(r))
        ratio = r / thresh[None, :]
        ready = (ratio >= 1.0) & has_edges[None, :]
        own_prio = jnp.where(jnp.any(ready), -jnp.max(jnp.where(
            has_edges[None, :], ratio, -INF)), INF)
        prio = prio.at[pid].set(own_prio)
        ops_count = ops_count.at[pid].set(jnp.sum(ready))
        stamp = stamp.at[pid].set(jnp.where(jnp.isfinite(own_prio), counter,
                                            jnp.int32(_BIG_STAMP)))
        pout = state.p.at[pid].set(pr)
        rout = state.r.at[pid].set(r)
        edges = state.edges + (eq - eq0)
        return PushState(pout, rout, buf, prio, ops_count, stamp, edges), rounds

    return visit


def init_push_state(dg: DeviceGraph, sources: np.ndarray,
                    eps: float) -> PushState:
    P, B = dg.num_parts, dg.block_size
    Q = int(len(sources))
    p = jnp.zeros((P, Q, B), dtype=jnp.float32)
    r = jnp.zeros((P, Q, B), dtype=jnp.float32)
    buf = jnp.zeros((P + 1, Q, B), dtype=jnp.float32)
    parts = np.asarray(sources) // B
    locs = np.asarray(sources) % B
    r = r.at[parts, np.arange(Q), locs].set(1.0)
    deg = np.asarray(dg.deg)
    degc = np.maximum(deg, 1)
    rnp = np.zeros((P, B), dtype=np.float32)
    np.maximum.at(rnp, (parts, locs), 1.0)
    ratio = rnp / (eps * degc)
    ready = (ratio >= 1.0) & (deg > 0)
    prio_np = np.where(ready.any(axis=1),
                       -np.where(ready, ratio, -np.inf).max(axis=1), np.inf)
    prio = jnp.asarray(prio_np.astype(np.float32))
    ops_count = jnp.asarray(ready.sum(axis=1).astype(np.int32))
    stamp = jnp.asarray(np.where(np.isfinite(prio_np), 0, _BIG_STAMP)
                        .astype(np.int32))
    edges = jnp.zeros((Q,), dtype=jnp.float32)
    return PushState(p, r, buf, prio, ops_count, stamp, edges)


# ---------------------------------------------------------------------------
# host-driven engine (Alg. 2 outer loop)


@dataclasses.dataclass
class EngineResult:
    values: np.ndarray        # [Q, n] distances (minplus) or PPR mass (push)
    residual: Optional[np.ndarray]
    edges_processed: np.ndarray  # [Q]
    stats: VisitStats
    visit_order: list


class FPPEngine:
    """Single-device ForkGraph engine.

    mode: "minplus" (SSSP/BFS) or "push" (PPR).
    """

    def __init__(self, bg: BlockGraph, mode: str = "minplus",
                 yield_config: YieldConfig = YieldConfig(),
                 schedule: str = "priority", num_queries: int = 1,
                 alpha: float = 0.15, eps: float = 1e-4, seed: int = 0,
                 use_pallas: bool = False):
        assert mode in ("minplus", "push")
        self.bg = bg
        self.mode = mode
        self.yc = yield_config
        self.num_queries = num_queries
        self.alpha, self.eps = alpha, eps
        self.dg = DeviceGraph.build(bg, yield_config, num_queries)
        self.scheduler = PartitionScheduler(schedule, bg.num_parts, seed)
        max_rounds = yield_config.max_rounds or (
            bg.block_size if mode == "minplus" else 64)
        relax = (minplus_ops.minplus_pallas if use_pallas else None)
        spread = (minplus_ops.masked_matmul_pallas if use_pallas else None)
        if mode == "minplus":
            self._visit = make_minplus_visit(self.dg, yield_config.window(),
                                             max_rounds, relax=relax)
        else:
            self._visit = make_push_visit(self.dg, alpha, eps, max_rounds,
                                          spread=spread)
        # modeled HBM traffic per visit: diagonal block + touched out-blocks +
        # two state tiles — the cache-miss analogue used by fig10.
        B = bg.block_size
        out_blocks = (bg.nbr_blk >= 0).sum(axis=1)
        self._visit_bytes = ((1 + out_blocks) * B * B * 4
                             + 2 * num_queries * B * 4).astype(np.float64)
        self._visit_blocks = (1 + out_blocks).astype(np.int64)

    def init_state(self, sources: np.ndarray):
        if self.mode == "minplus":
            return init_minplus_state(self.dg, sources)
        return init_push_state(self.dg, sources, self.eps)

    def run(self, sources: np.ndarray, max_visits: int | None = None,
            record_order: bool = False) -> EngineResult:
        assert len(sources) == self.num_queries
        state = self.init_state(np.asarray(sources))
        max_visits = max_visits or 2000 * self.bg.num_parts
        visits = rounds = blocks = 0
        traffic = 0.0
        order = []
        counter = 0
        while visits < max_visits:
            prio = np.asarray(state.prio)
            stamp = np.asarray(state.stamp)
            ops = np.asarray(state.ops_count)
            p = self.scheduler.select(prio, stamp, ops)
            if p is None:
                break
            state, r = self._visit(state, jnp.int32(p), jnp.int32(counter))
            counter += 1
            visits += 1
            rounds += int(r)
            blocks += int(self._visit_blocks[p])
            traffic += float(self._visit_bytes[p])
            if record_order:
                order.append(p)
        stats = VisitStats(visits=visits, rounds=rounds, blocks_loaded=blocks,
                           modeled_bytes=traffic)
        n = self.bg.n
        if self.mode == "minplus":
            vals = np.asarray(state.dist).transpose(1, 0, 2).reshape(
                self.num_queries, -1)[:, :n]
            return EngineResult(vals, None, np.asarray(state.edges), stats,
                                order)
        pvals = np.asarray(state.p).transpose(1, 0, 2).reshape(
            self.num_queries, -1)[:, :n]
        # pending buffered contributions ARE residual mass that was never
        # consolidated (below-eps ops at termination): fold them in so
        # p + r conserves probability exactly (test_ppr_mass_is_conserved)
        rfull = np.asarray(state.r) + np.asarray(
            state.buf[:self.bg.num_parts])
        rvals = rfull.transpose(1, 0, 2).reshape(
            self.num_queries, -1)[:, :n]
        return EngineResult(pvals, rvals, np.asarray(state.edges), stats,
                            order)
