"""The buffered execution engine — Algorithm 2 of the paper, TPU-native.

One *visit* makes a partition resident (HBM->VMEM via the Pallas kernels on
real hardware; a [B, B] block on CPU) and drains its buffered operations for
all Q queries at once.  The visit body itself lives in ``core/visit.py`` as a
single generic skeleton; this module owns the engine around it (device graph
staging, traffic modeling) and instantiates the skeleton for both modes.
The hot loop is *device-resident*: ``FPPEngine.run`` dispatches K-visit
megasteps (``core/visit.make_megastep``) whose scheduler decision is an
on-device argmin over the ``[P]`` metadata planes, so the host is consulted
once per K visits — O(visits/K) synchronizations instead of O(visits)
(``host_loop=True`` keeps the legacy per-visit loop as the tested oracle).
The two modes:

  minplus mode (SSSP / BFS / BC / LL):
    d <- min(d, buf)                      # apply + consolidate buffered ops
    repeat (until converged / yield):
      active = pending & Δ-window & edge-budget
      d <- min(d, minplus(d|active, W_pp))  # vectorized local relaxation
    emit: buf[j] <- min(buf[j], minplus(d|emitted, W_pj)) for each neighbor j

  push mode (PPR / NCP):
    r <- r + buf                          # residual contributions consolidate by +
    repeat: p += a*r|active; spread = ((1-a)*r/deg)|active @ Adj
    emit: buf[j] += push_acc @ Adj_pj

Everything a CPU thread did with a priority queue is done here by masking:
the Δ-window mask *is* the per-query priority order (only best-value ops
relax), and the dense min/sum buffer *is* query-centric consolidation
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import visit as _visit
from repro.core.graph import BlockGraph
from repro.core.oracles import decode_kreach
from repro.core.scheduler import PartitionScheduler
from repro.core.visit import (VisitAlgebra, VisitState, minplus_algebra,
                              push_algebra)
from repro.core.yielding import YieldConfig
from repro.kernels.minplus import ops as minplus_ops

#: ``cc`` and ``kreach`` are minplus-algebra instantiations over transformed
#: weight planes (zero weights + per-vertex label ops; hop-shifted weights),
#: so they inherit the megastep / fused-kernel / superstep machinery intact —
#: only the state init and the host-side finalize differ (DESIGN.md §2.1).
MODES = ("minplus", "push", "cc", "kreach")


class VisitStats(NamedTuple):
    visits: int
    rounds: int
    blocks_loaded: int
    modeled_bytes: float  # modeled HBM->VMEM traffic (cache-miss analogue)
    host_syncs: int = 0   # device->host round trips the run paid (megastep:
    #                       one per K-visit chunk; host loop: one per visit)


# ---------------------------------------------------------------------------
# device-side graph bundle


@dataclasses.dataclass
class DeviceGraph:
    """BlockGraph arrays staged onto device once (the in-memory graph)."""
    blocks: jax.Array     # [nblk, B, B] f32, +inf absent
    row_nnz: jax.Array    # [nblk, B] i32
    nbr_blk: jax.Array    # [P, Dmax] i32 (-1 pad)
    nbr_part: jax.Array   # [P, Dmax] i32 (-1 pad)
    diag_blk: jax.Array   # [P] i32
    deg: jax.Array        # [P, B] i32
    vmask: jax.Array      # [P, B] bool
    edge_budget: jax.Array  # [P] f32 per-query edge budget per visit
    num_parts: int
    block_size: int
    dmax: int

    @staticmethod
    def build(bg: BlockGraph, yc: YieldConfig, num_queries: int) -> "DeviceGraph":
        part_edges = np.zeros(bg.num_parts, dtype=np.int64)
        np.add.at(part_edges, bg.blk_src, bg.row_nnz.sum(axis=1))
        return DeviceGraph(
            blocks=jnp.asarray(bg.blocks),
            row_nnz=jnp.asarray(bg.row_nnz),
            nbr_blk=jnp.asarray(bg.nbr_blk),
            nbr_part=jnp.asarray(bg.nbr_part),
            diag_blk=jnp.asarray(bg.diag_blk),
            deg=jnp.asarray(bg.deg),
            vmask=jnp.asarray(bg.vmask),
            edge_budget=jnp.asarray(yc.edge_budget(part_edges, num_queries)),
            num_parts=bg.num_parts,
            block_size=bg.block_size,
            dmax=bg.nbr_blk.shape[1],
        )


# ---------------------------------------------------------------------------
# mode instantiations of the shared skeleton (core/visit.py)


def make_minplus_visit(dg: DeviceGraph, window: float, max_rounds: int,
                       relax: Callable = None) -> Callable:
    """SSSP/BFS visit = the generic kernel under the minplus algebra."""
    return _visit.make_visit(dg, minplus_algebra(window, relax=relax),
                             max_rounds)


def make_push_visit(dg: DeviceGraph, alpha: float, eps: float, max_rounds: int,
                    spread: Callable = None) -> Callable:
    """PPR visit = the generic kernel under the push algebra."""
    return _visit.make_visit(dg, push_algebra(alpha, eps, spread=spread),
                             max_rounds)


def init_minplus_state(dg: DeviceGraph, sources: np.ndarray) -> VisitState:
    """sources: [Q] vertex ids in the *reordered* id space."""
    return _visit.init_engine_state(minplus_algebra(np.inf), dg, sources)


def init_push_state(dg: DeviceGraph, sources: np.ndarray,
                    eps: float, alpha: float = 0.15) -> VisitState:
    return _visit.init_engine_state(push_algebra(alpha, eps), dg, sources)


# ---------------------------------------------------------------------------
# host-driven engine (Alg. 2 outer loop)


@dataclasses.dataclass
class EngineResult:
    values: np.ndarray        # [Q, n] distances (minplus) or PPR mass (push)
    residual: Optional[np.ndarray]
    edges_processed: np.ndarray  # [Q] float64, exact (host-accumulated)
    stats: VisitStats
    visit_order: list


class FPPEngine:
    """Single-device ForkGraph engine.

    mode: "minplus" (SSSP/BFS) or "push" (PPR).
    """

    def __init__(self, bg: BlockGraph, mode: str = "minplus",
                 yield_config: YieldConfig = YieldConfig(),
                 schedule: str = "priority", num_queries: int = 1,
                 alpha: float = 0.15, eps: float = 1e-4, seed: int = 0,
                 use_pallas: bool = False, k_visits: int = 64,
                 fused: bool = False, frontier_mode: str = "dense",
                 hop_budget: int = 8, hop_stride: float = 1.0):
        if mode not in MODES:
            raise ValueError(f"unknown engine mode {mode!r}; one of {MODES}")
        if k_visits < 1:
            raise ValueError(f"k_visits must be >= 1, got {k_visits}")
        if mode == "cc" and bg.n >= (1 << 24):
            raise ValueError(
                f"cc labels ride the f32 minplus planes, exact only below "
                f"2^24 vertices; got n={bg.n}")
        self.bg = bg
        self.mode = mode
        self.yc = yield_config
        self.num_queries = num_queries
        self.alpha, self.eps = alpha, eps
        self.hop_budget, self.hop_stride = int(hop_budget), float(hop_stride)
        self.seed = seed
        self.k_visits = int(k_visits)
        self.fused = bool(fused)
        self.frontier_mode = frontier_mode
        self.dg = DeviceGraph.build(bg, yield_config, num_queries)
        self.scheduler = PartitionScheduler(schedule, bg.num_parts, seed)
        max_rounds = yield_config.max_rounds or (
            bg.block_size if mode != "push" else 64)
        self.max_rounds = max_rounds
        # fused visits run the whole body inside one pallas_call, so the
        # algebra must keep its XLA relax/spread — a pallas_call nested in
        # a Pallas kernel body would not lower
        if mode == "push":
            spread = (minplus_ops.masked_matmul_pallas
                      if use_pallas and not fused else None)
            self.algebra: VisitAlgebra = push_algebra(alpha, eps,
                                                      spread=spread)
        else:
            relax = (minplus_ops.minplus_pallas
                     if use_pallas and not fused else None)
            # cc propagates over zero weights, where an equal re-sent label
            # would pend (and re-emit) forever under the default <= rule
            self.algebra = minplus_algebra(yield_config.window(), relax=relax,
                                           strict=(mode == "cc"))
        self._visit = _visit.make_visit(self.dg, self.algebra, max_rounds)
        # the hot loop: K visits per host dispatch, scheduler on device;
        # fused=True swaps the visit body for the fused Pallas kernel
        self._megastep = _visit.make_megastep(
            self.dg, self.algebra, max_rounds, policy=schedule,
            K=self.k_visits, fused=self.fused,
            frontier_mode=self.frontier_mode)
        # modeled HBM traffic per visit: diagonal block + touched out-blocks +
        # two state tiles — the cache-miss analogue used by fig10.
        B = bg.block_size
        out_blocks = (bg.nbr_blk >= 0).sum(axis=1)
        self._visit_bytes = ((1 + out_blocks) * B * B * 4
                             + 2 * num_queries * B * 4).astype(np.float64)
        self._visit_blocks = (1 + out_blocks).astype(np.int64)

    def init_state(self, sources: np.ndarray) -> VisitState:
        if self.mode == "cc":
            # cc is a per-graph computation: every vertex is a source and
            # every query lane converges to the same label plane, so the
            # one-hot source injection is replaced by a full init plane
            # (sources only set the lane count)
            return _visit.init_engine_state(
                self.algebra, self.dg, np.empty(0, dtype=np.int64),
                num_queries=self.num_queries,
                init_ops=_visit.cc_label_plane(self.bg))
        return _visit.init_engine_state(self.algebra, self.dg, sources)

    def run(self, sources: np.ndarray, max_visits: int | None = None,
            record_order: bool = False,
            host_loop: bool = False) -> EngineResult:
        """Run the engine to completion (or ``max_visits``).

        The default path dispatches K-visit *megasteps*: partition selection
        happens on device and the host is consulted O(visits/K) times — one
        dispatch + one small stats harvest per chunk (``stats.host_syncs``
        counts them).  ``host_loop=True`` keeps the legacy one-sync-per-visit
        loop with the numpy :class:`PartitionScheduler`; it is the oracle the
        megastep is tested against (tests/test_megastep.py) and the baseline
        the dispatch microbench compares (benchmarks/bench_dispatch.py).
        """
        if len(sources) != self.num_queries:
            raise ValueError(
                f"got {len(sources)} sources for an engine planned for "
                f"num_queries={self.num_queries}; rebuild the engine (or the "
                f"session plan) with num_queries={len(sources)}")
        state = self.init_state(np.asarray(sources))
        max_visits = max_visits or 2000 * self.bg.num_parts
        if host_loop:
            return self._run_host_loop(state, max_visits, record_order)
        visits = rounds = syncs = 0
        order: list = []
        counts = np.zeros(self.dg.num_parts, dtype=np.int64)
        # edge counts leave the device as an exact (hi, lo) int32 pair per
        # chunk and accumulate here in float64, so totals stay exact past
        # 2^24 (f32) edges.
        edges = np.zeros(self.num_queries, dtype=np.float64)
        key = jax.random.PRNGKey(self.seed)
        while visits < max_visits:
            limit = min(self.k_visits, max_visits - visits)
            state, ms = self._megastep(state, jnp.int32(visits),
                                       jnp.int32(limit), key)
            syncs += 1
            v = int(ms.visits)          # the one host sync per chunk
            if v == 0:
                break
            key = ms.key
            edges += _visit.harvest_edges(ms.eq_hi, ms.eq_lo)
            counts += np.asarray(ms.visit_counts, dtype=np.int64)
            visits += v
            rounds += int(ms.rounds)
            if record_order:
                order.extend(int(x) for x in np.asarray(ms.order)[:v])
            if v < limit:
                # the while-cond can only exit below the limit when no
                # partition holds a pending op: the run is complete, no
                # empty confirmation dispatch needed
                break
        stats = VisitStats(
            visits=visits, rounds=rounds,
            blocks_loaded=int(counts @ self._visit_blocks),
            modeled_bytes=float(counts @ self._visit_bytes),
            host_syncs=syncs)
        return self._finalize(state, edges, stats, order)

    def _run_host_loop(self, state: VisitState, max_visits: int,
                       record_order: bool) -> EngineResult:
        """Legacy per-visit loop: prio/stamp/ops sync to host, numpy argmin,
        one jitted visit per dispatch — O(visits) host synchronizations."""
        visits = rounds = blocks = 0
        traffic = 0.0
        order: list = []
        counter = 0
        edges = np.zeros(self.num_queries, dtype=np.float64)
        while visits < max_visits:
            prio = np.asarray(state.prio)
            stamp = np.asarray(state.stamp)
            ops = np.asarray(state.ops_count)
            p = self.scheduler.select(prio, stamp, ops)
            if p is None:
                break
            state, (r, eq) = self._visit(state, jnp.int32(p),
                                         jnp.int32(counter))
            edges += np.asarray(eq, dtype=np.float64)
            counter += 1
            visits += 1
            rounds += int(r)
            blocks += int(self._visit_blocks[p])
            traffic += float(self._visit_bytes[p])
            if record_order:
                order.append(p)
        stats = VisitStats(visits=visits, rounds=rounds, blocks_loaded=blocks,
                           modeled_bytes=traffic, host_syncs=visits)
        return self._finalize(state, edges, stats, order)

    def _finalize(self, state: VisitState, edges: np.ndarray,
                  stats: VisitStats, order: list) -> EngineResult:
        n = self.bg.n
        if self.mode != "push":
            dist = state.planes[0]
            vals = np.asarray(dist).transpose(1, 0, 2).reshape(
                self.num_queries, -1)[:, :n]
            if self.mode == "kreach":
                # the packed lex-(hops, dist) plane unpacks on host; the hop
                # plane rides the residual slot of the result contract
                vals, hops = decode_kreach(vals, self.hop_stride,
                                           self.hop_budget)
                return EngineResult(vals, hops, edges, stats, order)
            return EngineResult(vals, None, edges, stats, order)
        pvals = np.asarray(state.planes[0]).transpose(1, 0, 2).reshape(
            self.num_queries, -1)[:, :n]
        # pending buffered contributions ARE residual mass that was never
        # consolidated (below-eps ops at termination): fold them in so
        # p + r conserves probability exactly (test_ppr_mass_is_conserved)
        rfull = np.asarray(state.planes[1]) + np.asarray(
            state.buf[:self.bg.num_parts])
        rvals = rfull.transpose(1, 0, 2).reshape(
            self.num_queries, -1)[:, :n]
        return EngineResult(pvals, rvals, edges, stats, order)
